"""The declarative front door: InterconnectSpec serialization, the pass
pipeline's determinism and legacy equivalence, CompiledFabric end-to-end,
and the spec-digest cache keys of the DSE executor."""
import subprocess
import sys

import numpy as np
import pytest

import canal
from repro.core.compile import CompiledFabric
from repro.core.passes import (IRPass, PassManager, freeze, ir_digest,
                               materialize_tiles, prune_dead_muxes)
from repro.core.spec import (InterconnectSpec, SwitchBoxType,
                             spec_from_kwargs, spec_grid)

SMOKE = dict(width=4, height=4, num_tracks=2, io_ring=True, reg_density=1.0)


# ---------------------------------------------------------------------------
# Spec serialization
# ---------------------------------------------------------------------------

def test_spec_json_round_trip():
    spec = InterconnectSpec(width=6, height=5, num_tracks=3,
                            sb_type="imran", reg_density=0.5,
                            mem_columns=(2,), extra_layers={1: 4},
                            ready_valid=True, split_fifo=True,
                            route_strategy="minplus", auto_min_tiles=30)
    rt = InterconnectSpec.from_json(spec.to_json())
    assert rt == spec
    assert rt.digest() == spec.digest()
    assert hash(rt) == hash(spec)


def test_spec_digest_key_order_independent():
    spec = InterconnectSpec(**SMOKE)
    d = spec.to_dict()
    shuffled = {k: d[k] for k in sorted(d, reverse=True)}
    assert InterconnectSpec.from_dict(shuffled).digest() == spec.digest()


def test_spec_digest_stable_across_processes():
    import os

    import repro.core.spec as spec_mod

    spec = InterconnectSpec(**SMOKE)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(spec_mod.__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    code = ("from repro.core.spec import InterconnectSpec\n"
            f"print(InterconnectSpec(**{SMOKE!r}).digest())\n")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, check=True, env=env)
    assert out.stdout.strip() == spec.digest()


def test_spec_is_frozen_and_canonicalized():
    spec = InterconnectSpec(**SMOKE)
    with pytest.raises(Exception):       # FrozenInstanceError
        spec.width = 99                  # type: ignore[misc]
    # str sb_type and dict extra_layers canonicalize to enum/sorted tuple
    a = InterconnectSpec(sb_type="wilton", extra_layers={1: 4, 32: 2})
    b = InterconnectSpec(sb_type=SwitchBoxType.WILTON,
                         extra_layers=((1, 4), (32, 2)))
    assert a == b and a.digest() == b.digest()
    assert {a: "hit"}[b] == "hit"        # usable as a dict key


def test_spec_validation():
    with pytest.raises(ValueError):
        InterconnectSpec(width=0)
    with pytest.raises(ValueError):
        InterconnectSpec(reg_density=1.5)
    with pytest.raises(ValueError):
        InterconnectSpec(route_strategy="warp")
    with pytest.raises(TypeError):
        InterconnectSpec.from_dict({"widht": 4})     # typo -> clear error


def test_spec_from_kwargs_rejects_callables():
    with pytest.raises(TypeError, match="core_fn.*not serializable"):
        spec_from_kwargs(width=4, core_fn=lambda x, y, w, h: None)


def test_spec_grid_product_and_labels():
    base = InterconnectSpec(**SMOKE)
    pts = spec_grid(base, {"num_tracks": (2, 3), "sb_type":
                           (SwitchBoxType.WILTON, SwitchBoxType.DISJOINT)})
    assert len(pts) == 4
    specs = [s for s, _ in pts]
    assert len(set(specs)) == 4
    assert pts[0][1] == {"num_tracks": 2, "sb_type": "wilton"}
    labelled = spec_grid(base, {"num_tracks": (2,)},
                         label=lambda s: {"t": s.num_tracks * 10})
    assert labelled[0][1] == {"t": 20}


# ---------------------------------------------------------------------------
# Pass pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic():
    """Compiling the same spec twice yields isomorphic IR: identical
    node/edge multisets down to mux input order (= config semantics)."""
    spec = InterconnectSpec(width=5, height=4, num_tracks=3, io_ring=True,
                            reg_density=0.5, cb_track_fc=0.5,
                            mem_columns=(2,))
    a, b = canal.compile(spec), canal.compile(spec)
    assert a.ir_digest() == b.ir_digest()
    assert a.interconnect.num_nodes() == b.interconnect.num_nodes()
    assert a.interconnect.num_edges() == b.interconnect.num_edges()
    assert a.interconnect.connectivity() == b.interconnect.connectivity()


def test_shim_emits_deprecation_and_matches_pipeline():
    """`create_uniform_interconnect` still works, warns, and produces an
    interconnect isomorphic to PassManager.compile(InterconnectSpec())."""
    from repro.core.edsl import create_uniform_interconnect

    with pytest.warns(DeprecationWarning, match="canal.compile"):
        legacy = create_uniform_interconnect(**SMOKE)
    compiled = PassManager().compile(InterconnectSpec(**SMOKE))
    assert ir_digest(legacy) == compiled.ir_digest()
    assert legacy.connectivity() == compiled.interconnect.connectivity()


def test_pipeline_for_gates_optional_passes():
    pm = PassManager()
    static = pm.pipeline_for(InterconnectSpec(**SMOKE))
    rv = pm.pipeline_for(InterconnectSpec(ready_valid=True, **SMOKE))
    assert "readyvalid_transform" not in static
    assert "readyvalid_transform" in rv
    assert static == ["materialize_tiles", "apply_sb_topology",
                      "insert_pipeline_registers", "connect_core_ports",
                      "prune_dead_muxes", "freeze"]


def test_readyvalid_transform_annotates_ir():
    fab = canal.compile(InterconnectSpec(ready_valid=True, split_fifo=True,
                                         **SMOKE))
    ic = fab.interconnect
    regs = [r for g in ic.graphs.values() for r in g.registers]
    assert regs and all(r.attributes.get("rv_fifo") == "split"
                        for r in regs)
    assert ic.params["rv_fifo_mode"] == "split"
    from repro.fabric import RVFabric
    assert isinstance(fab.fabric(), RVFabric)


def test_prune_removes_only_isolated_nodes():
    """A pipeline that never wires the switch boxes leaves every SB node
    isolated: prune drops them all but keeps core ports (interface)."""
    spec = InterconnectSpec(**SMOKE)
    pm = PassManager((IRPass("materialize_tiles", materialize_tiles),
                      IRPass("prune_dead_muxes", prune_dead_muxes),
                      IRPass("freeze", freeze)))
    ic = pm.run(spec)
    from repro.core.graph import NodeKind
    kinds = {n.kind for n in ic.nodes()}
    assert NodeKind.SWITCH_BOX not in kinds          # all isolated -> gone
    assert NodeKind.PORT in kinds                    # interface kept
    # full pipeline on the stock uniform topology: nothing is isolated
    full = canal.compile(spec)
    log = [e for e in full.pass_log if e["pass"] == "prune_dead_muxes"]
    assert log and log[0]["removed"] == 0


def test_prune_refuses_connected_nodes():
    from repro.core.graph import InterconnectGraph, PortNode

    g = InterconnectGraph(16)
    a, b = PortNode("a", 0, 0, 16), PortNode("b", 0, 0, 16)
    a.add_edge(b)
    with pytest.raises(ValueError, match="connected"):
        g.prune([a])


def test_prune_accepts_generator_input():
    """A one-shot iterable must not drain during validation and then
    silently prune nothing."""
    from repro.core.graph import InterconnectGraph, RegisterNode

    g = InterconnectGraph(16)
    reg = RegisterNode("r", 0, 0, 0, 16)
    g.add_register(reg)
    g.prune(n for n in [reg])
    assert reg not in list(g.nodes())


def test_readyvalid_rejects_unsupported_fifo_depth():
    spec = InterconnectSpec(ready_valid=True, fifo_depth=8, **SMOKE)
    with pytest.raises(ValueError, match="depth-2"):
        canal.compile(spec)


def test_prune_never_removes_routed_nodes():
    """No node used by any routed example app is pruned."""
    from repro.core.pnr.app import BENCH_APPS

    spec = InterconnectSpec(width=6, height=6, num_tracks=4, io_ring=True,
                            reg_density=1.0)
    fab = canal.compile(spec)
    pruned = set()
    for g in fab.interconnect.graphs.values():
        pruned |= g._pruned
    for name in ("pointwise", "tree_reduce"):
        r = fab.place_and_route(BENCH_APPS[name](), alphas=(2.0,),
                                sa_steps=30, sa_batch=8)
        assert r.success, f"{name}: {r.error}"
        used = {n for e in r.route_edges() for n in e}
        assert not (used & pruned)


# ---------------------------------------------------------------------------
# CompiledFabric end to end
# ---------------------------------------------------------------------------

def test_compiled_fabric_end_to_end():
    """spec -> compile -> place_and_route -> bitstream -> emulate, the
    quickstart flow, asserted."""
    from repro.core.pnr.app import app_pointwise

    spec = InterconnectSpec(width=6, height=6, num_tracks=4, io_ring=True,
                            reg_density=1.0)
    fab = canal.compile(spec)
    area = fab.area()
    assert area["sb_area"] > 0 and area["cb_area"] > 0

    result = fab.place_and_route(app_pointwise(2), alphas=(2.0,),
                                 sa_steps=40, sa_batch=8)
    assert result.success, result.error
    assert result.route_strategy in ("python", "minplus")

    words = fab.bitstream(result)
    assert len(words) > 0
    # all three accepted cfg forms agree: PnRResult, edge list, vector
    assert fab.bitstream(result.route_edges()) == words
    cfg = fab.fabric().route_to_config(result.route_edges())
    assert fab.bitstream(cfg) == words

    T = 10
    x = np.arange(7, 7 + T, dtype=np.int32)
    outs = fab.emulate(result, {"in0": x}, cycles=T)
    y = np.asarray(outs[result.placement["out0"]])
    lat = int(np.nonzero(y)[0][0])
    assert list(y[lat:lat + 4]) == list(x[:4] + 3)


def test_compiled_fabric_backend_memoized():
    fab = canal.compile(InterconnectSpec(**SMOKE))
    assert fab.fabric() is fab.fabric()
    assert fab.resources() is fab.resources()
    assert fab.resources(2.0) is not fab.resources(4.0)


def test_custom_core_fn_marks_uncacheable():
    fab = canal.compile(InterconnectSpec(**SMOKE),
                        core_fn=lambda x, y, w, h: None)
    assert not fab.cacheable
    assert canal.compile(InterconnectSpec(**SMOKE)).cacheable


# ---------------------------------------------------------------------------
# Executor spec-digest caching
# ---------------------------------------------------------------------------

def test_executor_key_canonicalization():
    from repro.core.dse import SweepExecutor

    kw = dict(SMOKE)
    spec = InterconnectSpec(**kw)
    assert SweepExecutor._key(kw) == SweepExecutor._key(spec)
    assert SweepExecutor._key(kw) == ("spec", spec.digest())
    # spellings that used to produce distinct raw-kwargs keys now collapse
    assert SweepExecutor._key(dict(kw, sb_type="wilton")) == \
        SweepExecutor._key(dict(kw, sb_type=SwitchBoxType.WILTON))


def test_executor_key_rejects_callables_with_clear_error():
    from repro.core.dse import SweepExecutor

    with pytest.raises(TypeError, match="callable"):
        SweepExecutor._key(dict(width=4, core_fn=lambda *a: None))


def test_executor_caches_hit_across_spellings():
    from repro.core.dse import SweepExecutor

    ex = SweepExecutor(apps={}, emulate_cycles=0)
    ic1 = ex.interconnect(**SMOKE)
    ic2 = ex.interconnect(InterconnectSpec(**SMOKE))
    ic3 = ex.interconnect(**dict(SMOKE, sb_type="wilton"))
    assert ic1 is ic2 is ic3


def test_executor_caches_shared_across_execution_knobs():
    """Points differing only in execution knobs (router strategy etc.)
    compile to the same hardware: the IR cache must not split."""
    from repro.core.dse import SweepExecutor

    spec = InterconnectSpec(**SMOKE)
    py = spec.replace(route_strategy="python")
    mp = spec.replace(route_strategy="minplus", emulate_io_chunk=4)
    assert py.digest() != mp.digest()                # records distinguish
    assert py.hardware_digest() == mp.hardware_digest() == spec.digest()
    ex = SweepExecutor(apps={}, emulate_cycles=0)
    ic = ex.interconnect(py)
    assert ic is ex.interconnect(mp)
    # the shared IR's stamped identity is the hardware's, not whichever
    # knob variant happened to compile it first
    assert ic.params["spec_digest"] == spec.hardware_digest()
    assert ic.spec == spec.hardware_spec()


def test_run_point_spec_equals_kwargs():
    """One design point through the spec path and the legacy kwargs path:
    identical deterministic record fields and shared caches."""
    from repro.core.dse import SweepExecutor
    from repro.core.pnr.app import app_pointwise

    kw = dict(width=6, height=6, num_tracks=4, io_ring=True,
              reg_density=1.0)
    ex = SweepExecutor(apps={"pw": lambda: app_pointwise(1)}, sa_steps=20,
                       sa_batch=8, emulate_cycles=6, use_pallas=False,
                       max_workers=1)
    rec_kw = ex.run_point(kw, {"tag": 1})
    rec_spec = ex.run_point(InterconnectSpec(**kw), {"tag": 1})
    assert len(ex._ic_cache) == 1                    # one shared entry
    assert rec_kw["spec_digest"] == rec_spec["spec_digest"]
    for f in ("success", "critical_path_ns", "wirelength",
              "route_iterations", "route_strategy"):
        assert rec_kw["apps"]["pw"][f] == rec_spec["apps"]["pw"][f]
    assert rec_kw["apps"]["pw"]["emulation"]["out_checksum"] == \
        rec_spec["apps"]["pw"]["emulation"]["out_checksum"]
    assert rec_kw["sb_area"] == rec_spec["sb_area"]


# ---------------------------------------------------------------------------
# Route-strategy knob (auto threshold)
# ---------------------------------------------------------------------------

def test_auto_min_tiles_env_and_spec_override(monkeypatch):
    from repro.core.pnr.route import auto_min_tiles_threshold

    monkeypatch.delenv("CANAL_AUTO_MIN_TILES", raising=False)
    assert auto_min_tiles_threshold() == 49
    monkeypatch.setenv("CANAL_AUTO_MIN_TILES", "12")
    assert auto_min_tiles_threshold() == 12
    assert auto_min_tiles_threshold(override=7) == 7
    monkeypatch.setenv("CANAL_AUTO_MIN_TILES", "not-a-number")
    assert auto_min_tiles_threshold() == 49


def test_auto_strategy_resolved_and_recorded():
    """With strategy "auto" the resolved engine lands on the PnR result:
    a 4x4 (16 tiles) resolves to python at the default threshold and to
    minplus when the spec lowers it below 16."""
    from repro.core.pnr.app import app_pointwise

    app = app_pointwise(1)
    fab = canal.compile(InterconnectSpec(route_strategy="auto", **SMOKE))
    r = fab.place_and_route(app, alphas=(2.0,), sa_steps=20, sa_batch=8)
    assert r.success and r.route_strategy == "python"

    low = canal.compile(InterconnectSpec(route_strategy="auto",
                                         auto_min_tiles=4, **SMOKE))
    r2 = low.place_and_route(app, alphas=(2.0,), sa_steps=20, sa_batch=8)
    assert r2.success and r2.route_strategy == "minplus"
    assert r2.timing["critical_path_ns"] == \
        pytest.approx(r.timing["critical_path_ns"], rel=0.10)
