"""Differential harness for the fabric emulators: the fused batched
engine must be bit-identical to the serial per-config reference.

Hypothesis-driven (through ``tests/_hypothesis_compat``): random
interconnect geometries, random (often combinationally-cyclic) configs,
random PE programs and stream lengths, checked on both the vmap oracle
path (``use_pallas=False``) and the Pallas interpret path
(``use_pallas=True``), and — in a subprocess with forced host devices —
on the shard_map multi-device path."""
import functools
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.edsl import create_uniform_interconnect
from repro.core.lowering import compile_interconnect

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src")


@functools.lru_cache(maxsize=None)
def _ic(width, height, num_tracks):
    return create_uniform_interconnect(width=width, height=height,
                                       num_tracks=num_tracks,
                                       sb_type="wilton", io_ring=True,
                                       reg_density=1.0)


@functools.lru_cache(maxsize=None)
def _fabric(width, height, num_tracks, use_pallas):
    return compile_interconnect(_ic(width, height, num_tracks),
                                use_pallas=use_pallas)


def _random_workload(fab, rng, batch, cycles):
    """Random configs (legal and cycle-wiring alike), IO streams and PE
    programs — the full surface run/run_batch must agree on."""
    cfgs = rng.integers(0, 4, (batch, fab.num_config)).astype(np.int32)
    ext = rng.integers(0, 2000, (batch, cycles, fab.num_io)) \
             .astype(np.int32)
    n = max(fab.num_pe, 1)
    pe_cfgs = {
        "op": rng.integers(0, 14, (batch, n)).astype(np.int32),
        "const": rng.integers(0, 0xFFFF, (batch, n)).astype(np.int32),
        "imm_mask": (rng.random((batch, n, 4)) < 0.2).astype(np.int32),
        "imm_val": rng.integers(0, 0xFFFF, (batch, n, 4))
                      .astype(np.int32),
    }
    return cfgs, ext, pe_cfgs


@pytest.mark.parametrize("use_pallas", [False, True])
@given(st.integers(3, 4), st.integers(1, 4), st.sampled_from([3, 5, 7]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_run_batch_bit_identical_to_serial(use_pallas, size, batch,
                                           cycles, seed):
    """run_batch (fused and unfused) == per-config run, lane for lane,
    with per-config combinational depths — even for random configs whose
    active network is cyclic, thanks to masked early exit."""
    fab = _fabric(size, size, 2, use_pallas)
    rng = np.random.default_rng(seed)
    cfgs, ext, pe_cfgs = _random_workload(fab, rng, batch, cycles)
    serial = np.stack([
        np.asarray(fab.run(
            jnp.asarray(cfgs[i]), jnp.asarray(ext[i]),
            pe_cfg={k: jnp.asarray(v[i]) for k, v in pe_cfgs.items()}))
        for i in range(batch)])
    for fused in (True, False):
        batched = np.asarray(fab.run_batch(
            jnp.asarray(cfgs), jnp.asarray(ext),
            pe_cfgs={k: jnp.asarray(v) for k, v in pe_cfgs.items()},
            fused=fused))
        np.testing.assert_array_equal(
            serial, batched,
            err_msg=f"use_pallas={use_pallas} fused={fused} seed={seed}")


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_pallas_and_vmap_paths_agree(seed):
    """The Pallas-interpret engine and the pure-jnp oracle engine produce
    the same observations for the same workload."""
    rng = np.random.default_rng(seed)
    batch, cycles = 3, 5
    fab_ref = _fabric(4, 4, 2, False)
    fab_pal = _fabric(4, 4, 2, True)
    cfgs, ext, pe_cfgs = _random_workload(fab_ref, rng, batch, cycles)
    kw = dict(pe_cfgs={k: jnp.asarray(v) for k, v in pe_cfgs.items()})
    a = np.asarray(fab_ref.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                     **kw))
    b = np.asarray(fab_pal.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                     **kw))
    np.testing.assert_array_equal(a, b, err_msg=f"seed={seed}")


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_stream_length_invariance(prefix, seed):
    """Emulating T cycles then truncating == emulating the first T' < T
    cycles directly: the scan carries no hidden cross-cycle coupling."""
    fab = _fabric(4, 4, 2, False)
    rng = np.random.default_rng(seed)
    cycles = 8
    cfgs, ext, pe_cfgs = _random_workload(fab, rng, 2, cycles)
    t_cut = 1 + prefix % (cycles - 1)
    kw = dict(pe_cfgs={k: jnp.asarray(v) for k, v in pe_cfgs.items()})
    full = np.asarray(fab.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                    **kw))
    short = np.asarray(fab.run_batch(jnp.asarray(cfgs),
                                     jnp.asarray(ext[:, :t_cut]), **kw))
    np.testing.assert_array_equal(full[:, :t_cut], short,
                                  err_msg=f"t_cut={t_cut} seed={seed}")


def test_per_lane_depth_equals_per_config_runs():
    """Explicit heterogeneous depths: lane i must behave exactly like a
    serial run at depth_i, not at the batch max."""
    fab = _fabric(4, 4, 2, False)
    rng = np.random.default_rng(7)
    cfgs, ext, pe_cfgs = _random_workload(fab, rng, 4, 5)
    depths = np.array([2, 5, 9, 3], np.int32)
    batched = np.asarray(fab.run_batch(
        jnp.asarray(cfgs), jnp.asarray(ext),
        pe_cfgs={k: jnp.asarray(v) for k, v in pe_cfgs.items()},
        depth=depths))
    serial = np.stack([
        np.asarray(fab.run(
            jnp.asarray(cfgs[i]), jnp.asarray(ext[i]),
            pe_cfg={k: jnp.asarray(v[i]) for k, v in pe_cfgs.items()},
            depth=int(depths[i])))
        for i in range(4)])
    np.testing.assert_array_equal(serial, batched)


def test_sharded_run_batch_matches_single_device():
    """shard_map over forced host devices == the single-device engine,
    including a batch that does not divide the device count (padding)."""
    code = (
        "import numpy as np, jax, jax.numpy as jnp\n"
        "from repro.core.edsl import create_uniform_interconnect\n"
        "from repro.core.lowering import compile_interconnect\n"
        "assert len(jax.devices()) == 4, jax.devices()\n"
        "ic = create_uniform_interconnect(width=3, height=3,"
        " num_tracks=2, sb_type='wilton', io_ring=True, reg_density=1.0)\n"
        "fab = compile_interconnect(ic, use_pallas=False)\n"
        "rng = np.random.default_rng(0)\n"
        "cfgs = rng.integers(0, 4, (6, fab.num_config)).astype(np.int32)\n"
        "ext = rng.integers(0, 999, (6, 4, fab.num_io)).astype(np.int32)\n"
        "one = np.asarray(fab.run_batch(jnp.asarray(cfgs),"
        " jnp.asarray(ext), shard=False))\n"
        "many = np.asarray(fab.run_batch(jnp.asarray(cfgs),"
        " jnp.asarray(ext), shard=True))\n"
        "assert np.array_equal(one, many)\n"
        "print('SHARDED_OK')\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = SRC_ROOT + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in out.stdout, out.stderr[-2000:]
