"""Routed-design static analysis (ISSUE 9).

Four layers of coverage:

* **clean property** — every routed bench app on the reference fabrics
  (and every golden config) analyzes clean at ``scope="routed"``: zero
  findings, success is silent;
* **seeded violations** — a hand-routed FIFO ring the ``rv-deadlock``
  rule flags *and* the ready-valid emulator confirms stalls (the
  acceptance link between the static verdict and fabric behavior), a
  register-free ring (hard error), a corrupted route tree
  (``x-propagation``), node overuse (``congestion-hotspot``), and a
  tight clock (``sta-slack``);
* **bound validity** — ``throughput-bound``'s static II is a true lower
  bound on every bench app and errors when an emulated II contradicts
  it;
* **integration** — the executor stamps static metrics + rule-set
  version into store records (stale stamps force re-analysis), the
  Pareto layer consumes them without extra PnR, and the lint CLI's
  ``--routed`` / ``--store`` paths audit live designs and persisted
  verdicts.
"""
import json

import jax.numpy as jnp
import pytest

import canal
from repro.core.analysis import (DEFAULT_CLOCK_NS, analyze,
                                 routed_static_metrics, rule_set_version,
                                 rule_table)
from repro.core.analysis.diagnostics import (AnalysisReport, Diagnostic,
                                             Severity)
from repro.core.analysis.flow import build_channel_graph
from repro.core.analysis.lint import run as lint_run
from repro.core.analysis.routed import static_ii_bound
from repro.core.dse import SweepExecutor
from repro.core.graph import NodeKind
from repro.core.pnr.app import AppGraph, BENCH_APPS, app_pointwise
from repro.core.pnr.packing import PackedGraph
from repro.core.pnr.route import RoutedNet, RoutingResult
from repro.core.search.pareto import (dominates, objective_value,
                                      point_metrics, satisfies)
from repro.core.spec import InterconnectSpec
from repro.core.store import ResultStore, record_metrics

ROUTED_RULES = {"rv-deadlock", "throughput-bound", "sta-slack",
                "congestion-hotspot", "x-propagation"}

#: reference fabric that routes every bench app (stencil needs a mem
#: column; tree_reduce needs the 8x8 PE count)
BENCH8 = dict(width=8, height=8, num_tracks=4, io_ring=True,
              reg_density=1.0, mem_columns=(2,))


@pytest.fixture(scope="module")
def fab8():
    return canal.compile(InterconnectSpec(**BENCH8))


@pytest.fixture(scope="module")
def routed8(fab8):
    r = fab8.place_and_route(BENCH_APPS["pointwise"]())
    assert r.success, r.error
    return r


@pytest.fixture(scope="module")
def rv_fab():
    # the pass pipeline (readyvalid_transform) is what tags rv_fifo
    # registers and stamps ic.params["rv_fifo_mode"]; default mode is
    # "full" (depth-2 FIFOs)
    return canal.compile(InterconnectSpec(width=4, height=4, num_tracks=2,
                                          io_ring=True, reg_density=1.0,
                                          ready_valid=True))


# ---------------------------------------------------------------------------
# registry and clean property
# ---------------------------------------------------------------------------

def test_routed_rules_registered():
    table = {r.name: r for r in rule_table(scope="routed")}
    assert set(table) == ROUTED_RULES
    assert table["throughput-bound"].default_severity == Severity.WARNING
    assert table["rv-deadlock"].default_severity == Severity.ERROR
    # the version hash is deterministic and scope-sensitive
    assert rule_set_version() == rule_set_version()
    assert rule_set_version() != rule_set_version(scope="routed")


def test_place_and_route_attaches_clean_routed_report(routed8):
    rep = routed8.analysis
    assert rep is not None
    assert ROUTED_RULES <= set(rep.rules_run)
    assert len(rep) == 0          # clean routed designs are silent


def test_golden_configs_analyze_clean_routed():
    from test_spec_golden import GOLDEN_SPECS, IR_BUILT
    for name in IR_BUILT:
        fab = canal.compile(GOLDEN_SPECS[name])
        r = fab.place_and_route(app_pointwise(2))
        assert r.success, (name, r.error)
        assert len(r.analysis) == 0, (name, r.analysis.render())


# ---------------------------------------------------------------------------
# throughput-bound: a valid lower bound on every bench app
# ---------------------------------------------------------------------------

def test_throughput_bound_valid_on_every_bench_app(fab8, routed8):
    for name, factory in sorted(BENCH_APPS.items()):
        r = routed8 if name == "pointwise" \
            else fab8.place_and_route(factory())
        assert r.success, (name, r.error)
        # the bench apps are feed-forward DAGs: the channel dependency
        # graph is acyclic and the static bound is the fully-pipelined
        # II = 1.0. Emulated II is >= 1 cycle/token by definition, so
        # static <= emulated holds for every app.
        assert static_ii_bound(r.packed, r.routing) == 1.0, name
        m = routed_static_metrics(r.packed, r.routing, r.placement)
        assert m["static_ii"] == 1.0 and m["throughput"] == 1.0
        assert 0.0 < m["min_slack_ns"] < DEFAULT_CLOCK_NS


def test_throughput_bound_emulated_crosscheck(fab8, routed8):
    def rep(emulated):
        return analyze(fab8.interconnect, spec=fab8.spec, scope="routed",
                       rules=["throughput-bound"], packed=routed8.packed,
                       routing=routed8.routing,
                       timing={"emulated_ii": emulated})
    # an emulated II above the static bound is consistent: silent
    assert len(rep(4.0)) == 0
    # an emulated II *below* the bound means the "lower bound" is not
    # one — the analyzer must flag its own model as wrong
    bad = rep(0.25)
    assert bad.errors and "lower bound" in bad.errors[0].message


# ---------------------------------------------------------------------------
# sta-slack: target-clock gating
# ---------------------------------------------------------------------------

def test_sta_slack_clock_target(fab8, routed8):
    # no target clock: no period to violate, the rule stays silent
    assert len(fab8.analyze(scope="routed", rules=["sta-slack"],
                            pnr=routed8)) == 0
    tight = fab8.analyze(scope="routed", rules=["sta-slack"], pnr=routed8,
                         clock_ns=0.1)
    assert tight.errors and not tight.ok()
    assert "slack" in tight.errors[0].message
    # generous clock: every net has > 10% margin
    assert len(fab8.analyze(scope="routed", rules=["sta-slack"],
                            pnr=routed8, clock_ns=1000.0)) == 0


# ---------------------------------------------------------------------------
# seeded rings: the deadlock verdict, confirmed by the RV emulator
# ---------------------------------------------------------------------------

def _find_cycle(res, starts, allow):
    """Shortest physical cycle ``[n0, ..., nk]`` (edges n_i -> n_{i+1},
    nk -> n0) through one of ``starts``, visiting only ``allow``-ed
    node ids — BFS over the fine-graph fan-out."""
    from collections import deque
    nid = res.node_id
    for start in starts:
        parent = {start: None}
        q = deque([start])
        while q:
            u = q.popleft()
            for dst in res.nodes[u].fan_out:
                v = nid[dst]
                if v == start:
                    path = [u]
                    while parent[path[-1]] is not None:
                        path.append(parent[path[-1]])
                    return list(reversed(path))
                if v not in parent and allow(v):
                    parent[v] = u
                    q.append(v)
    raise AssertionError("no cycle found in the fine graph")


def _ring_artifacts(res, ring):
    """A hand-routed net that configures ``ring`` as a closed loop, plus
    the minimal PackedGraph the routed rules need."""
    n = len(ring)
    tree = {ring[(i + 1) % n]: ring[i] for i in range(n)}
    rnet = RoutedNet(name="ring", src=ring[0], sinks=[ring[-1]],
                     tree=tree)
    routing = RoutingResult(nets=[rnet], iterations=1, overuse_history=[],
                            resources=res, strategy="manual")
    return PackedGraph(app=AppGraph()), routing


def _rv_fifo_ids(res):
    return [i for i, nd in enumerate(res.nodes)
            if nd.kind == NodeKind.REGISTER
            and nd.attributes.get("rv_fifo")]


def test_rv_deadlock_buffered_ring_flagged_and_emulation_stalls(rv_fab):
    res = rv_fab.resources()
    ring = _find_cycle(res, _rv_fifo_ids(res), lambda v: True)
    fifo_ids = [i for i in ring if i in set(_rv_fifo_ids(res))]
    assert fifo_ids        # the ring passes through >= 1 FIFO stage
    packed, routing = _ring_artifacts(res, ring)

    report = analyze(rv_fab.interconnect, scope="routed",
                     rules=["rv-deadlock"], packed=packed, routing=routing)
    found = report.by_rule("rv-deadlock")
    assert len(found) == 1 and found[0].severity == Severity.WARNING
    assert "FIFO-constrained" in found[0].message
    # the same graph yields the finite (capacity-limited) static II
    cdg = build_channel_graph(packed, routing)
    assert cdg.static_ii() < float("inf")

    # --- emulation confirms the verdict: preload the ring FIFOs to
    # capacity (the trapped-token condition the warning names) and the
    # fabric freezes — full-mode ready is occ < 2, so every stage's
    # pop waits on the next stage's ready, which is 0 forever
    fab = rv_fab.fabric()
    assert fab.fifo_depth == 2
    config = jnp.asarray(fab.route_to_config(
        [(res.nodes[p], res.nodes[c])
         for c, p in routing.nets[0].tree.items()]))
    state = fab.init_state()
    slots = [int(fab.reg_slot[fab.node_id[res.nodes[i]]])
             for i in fifo_ids]
    assert all(s >= 0 for s in slots)
    for s in slots:
        state["occ"] = state["occ"].at[s].set(fab.fifo_depth)
        state["slots"] = state["slots"].at[s].set(
            jnp.full((2,), 7, jnp.int32))
    zeros = jnp.zeros(fab.num_io, jnp.int32)
    for _ in range(5):
        state, _ = fab.step(state, zeros, zeros, config)
        for s in slots:
            assert int(state["occ"][s]) == fab.fifo_depth, \
                "a full FIFO ring drained: the deadlock verdict is wrong"


def test_rv_deadlock_unbuffered_ring_is_error(rv_fab):
    res = rv_fab.resources()
    not_reg = [i for i, nd in enumerate(res.nodes)
               if nd.kind != NodeKind.REGISTER]
    ring = _find_cycle(res, not_reg[:64],
                       lambda v: res.nodes[v].kind != NodeKind.REGISTER)
    packed, routing = _ring_artifacts(res, ring)
    report = analyze(rv_fab.interconnect, scope="routed",
                     packed=packed, routing=routing,
                     rules=["rv-deadlock", "throughput-bound"])
    dead = report.by_rule("rv-deadlock")
    assert dead and dead[0].severity == Severity.ERROR
    assert "no FIFO stage" in dead[0].message
    # no steady state at all: the static II bound is infinite and
    # throughput-bound escalates
    assert build_channel_graph(packed, routing).static_ii() == float("inf")
    tp = report.by_rule("throughput-bound")
    assert tp and tp[0].severity == Severity.ERROR


# ---------------------------------------------------------------------------
# x-propagation and congestion-hotspot: seeded routed violations
# ---------------------------------------------------------------------------

def test_x_propagation_corrupted_tree(fab8, routed8):
    net = next(n for n in routed8.routing.nets if n.tree)
    child = sorted(net.tree)[0]
    res = routed8.routing.resources
    # point the child's configured driver at a node that is not a
    # physical fan-in (pick one guaranteed foreign: the child itself)
    bad_tree = dict(net.tree)
    bad_tree[child] = child
    bad_net = RoutedNet(name=net.name, src=net.src, sinks=list(net.sinks),
                        tree=bad_tree)
    routing = RoutingResult(nets=[bad_net], iterations=1,
                            overuse_history=[], resources=res,
                            strategy="manual")
    report = analyze(fab8.interconnect, scope="routed",
                     rules=["x-propagation"], packed=routed8.packed,
                     routing=routing)
    assert report.errors
    msgs = " ".join(d.message for d in report.errors)
    assert "not a physical fan-in" in msgs or "never reaches" in msgs
    # the pristine routing is clean
    clean = analyze(fab8.interconnect, scope="routed",
                    rules=["x-propagation"], packed=routed8.packed,
                    routing=routed8.routing)
    assert len(clean) == 0


def test_congestion_hotspot_flags_overuse(fab8, routed8):
    net = next(n for n in routed8.routing.nets if n.tree)
    dup = RoutedNet(name=net.name + "__dup", src=net.src,
                    sinks=list(net.sinks), tree=dict(net.tree))
    routing = RoutingResult(nets=[net, dup], iterations=1,
                            overuse_history=[],
                            resources=routed8.routing.resources,
                            strategy="manual")
    report = analyze(fab8.interconnect, scope="routed",
                     rules=["congestion-hotspot"], packed=routed8.packed,
                     routing=routing)
    assert report.errors
    assert "used by 2 nets" in report.errors[0].message


# ---------------------------------------------------------------------------
# report plumbing: truncation keeps the most severe, "off" suppresses
# ---------------------------------------------------------------------------

def test_report_truncation_keeps_most_severe():
    diags = [Diagnostic("r", Severity.INFO, f"i{k}") for k in range(3)] \
        + [Diagnostic("r", Severity.ERROR, "e0"),
           Diagnostic("r", Severity.WARNING, "w0"),
           Diagnostic("r", Severity.ERROR, "e1")]
    rep = AnalysisReport(diagnostics=diags, rules_run=("r",))
    doc = rep.to_dict(max_diagnostics=3)
    assert doc["truncated"] == 3
    kept = [(d["severity"], d["message"]) for d in doc["diagnostics"]]
    assert [s for s, _ in kept] == ["error", "error", "warning"]
    # under the cap: no truncation marker at all
    assert "truncated" not in rep.to_dict(max_diagnostics=6)
    assert rep.to_dict()["counts"] == {"error": 2, "warning": 1, "info": 3}


def test_severity_remap_unknown_rule_rejected(fab8):
    with pytest.raises(ValueError, match="unknown"):
        fab8.analyze(severities={"no-such-rule": "info"})


def test_severity_off_suppresses_rule(fab8, routed8):
    # sta-slack at a violating clock, suppressed via "off": no findings
    # and the rule is excluded from rules_run (it never ran)
    loud = fab8.analyze(scope="routed", rules=["sta-slack"], pnr=routed8,
                        clock_ns=0.1)
    assert loud.errors
    off = fab8.analyze(scope="routed", rules=["sta-slack"], pnr=routed8,
                       clock_ns=0.1, severities={"sta-slack": "off"})
    assert len(off) == 0 and "sta-slack" not in off.rules_run


# ---------------------------------------------------------------------------
# store / search wiring: stamps, staleness, and optional metrics
# ---------------------------------------------------------------------------

STOCK = dict(width=4, height=4, num_tracks=2, io_ring=True,
             reg_density=1.0)


def test_executor_stamps_static_metrics_and_rule_set(tmp_path):
    apps = {"pw": lambda: app_pointwise(2)}
    spec = InterconnectSpec(**STOCK)
    ex = SweepExecutor(apps=apps, store=str(tmp_path))
    rec = ex.run_point(spec)
    assert rec["analysis"]["rule_set"] == rule_set_version()
    entry = rec["apps"]["pw"]
    assert entry["success"] and entry["static_ii"] == 1.0
    assert entry["routed_analysis"]["clean"] is True
    # the record-level metrics gain the routed pair...
    m = record_metrics(rec)
    assert m["throughput"] == 1.0 and "min_slack_ns" in m
    # ...and the Pareto layer consumes them with no extra PnR
    pm = point_metrics(rec)
    assert pm["throughput"] == 1.0
    assert satisfies(pm, {"min_throughput": 0.5})
    assert not satisfies(pm, {"min_slack_ns": 1e9})


def test_stale_rule_set_stamp_forces_reanalysis(tmp_path):
    apps = {"pw": lambda: app_pointwise(2)}
    spec = InterconnectSpec(**STOCK)
    ex = SweepExecutor(apps=apps, store=str(tmp_path))
    rec = ex.run_point(spec)
    assert ex.pnr_computations == 1

    # synthetically downgrade the stamp: the record now claims it was
    # analyzed under an older rule set
    stale = json.loads(json.dumps(rec))
    stale["analysis"]["rule_set"] = "deadbeefcafe"
    # records live under the executor-resolved digest (PnR knobs pinned)
    ResultStore(str(tmp_path)).put(ex.resolve(spec), stale, merge=False)

    ex2 = SweepExecutor(apps=apps, store=str(tmp_path))
    rec2 = ex2.run_point(spec)
    assert ex2.stale_rule_set == 1 and ex2.store_hits == 0
    assert ex2.pnr_computations == 1          # recomputed, not served
    assert rec2["analysis"]["rule_set"] == rule_set_version()
    assert ex2.stats()["stale_rule_set"] == 1

    # current stamps round-trip as plain hits
    ex3 = SweepExecutor(apps=apps, store=str(tmp_path))
    ex3.run_point(spec)
    assert ex3.store_hits == 1 and ex3.pnr_computations == 0


def test_optional_metrics_never_poison_legacy_records():
    legacy = {"apps": {"pw": {"success": True,
                              "critical_path_ns": 3.0}},
              "sb_area": 10.0, "cb_area": 2.0}
    m = record_metrics(legacy)
    assert set(m) == {"area", "critical_path_ns", "routability"}
    stamped = dict(legacy)
    stamped["apps"] = {"pw": {"success": True, "critical_path_ns": 3.0,
                              "static_ii": 1.0, "min_slack_ns": 7.0}}
    s = record_metrics(stamped)
    assert s["throughput"] == 1.0 and s["min_slack_ns"] == 7.0
    # dominance only compares shared keys: the legacy point ties the
    # stamped one on the core triple and is not disqualified by the
    # metrics it never measured
    assert not dominates(s, m) and not dominates(m, s)
    assert objective_value(m, "throughput") == 0.0   # pessimistic default
    with pytest.raises(ValueError, match="unknown constraint"):
        satisfies(m, {"max_tracks": 2})


# ---------------------------------------------------------------------------
# lint CLI: rule table columns, --routed, and the --store audit
# ---------------------------------------------------------------------------

def test_lint_list_rules_has_scope_and_severity_columns(capsys):
    assert lint_run(["--list-rules"]) == 0
    out = capsys.readouterr().out
    header = out.splitlines()[0]
    for col in ("RULE", "SCOPE", "SEVERITY", "DESCRIPTION"):
        assert col in header
    row = next(ln for ln in out.splitlines() if ln.startswith("rv-deadlock"))
    assert "routed" in row and "error" in row
    row = next(ln for ln in out.splitlines()
               if ln.startswith("throughput-bound"))
    assert "warning" in row


def test_lint_fail_on_help_names_severities():
    from repro.core.analysis.lint import build_parser
    help_text = build_parser().format_help()
    for word in ("info", "warning", "error"):
        assert word in help_text


def test_lint_routed_spec_target(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(InterconnectSpec(**STOCK).to_json())
    art = tmp_path / "routed.json"
    # pointwise(6) does not fit a 4x4: the routed lint degrades to a
    # routed-verdict warning, which does not fail at the default
    # --fail-on error
    assert lint_run([str(good), "--routed", "--format", "json",
                     "-o", str(art)]) == 0
    doc = json.loads(art.read_text())
    target = doc["targets"][str(good)]
    assert ROUTED_RULES <= set(target["rules_run"])
    assert lint_run([str(good), "--routed", "--app", "nope"]) == 2


def test_lint_store_audit(tmp_path, capsys):
    store_root = tmp_path / "store"
    apps = {"pw": lambda: app_pointwise(2)}
    spec = InterconnectSpec(**STOCK)
    ex = SweepExecutor(apps=apps, store=str(store_root))
    rec = ex.run_point(spec)

    art = tmp_path / "audit.json"
    assert lint_run(["--store", str(store_root), "--routed",
                     "--format", "json", "-o", str(art)]) == 0
    doc = json.loads(art.read_text())
    origin = f"store:{ex.resolve(spec).digest()[:12]}"
    assert doc["targets"][origin]["clean"] is True

    # a stale stamp surfaces as a finding and fails at --fail-on warning
    stale = json.loads(json.dumps(rec))
    stale["analysis"]["rule_set"] = "deadbeefcafe"
    ResultStore(str(store_root)).put(ex.resolve(spec), stale, merge=False)
    assert lint_run(["--store", str(store_root)]) == 0
    assert lint_run(["--store", str(store_root),
                     "--fail-on", "warning"]) == 1
    capsys.readouterr()
    # an empty store is a usage error, not "clean"
    assert lint_run(["--store", str(tmp_path / "empty")]) == 2
