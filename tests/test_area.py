"""PPA model calibration (Figs. 8, 10, 13)."""
import pytest

from repro.core.area import (connection_box_area, interconnect_area,
                             mux_area, rv_mux_overhead, switch_box_area)
from repro.core.edsl import SwitchBoxType, create_uniform_interconnect


@pytest.fixture(scope="module")
def paper_baseline():
    """5 16-bit tracks, PE with 4 in / 2 out (paper §4.1)."""
    return create_uniform_interconnect(width=8, height=8, num_tracks=5,
                                       track_width=16, reg_density=1.0)


def test_fig8_fifo_ratios(paper_baseline):
    base = switch_box_area(paper_baseline)
    full = switch_box_area(paper_baseline, rv="full")
    split = switch_box_area(paper_baseline, rv="split")
    assert abs(full / base - 1.54) < 0.03
    assert abs(split / base - 1.32) < 0.03


def test_onehot_join_cheaper_than_lut():
    """Fig. 5's point: reusing the AOI mux one-hot beats a LUT join."""
    assert rv_mux_overhead(5, use_lut=True) > 2 * rv_mux_overhead(5)


def test_fig10_area_scales_with_tracks():
    sb, cb = [], []
    for t in (2, 4, 6, 8):
        ic = create_uniform_interconnect(width=6, height=6, num_tracks=t,
                                         reg_density=1.0)
        sb.append(switch_box_area(ic))
        cb.append(connection_box_area(ic))
    assert all(b > a for a, b in zip(sb, sb[1:]))
    assert all(b > a for a, b in zip(cb, cb[1:]))
    # near-linear: tripling tracks less than ~3.5x's area
    assert sb[2] / sb[0] < 3.5 and cb[2] / cb[0] < 3.5


def test_fig13_depopulation_shrinks_boxes():
    full = create_uniform_interconnect(width=6, height=6, num_tracks=5)
    sb2 = create_uniform_interconnect(width=6, height=6, num_tracks=5,
                                      sb_sides=2)
    cb2 = create_uniform_interconnect(width=6, height=6, num_tracks=5,
                                      cb_sides=2)
    assert switch_box_area(sb2) < switch_box_area(full)
    assert connection_box_area(cb2) < connection_box_area(full)
    # CB shrinks relatively more (paper)
    sb_drop = 1 - switch_box_area(sb2) / switch_box_area(full)
    cb_drop = 1 - connection_box_area(cb2) / connection_box_area(full)
    assert cb_drop > sb_drop


def test_topology_area_equal():
    """Wilton and Disjoint have the same area (§4.2.1)."""
    a = {}
    for topo in (SwitchBoxType.WILTON, SwitchBoxType.DISJOINT):
        ic = create_uniform_interconnect(width=6, height=6, num_tracks=5,
                                         sb_type=topo)
        a[topo] = switch_box_area(ic)
    assert abs(a[SwitchBoxType.WILTON] - a[SwitchBoxType.DISJOINT]) < 1e-9


def test_whole_array_accounting(paper_baseline):
    tot = interconnect_area(paper_baseline)
    assert tot["total"] == pytest.approx(tot["sb"] + tot["cb"]
                                         + tot["fifo"])
    assert tot["total"] > 64 * 1000      # 8x8 tiles, ~1.4k um2 SB each


def test_mux_area_monotone():
    assert mux_area(2, 16) < mux_area(4, 16) < mux_area(8, 16)
    assert mux_area(4, 1) < mux_area(4, 16)
    assert mux_area(1, 16) == 0.0
