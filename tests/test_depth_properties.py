"""Property tests for the computed combinational depths.

``combinational_depth`` (generic, whole configured network) must cover
``depth_for_route`` (routed tree only) so that a route's config always
gets enough fixpoint sweeps, and its cycle guard must terminate with a
sane bound on adversarial configs that wire combinational loops."""
import functools

import numpy as np
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.edsl import create_uniform_interconnect
from repro.core.lowering import compile_interconnect
from test_lowering_fabric import manual_east_route


@functools.lru_cache(maxsize=None)
def _setup(width=4, height=4, num_tracks=2):
    ic = create_uniform_interconnect(width=width, height=height,
                                     num_tracks=num_tracks,
                                     sb_type="wilton", io_ring=True,
                                     reg_density=1.0)
    return ic, compile_interconnect(ic)


@given(st.integers(1, 2), st.integers(0, 1), st.sampled_from([4, 5]))
@settings(max_examples=8, deadline=None)
def test_combinational_depth_covers_routed_tree(y, track, size):
    """The generic per-config depth is at least the routed tree's chain
    length (equal margins): the sweeps a route needs are always granted."""
    ic, fab = _setup(size, size)
    edges = manual_east_route(ic, y=y, track=track)
    cfg = fab.route_to_config(edges)
    assert fab.combinational_depth(cfg) >= fab.depth_for_route(edges,
                                                               margin=1)


@given(st.integers(1, 2), st.integers(0, 1))
@settings(max_examples=4, deadline=None)
def test_route_config_depth_sufficient_for_fixpoint(y, track):
    """Emulating with the computed per-config depth reproduces the
    fixpoint a generous fixed bound reaches (legal routes are acyclic)."""
    ic, fab = _setup()
    edges = manual_east_route(ic, y=y, track=track)
    cfg = jnp.asarray(fab.route_to_config(edges))
    ext = jnp.asarray(np.arange(1, 5 * fab.num_io + 1, dtype=np.int32)
                      .reshape(5, fab.num_io))
    auto = np.asarray(fab.run(cfg, ext))
    fixed = np.asarray(fab.run(cfg, ext, depth=64))
    np.testing.assert_array_equal(auto, fixed)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_cycle_guard_terminates_on_adversarial_configs(seed):
    """Random configs can wire combinational loops (no fixpoint): the
    cycle guard must still terminate and report a positive, bounded
    sweep count instead of diverging."""
    _, fab = _setup()
    rng = np.random.default_rng(seed)
    cfg = rng.integers(0, 8, fab.num_config).astype(np.int32)
    d = fab.combinational_depth(cfg)
    assert 1 <= d <= fab.arrays.num_nodes + 2


def test_cycle_guard_excludes_unstable_portion():
    """The all-zeros default config on this fabric contains register-
    bypass loops; the guard reports the stable portion's depth, which a
    legal route's depth then dominates."""
    ic, fab = _setup()
    zero = fab.combinational_depth(np.zeros(fab.num_config, np.int32))
    assert zero >= 1
    edges = manual_east_route(ic)
    routed = fab.combinational_depth(fab.route_to_config(edges))
    assert routed >= fab.depth_for_route(edges, margin=1)


def test_depth_for_route_cycle_fallback():
    """A route that feeds a PE its own output has no finite chain: the
    conservative ``len(edges) + 4`` fallback bound must kick in."""
    ic, fab = _setup()
    g = ic.graph(16)
    x, y = fab.pe_coords[0]
    res0 = g.get_port(x, y, "res0")
    data0 = g.get_port(x, y, "data0")
    # res0 -> data0 route edge + the implicit weight-0 PE hop
    # data0 -> res0 closes a combinational loop
    edges = [(res0, data0)]
    assert fab.depth_for_route(edges) == len(edges) + 4
