"""The ``canal.analyze`` static-analysis framework (ISSUE 6).

Three layers of coverage:

* **clean property** — every spec drawn from a strategy over
  ``InterconnectSpec`` space compiles diagnostic-clean through
  ``DEFAULT_PASSES`` (the pipeline's output is well-formed by
  construction, and the analyzer knows the difference between interface
  and waste);
* **mutation suite** — each built-in rule flags its seeded IR violation
  with the right rule id and location (the rules actually detect what
  they claim to detect);
* **integration** — the ``analyze=`` compile knob, per-pass attribution,
  the DSE pre-screen (PnR skipped, verdict persisted, counter exposed),
  the lint CLI's exit-code contract, and the ``prune_dead_muxes``
  fixpoint with the ``dead-mux`` rule as convergence oracle.
"""
import json

import pytest

from _hypothesis_compat import given, settings, st

import canal
from repro.configs.cgra_amber import smoke
from repro.core.analysis import (AnalysisError, Severity, analyze)
from repro.core.analysis.framework import RULES
from repro.core.analysis.lint import run as lint_run
from repro.core.dse import SweepExecutor
from repro.core.graph import IO, NodeKind, SwitchBoxNode
from repro.core.passes import (DEFAULT_PASSES, IRPass, PassContext,
                               PassManager, _default_core_fn, ir_digest,
                               prune_dead_muxes)
from repro.core.pnr.app import app_pointwise
from repro.core.spec import InterconnectSpec

STOCK = dict(width=4, height=4, num_tracks=2, io_ring=True,
             reg_density=1.0)


def build(**overrides):
    spec = InterconnectSpec(**{**STOCK, **overrides})
    return spec, PassManager().run(spec)


def interior_sb(g, io, exclude=()):
    w, h = g.dims()
    for n in g.nodes():
        if (isinstance(n, SwitchBoxNode) and n.io == io
                and 0 < n.x < w - 1 and 0 < n.y < h - 1
                and n not in exclude):
            return n
    raise AssertionError("no interior SB node")


# ---------------------------------------------------------------------------
# clean property: the pipeline's output carries no diagnostics
# ---------------------------------------------------------------------------

@settings(max_examples=12)
@given(st.integers(2, 6), st.integers(2, 6), st.integers(1, 4),
       st.sampled_from(["wilton", "disjoint", "imran"]),
       st.sampled_from([0.0, 0.5, 1.0]),
       st.sampled_from([False, True]),
       st.sampled_from([False, True]))
def test_default_pipeline_is_diagnostic_clean(width, height, num_tracks,
                                              sb_type, reg_density,
                                              io_ring, ready_valid):
    spec = InterconnectSpec(width=width, height=height,
                            num_tracks=num_tracks, sb_type=sb_type,
                            reg_density=reg_density, io_ring=io_ring,
                            ready_valid=ready_valid)
    fab = canal.compile(spec, analyze="error")   # raises if not clean
    report = fab.diagnostics
    assert report is not None and report.ok()
    # no waste either: the pipeline never leaves dead/unreachable
    # hardware behind (capacity warnings from static-routability are
    # honest on tight fabrics — e.g. 1-track arrays — and allowed)
    waste = {"dead-mux", "unreachable-node"}
    assert [d for d in report if d.rule in waste] == []


def test_stock_configs_lint_clean_at_error():
    from test_spec_golden import GOLDEN_SPECS, IR_BUILT
    for name in IR_BUILT:
        fab = canal.compile(GOLDEN_SPECS[name], analyze="error")
        assert fab.diagnostics.ok(), name


# ---------------------------------------------------------------------------
# mutation suite: every rule flags its seeded violation, id + location
# ---------------------------------------------------------------------------

def the_finding(ic, rule):
    report = analyze(ic, rules=[rule])
    assert report.rule_ids() == [rule], report.render()
    return report.by_rule(rule)[0]


def test_rule_combinational_loop():
    _, ic = build()
    g = ic.graphs[16]
    a = interior_sb(g, IO.SB_IN)
    b = interior_sb(g, IO.SB_IN, exclude=(a,))
    for n in (a, b):
        for s in list(n.fan_in):
            s.remove_edge(n)
    a.add_edge(b)
    b.add_edge(a)        # fan-in 1 each: hardwired, unbreakable
    d = the_finding(ic, "combinational-loop")
    assert d.severity == Severity.ERROR
    assert d.tile in ((a.x, a.y), (b.x, b.y))


def test_rule_dead_mux():
    _, ic = build()
    g = ic.graphs[16]
    n = interior_sb(g, IO.SB_OUT)
    for dst in list(n.fan_out):
        n.remove_edge(dst)
    d = the_finding(ic, "dead-mux")
    assert d.tile == (n.x, n.y) and d.node == repr(n)


def test_rule_unreachable_node():
    _, ic = build()
    g = ic.graphs[16]
    n = interior_sb(g, IO.SB_IN)
    for src in list(n.fan_in):
        src.remove_edge(n)
    d = the_finding(ic, "unreachable-node")
    assert d.tile == (n.x, n.y) and d.node == repr(n)


def test_rule_dangling_port():
    _, ic = build()
    g = ic.graphs[16]
    port = g.tiles[(1, 1)].ports["data0"]
    for src in list(port.fan_in):
        src.remove_edge(port)
    d = the_finding(ic, "dangling-port")
    assert d.severity == Severity.ERROR and d.tile == (1, 1)
    assert "data0" in d.message


def test_rule_fanin_overflow():
    _, ic = build()
    ic.config_data_width = 1     # select field holds 2 values; fan-in > 2
    d = the_finding(ic, "fanin-overflow")
    assert d.severity == Severity.ERROR


def test_rule_sb_topology_conformance():
    _, ic = build()
    g = ic.graphs[16]
    sb = g.tiles[(1, 1)].switchbox
    (tf, sf, tt, st_) = sb.internal_connections[0]
    sb.get_sb(sf, tf, IO.SB_IN).remove_edge(sb.get_sb(st_, tt, IO.SB_OUT))
    d = the_finding(ic, "sb-topology-conformance")
    assert d.tile == (1, 1) and "wilton" in d.message


def test_rule_rv_handshake():
    _, ic = build(ready_valid=True)
    g = ic.graphs[16]
    reg = next(n for n in g.nodes() if n.kind == NodeKind.REGISTER)
    reg.attributes.pop("rv_fifo")
    d = the_finding(ic, "rv-handshake")
    assert d.tile == (reg.x, reg.y) and d.node == repr(reg)


def test_rule_static_routability():
    _, ic = build()
    g = ic.graphs[16]
    tile = g.tiles[(1, 1)]
    ports = [tile.ports[p.name] for p in tile.core.inputs()]
    one = ports[0].fan_in[0]
    for p in ports:              # all operands from one driver: supply 1
        for src in list(p.fan_in):
            src.remove_edge(p)
        one.add_edge(p)
    d = the_finding(ic, "static-routability")
    assert d.tile == (1, 1)


def test_unknown_rule_id_raises():
    _, ic = build()
    with pytest.raises(ValueError, match="unknown analysis rules"):
        analyze(ic, rules=["no-such-rule"])


def test_severity_remap():
    _, ic = build()
    g = ic.graphs[16]
    n = interior_sb(g, IO.SB_OUT)
    for dst in list(n.fan_out):
        n.remove_edge(dst)
    report = analyze(ic, rules=["dead-mux"],
                     severities={"dead-mux": "info"})
    assert report.by_rule("dead-mux") and report.warnings == []
    assert report.ok("warning")


# ---------------------------------------------------------------------------
# prune fixpoint (dead-mux as the regression oracle)
# ---------------------------------------------------------------------------

def test_prune_dead_muxes_iterates_to_fixpoint():
    """Severing a pipeline stage's output leaves a chain SB_OUT -> REG ->
    RMUX in which each removal exposes the next: one round cannot clear
    it, the fixpoint must."""
    spec, ic = build()
    g = ic.graphs[16]
    rmux = next(m for m in g.reg_muxes if 0 < m.x < 3 and 0 < m.y < 3)
    for dst in list(rmux.fan_out):
        rmux.remove_edge(dst)
    before = analyze(ic, rules=["dead-mux"])
    assert len(before) >= 3      # rmux + reg + sb_out all unobservable
    ctx = PassContext(spec=spec, core_fn=_default_core_fn(spec), ic=ic)
    prune_dead_muxes(ctx)
    entry = ctx.log[-1]
    assert entry["removed"] >= 3 and entry["rounds"] >= 2
    # convergence oracle: nothing dead survives the fixpoint
    assert len(analyze(ic, rules=["dead-mux"])) == 0
    assert rmux not in list(g.nodes())


def test_prune_is_noop_on_stock_and_digest_stable():
    """The fixpoint prune (with its boundary exemption) must not touch
    the stock uniform topologies — golden IR digests stay put."""
    spec = InterconnectSpec(**STOCK)
    fab = canal.compile(spec)
    log = [e for e in fab.pass_log if e["pass"] == "prune_dead_muxes"]
    assert log[0]["removed"] == 0 and log[0]["rounds"] == 0


# ---------------------------------------------------------------------------
# compile integration: the analyze= knob and per-pass attribution
# ---------------------------------------------------------------------------

def test_compile_analyze_knob():
    spec = InterconnectSpec(**STOCK)
    assert canal.compile(spec, analyze="off").diagnostics is None
    fab = canal.compile(spec)                      # default: "warn"
    assert fab.diagnostics is not None and fab.diagnostics.ok()
    bad = InterconnectSpec(**{**STOCK, "cb_track_fc": 0.01})
    warned = canal.compile(bad)                    # records, no raise
    assert not warned.diagnostics.ok()
    with pytest.raises(AnalysisError) as ei:
        canal.compile(bad, analyze="error")
    assert ei.value.report.by_rule("dangling-port")
    with pytest.raises(ValueError, match="analyze="):
        canal.compile(spec, analyze="loud")


def test_compiled_fabric_reanalyze_subset():
    fab = canal.compile(InterconnectSpec(**STOCK))
    report = fab.analyze(rules=["combinational-loop", "dead-mux"])
    assert set(report.rules_run) == {"combinational-loop", "dead-mux"}


def test_per_pass_attribution():
    """A custom pass that severs a port is blamed — not the stock passes
    that built the (clean) fabric before it."""
    def sever(ctx):
        g = ctx.graphs()[16]
        port = g.tiles[(1, 1)].ports["data0"]
        for src in list(port.fan_in):
            src.remove_edge(port)

    passes = tuple(DEFAULT_PASSES) + (IRPass("sever_port", sever),)
    fab = PassManager(passes).compile(
        InterconnectSpec(**STOCK), analyze_per_pass=True)
    found = fab.diagnostics.by_rule("dangling-port")
    assert found and all(d.pass_name == "sever_port" for d in found)


def test_per_pass_mode_does_not_change_ir():
    spec = InterconnectSpec(**STOCK)
    plain = canal.compile(spec, analyze="off")
    attributed = canal.compile(spec, analyze="error",
                               analyze_per_pass=True)
    assert ir_digest(plain.interconnect) == \
        ir_digest(attributed.interconnect)


# ---------------------------------------------------------------------------
# lowered-scope verification (verify.py folded into the framework)
# ---------------------------------------------------------------------------

def test_compiled_fabric_verify_runs_lowered_rules():
    fab = canal.compile(InterconnectSpec(width=2, height=2, num_tracks=2,
                                         reg_density=1.0))
    report = fab.verify()
    assert set(report.rules_run) == {"structural-equivalence",
                                     "config-sweep"}
    assert report.ok()
    info = report.by_rule("config-sweep")
    assert info and "verified" in info[0].message


def test_lowered_rules_not_in_default_scope():
    _, ic = build()
    report = analyze(ic)
    assert "config-sweep" not in report.rules_run
    assert RULES["config-sweep"].scope == "lowered"


# ---------------------------------------------------------------------------
# DSE pre-screen: skip PnR, persist + round-trip the verdict
# ---------------------------------------------------------------------------

def test_executor_skips_pnr_for_invalid_spec(tmp_path):
    apps = {"pw": lambda: app_pointwise(1)}
    bad = InterconnectSpec(**{**STOCK, "cb_track_fc": 0.01})
    ex = SweepExecutor(apps=apps, store=str(tmp_path))
    rec = ex.run_point(bad)
    assert ex.analysis_rejections == 1 and ex.pnr_computations == 0
    assert rec["analysis"]["clean"] is False
    entry = rec["apps"]["pw"]
    assert entry["success"] is False
    assert entry["skipped"] == "static-analysis"
    assert "dangling-port" in entry["error"]

    # verdict round-trips through the store: a fresh executor gets the
    # rejected record as a hit and never re-analyzes or re-routes
    ex2 = SweepExecutor(apps=apps, store=str(tmp_path))
    rec2 = ex2.run_point(bad)
    assert ex2.store_hits == 1 and ex2.analysis_rejections == 0
    assert rec2["analysis"] == rec["analysis"]

    # valid specs still compute — and carry their (clean) verdict
    good = InterconnectSpec(**STOCK)
    rec3 = ex2.run_point(good)
    assert ex2.pnr_computations == 1
    assert rec3["analysis"]["clean"] is True
    assert rec3["apps"]["pw"]["success"] is True


def test_service_exposes_analysis_rejections(tmp_path):
    from repro.serve.dse_service import DSEService
    apps = {"pw": lambda: app_pointwise(1)}
    bad = InterconnectSpec(**{**STOCK, "cb_track_fc": 0.01})
    with DSEService(apps=apps,
                    store=canal.ResultStore(str(tmp_path))) as svc:
        rec = svc.query(bad)
        assert rec["analysis"]["clean"] is False
        stats = svc.stats()
        assert stats["executor"]["analysis_rejections"] == 1
        assert stats["executor"]["pnr_computations"] == 0


# ---------------------------------------------------------------------------
# lint CLI: exit codes and artifact shape
# ---------------------------------------------------------------------------

def test_lint_cli_contract(tmp_path, capsys):
    good = tmp_path / "good.json"
    good.write_text(InterconnectSpec(**STOCK).to_json())
    bad = tmp_path / "bad.json"
    bad.write_text(InterconnectSpec(
        **{**STOCK, "cb_track_fc": 0.01}).to_json())
    artifact = tmp_path / "diag.json"

    assert lint_run([str(good),
                     "--config", "repro.configs.cgra_amber:smoke"]) == 0
    assert lint_run([str(bad), "--format", "json",
                     "-o", str(artifact)]) == 1
    doc = json.loads(artifact.read_text())
    assert doc["clean"] is False
    target = doc["targets"][str(bad)]
    rules = {d["rule"] for d in target["diagnostics"]}
    assert "dangling-port" in rules

    capsys.readouterr()
    assert lint_run([]) == 2                       # no targets
    assert lint_run([str(good), "--rules", "nope"]) == 2
    assert lint_run([str(tmp_path / "missing.json")]) == 2
    assert lint_run(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "combinational-loop" in out


def test_lint_smoke_config_object():
    """--config accepts a zero-arg factory returning a CompiledFabric."""
    assert smoke() is not None  # the factory the CI lint step points at
    assert lint_run(["--config", "repro.configs.cgra_amber:smoke"]) == 0
