"""Hybrid ready-valid NoC backend (§3.3, Figs. 5–6)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core.edsl import create_uniform_interconnect
from repro.fabric.ready_valid import compile_ready_valid
from test_lowering_fabric import manual_east_route


@pytest.fixture(scope="module")
def rv_ic():
    return create_uniform_interconnect(width=4, height=4, num_tracks=2,
                                       sb_type="wilton", io_ring=True,
                                       reg_density=1.0, ready_valid=True)


@pytest.mark.parametrize("mode", ["full", "split"])
def test_lossless_under_backpressure(rv_ic, mode):
    fab = compile_ready_valid(rv_ic, fifo_mode=mode)
    edges = manual_east_route(rv_ic)
    config = jnp.asarray(fab.route_to_config(edges))
    io_idx = {c: i for i, c in enumerate(fab.io_coords)}
    T = 28
    streams = np.zeros((T, fab.num_io), np.int32)
    lens = np.zeros(fab.num_io, np.int32)
    n_items = 10
    streams[:n_items, io_idx[(0, 1)]] = np.arange(1, n_items + 1)
    lens[io_idx[(0, 1)]] = n_items
    sink_ready = np.ones((T, fab.num_io), np.int32)
    sink_ready[3:11, io_idx[(3, 1)]] = 0      # 8-cycle stall
    od, ov, acc = fab.run_with_sources(config, jnp.asarray(streams),
                                       jnp.asarray(lens),
                                       jnp.asarray(sink_ready), depth=20)
    j = io_idx[(3, 1)]
    received = np.asarray(od)[:, j][np.asarray(acc)[:, j] > 0]
    assert list(received) == list(range(1, n_items + 1)), \
        f"{mode}: lossy or out of order: {received}"


@pytest.mark.parametrize("mode", ["full", "split"])
def test_ready_propagates_to_source(rv_ic, mode):
    """With the sink always stalled, source ready must eventually drop:
    the Fig. 5 join logic propagates backpressure end to end."""
    fab = compile_ready_valid(rv_ic, fifo_mode=mode)
    edges = manual_east_route(rv_ic)
    config = jnp.asarray(fab.route_to_config(edges))
    io_idx = {c: i for i, c in enumerate(fab.io_coords)}
    T = 20
    streams = np.zeros((T, fab.num_io), np.int32)
    lens = np.zeros(fab.num_io, np.int32)
    streams[:T, io_idx[(0, 1)]] = np.arange(1, T + 1)
    lens[io_idx[(0, 1)]] = T
    sink_ready = np.zeros((T, fab.num_io), np.int32)   # never ready
    od, ov, acc = fab.run_with_sources(config, jnp.asarray(streams),
                                       jnp.asarray(lens),
                                       jnp.asarray(sink_ready), depth=20)
    assert np.asarray(acc).sum() == 0
    # buffering capacity is finite: the fabric can only have absorbed a
    # few items (FIFO slots along the path), not the whole stream
    # -> source must have stalled.
    # full mode: 3 hops x depth-2 = 6 slots; split: 3 single slots.
    limit = 8 if mode == "full" else 5
    # items absorbed = final source pointer; recompute by rerunning with
    # ready-latched sources is internal, so check via valid at sink only:
    assert np.asarray(ov)[:, io_idx[(3, 1)]].max() <= 1


@pytest.mark.parametrize("mode", ["full", "split"])
@given(st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_token_conservation_random_backpressure(rv_ic, mode, n_items,
                                                seed):
    """Conservation under a random backpressure schedule: the fabric must
    neither drop nor duplicate tokens. The sink stalls randomly (~50%)
    for a window, then drains — every injected token must arrive exactly
    once, in order, for both FIFO lowerings."""
    fab = compile_ready_valid(rv_ic, fifo_mode=mode)
    edges = manual_east_route(rv_ic)
    config = jnp.asarray(fab.route_to_config(edges))
    io_idx = {c: i for i, c in enumerate(fab.io_coords)}
    rng = np.random.default_rng(seed)
    T = 40
    streams = np.zeros((T, fab.num_io), np.int32)
    lens = np.zeros(fab.num_io, np.int32)
    src, dst = io_idx[(0, 1)], io_idx[(3, 1)]
    streams[:n_items, src] = np.arange(1, n_items + 1)
    lens[src] = n_items
    sink_ready = np.ones((T, fab.num_io), np.int32)
    # random stalls over the first 26 cycles, full drain afterwards
    sink_ready[:26, dst] = (rng.random(26) < 0.5).astype(np.int32)
    od, ov, acc = fab.run_with_sources(config, jnp.asarray(streams),
                                       jnp.asarray(lens),
                                       jnp.asarray(sink_ready), depth=20)
    received = np.asarray(od)[:, dst][np.asarray(acc)[:, dst] > 0]
    assert list(received) == list(range(1, n_items + 1)), \
        f"{mode} seed={seed}: lost/dup/reordered tokens: {received}"


def test_full_mode_buffers_more_than_split(rv_ic):
    """Depth-2 FIFOs (full) hold ~2x the in-flight items of split
    single-slot stages — the area/buffering trade of Fig. 8."""
    absorbed = {}
    for mode in ("full", "split"):
        fab = compile_ready_valid(rv_ic, fifo_mode=mode)
        edges = manual_east_route(rv_ic)
        config = jnp.asarray(fab.route_to_config(edges))
        io_idx = {c: i for i, c in enumerate(fab.io_coords)}
        T = 16
        streams = np.zeros((T, fab.num_io), np.int32)
        lens = np.zeros(fab.num_io, np.int32)
        streams[:T, io_idx[(0, 1)]] = 1 + np.arange(T)
        lens[io_idx[(0, 1)]] = T
        sink_ready = np.zeros((T, fab.num_io), np.int32)
        # count accepted-by-fabric items: run and measure source ready
        od, ov, orr = fab.run_stream(config,
                                     jnp.asarray(streams),
                                     jnp.asarray((streams > 0)
                                                 .astype(np.int32)),
                                     jnp.asarray(sink_ready), depth=20)
        absorbed[mode] = int(np.asarray(orr)[:, io_idx[(0, 1)]].sum())
    assert absorbed["full"] > absorbed["split"]
