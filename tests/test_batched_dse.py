"""Batched DSE engine: run_batch ≡ looped run, computed depth ≡
conservative depth, batched app emulation, SweepExecutor caching, and the
long-stream AppEmulator regression."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.edsl import create_uniform_interconnect
from repro.core.graph import IO, NodeKind, Side
from repro.core.lowering import compile_interconnect


@pytest.fixture(scope="module")
def small_ic():
    return create_uniform_interconnect(width=4, height=4, num_tracks=2,
                                       sb_type="wilton", io_ring=True,
                                       reg_density=1.0)


@pytest.fixture(scope="module")
def fabric(small_ic):
    return compile_interconnect(small_ic)


def _random_cases(fab, b, t, seed=0):
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 4, (b, fab.num_config)).astype(np.int32)
    ext = rng.integers(0, 1000, (b, t, fab.num_io)).astype(np.int32)
    return cfgs, ext


@pytest.mark.parametrize("use_pallas,fused", [(False, True),
                                              (False, False),
                                              (True, True),
                                              (True, False)])
def test_run_batch_matches_looped_run(small_ic, use_pallas, fused):
    """B configurations through one run_batch == B serial run calls —
    the Pallas/fused variant exercises fabric_fused_batch end to end,
    the unfused one the sweep-at-a-time fabric_sweep_batch baseline."""
    fab = compile_interconnect(small_ic, use_pallas=use_pallas)
    cfgs, ext = _random_cases(fab, b=4, t=5)
    serial = np.stack([
        np.asarray(fab.run(jnp.asarray(cfgs[i]), jnp.asarray(ext[i]),
                           depth=8))
        for i in range(len(cfgs))])
    batched = np.asarray(fab.run_batch(jnp.asarray(cfgs),
                                       jnp.asarray(ext), depth=8,
                                       fused=fused))
    np.testing.assert_array_equal(serial, batched)


def test_run_batch_computed_depth(small_ic, fabric):
    """depth=None resolves the per-config combinational depth and matches
    the fixed conservative bound. Configs come from legal routes: only an
    acyclic active network has a fixpoint, so only there is output
    depth-independent (a random config may wire a combinational loop)."""
    routes = [_east_route(small_ic, y=1), _east_route(small_ic, y=2),
              _east_route(small_ic, y=1, track=1)]
    cfgs = np.stack([fabric.route_to_config(r) for r in routes])
    rng = np.random.default_rng(1)
    ext = rng.integers(0, 1000, (3, 4, fabric.num_io)).astype(np.int32)
    auto = np.asarray(fabric.run_batch(jnp.asarray(cfgs),
                                       jnp.asarray(ext)))
    fixed = np.asarray(fabric.run_batch(jnp.asarray(cfgs),
                                        jnp.asarray(ext), depth=64))
    np.testing.assert_array_equal(auto, fixed)


def _east_route(ic, y=1, track=0):
    # same manual registered east route as test_lowering_fabric
    g = ic.graph(16)
    edges = []
    port = g.get_port(0, y, "io_out")
    sb_out = g.get_sb(0, y, Side.EAST, track, IO.SB_OUT)
    edges.append((port, sb_out))
    cur = sb_out
    w = ic.dims()[0]
    for x in range(1, w):
        rmux = [n for n in cur.fan_out if n.kind == NodeKind.REG_MUX][0]
        reg = [n for n in cur.fan_out if n.kind == NodeKind.REGISTER][0]
        edges += [(cur, reg), (reg, rmux)]
        sb_in = rmux.fan_out[0]
        edges.append((rmux, sb_in))
        if x < w - 1:
            nxt = g.get_sb(x, y, Side.EAST, track, IO.SB_OUT)
            edges.append((sb_in, nxt))
            cur = nxt
        else:
            edges.append((sb_in, g.get_port(x, y, "io_in")))
    return edges


def test_depth_for_route_tighter_and_equivalent(small_ic, fabric):
    """Computed route depth is <= the conservative bound and produces
    bit-identical emulation."""
    from repro.fabric import AppEmulator

    edges = _east_route(small_ic)
    computed = fabric.depth_for_route(edges)
    conservative = len(edges) + 4
    assert 1 <= computed <= conservative

    emu_new = AppEmulator(fabric, edges, pe_ops={})
    emu_old = AppEmulator(fabric, edges, pe_ops={}, depth=conservative)
    assert emu_new.depth == computed
    T = 10
    ins = {(0, 1): np.arange(100, 100 + T, dtype=np.int32)}
    a, b = emu_new.run(ins, T), emu_old.run(ins, T)
    for coord in a:
        np.testing.assert_array_equal(a[coord], b[coord])


def test_app_emulator_truncates_long_stream(small_ic, fabric):
    """Regression: an input stream longer than the emulation window used
    to raise on broadcast; it must truncate to ``cycles``."""
    from repro.fabric import AppEmulator

    edges = _east_route(small_ic)
    emu = AppEmulator(fabric, edges, pe_ops={})
    T = 6
    out = emu.run({(0, 1): np.arange(100, dtype=np.int32)}, T)
    assert all(len(v) == T for v in out.values())
    short = emu.run({(0, 1): np.arange(100, 100 + T, dtype=np.int32)}, T)
    lng = emu.run({(0, 1): np.arange(100, 200, dtype=np.int32)}, T)
    for coord in short:
        np.testing.assert_array_equal(short[coord], lng[coord])


def test_run_apps_batch_matches_per_app(small_ic, fabric):
    """Several apps on one fabric as one batch == per-app emulation."""
    from repro.fabric import AppEmulator, run_apps_batch

    e1 = AppEmulator(fabric, _east_route(small_ic, y=1), pe_ops={})
    e2 = AppEmulator(fabric, _east_route(small_ic, y=2), pe_ops={})
    T = 8
    i1 = {(0, 1): np.arange(10, 10 + T, dtype=np.int32)}
    i2 = {(0, 2): np.arange(50, 50 + T, dtype=np.int32)}
    outs = run_apps_batch([e1, e2], [i1, i2], T)
    ref = [e1.run(i1, T), e2.run(i2, T)]
    for got, want in zip(outs, ref):
        for coord in want:
            np.testing.assert_array_equal(got[coord], want[coord])


def test_run_apps_batch_rejects_mixed_fabrics(small_ic, fabric):
    from repro.fabric import AppEmulator, run_apps_batch

    other = compile_interconnect(small_ic)
    e1 = AppEmulator(fabric, _east_route(small_ic, y=1), pe_ops={})
    e2 = AppEmulator(other, _east_route(small_ic, y=2), pe_ops={})
    with pytest.raises(ValueError, match="shared fabric"):
        run_apps_batch([e1, e2], [{}, {}], 4)


@pytest.mark.parametrize("chunk", [1, 4, 16])
def test_run_batch_io_chunk_streams_bit_identically(small_ic, chunk):
    """The streamed fused kernel (ext-IO gridded from HBM in chunk-cycle
    blocks, register/mem state carried across grid steps) must be
    bit-identical to the per-cycle scan — including T not divisible by
    the chunk and per-config computed depths."""
    fab = compile_interconnect(small_ic, use_pallas=True)
    cfgs, ext = _random_cases(fab, b=3, t=7)
    base = np.asarray(fab.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                    depth=8))
    stream = np.asarray(fab.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                      depth=8, io_chunk=chunk))
    np.testing.assert_array_equal(base, stream)


def test_run_batch_io_chunk_streams_mem_state_bit_identically():
    """Memory cores exercise the third state region of the streamed
    kernel (mem_out pin slots, mem_in gather): a mem-bearing fabric must
    stream bit-identically to the per-cycle scan too."""
    ic = create_uniform_interconnect(width=4, height=4, num_tracks=2,
                                     sb_type="wilton", io_ring=True,
                                     reg_density=1.0, mem_columns=(2,))
    fab = compile_interconnect(ic, use_pallas=True)
    assert fab.num_mem > 0
    cfgs, ext = _random_cases(fab, b=3, t=9)
    base = np.asarray(fab.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                    depth=8))
    stream = np.asarray(fab.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                      depth=8, io_chunk=4))
    np.testing.assert_array_equal(base, stream)


def test_run_batch_io_chunk_ignored_on_reference_engine(small_ic, fabric):
    """Without the Pallas engine there is nothing to stream: io_chunk is
    accepted and ignored (the scan already leaves the trace off-chip)."""
    cfgs, ext = _random_cases(fabric, b=2, t=5)
    a = np.asarray(fabric.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                    depth=8))
    b = np.asarray(fabric.run_batch(jnp.asarray(cfgs), jnp.asarray(ext),
                                    depth=8, io_chunk=4))
    np.testing.assert_array_equal(a, b)


def test_run_apps_batch_io_chunk_matches(small_ic):
    """run_apps_batch forwards io_chunk; routed-app emulation streamed
    from HBM stays bit-identical to the unstreamed batch."""
    from repro.fabric import AppEmulator, run_apps_batch

    fab = compile_interconnect(small_ic, use_pallas=True)
    e1 = AppEmulator(fab, _east_route(small_ic, y=1), pe_ops={})
    e2 = AppEmulator(fab, _east_route(small_ic, y=2), pe_ops={})
    T = 9
    i1 = {(0, 1): np.arange(10, 10 + T, dtype=np.int32)}
    i2 = {(0, 2): np.arange(50, 50 + T, dtype=np.int32)}
    plain = run_apps_batch([e1, e2], [i1, i2], T)
    streamed = run_apps_batch([e1, e2], [i1, i2], T, io_chunk=4)
    for got, want in zip(streamed, plain):
        for coord in want:
            np.testing.assert_array_equal(got[coord], want[coord])


def test_pipelined_emulation_matches_inline():
    """The async PnR/emulation pipeline (deferred per-device dispatch,
    futures joined before records return) must produce the same records
    as inline emulation, emulation report included."""
    from repro.core.dse import SweepExecutor
    from repro.core.pnr.app import app_pointwise

    kw1 = dict(width=6, height=6, num_tracks=4, io_ring=True,
               reg_density=1.0)
    kw2 = dict(width=6, height=6, num_tracks=3, io_ring=True,
               reg_density=1.0)
    points = [(kw1, {"num_tracks": 4}), (kw2, {"num_tracks": 3})]
    recs = {}
    for pipelined in (False, True):
        ex = SweepExecutor(apps={"pw1": lambda: app_pointwise(1)},
                           sa_steps=20, sa_batch=8, emulate_cycles=8,
                           use_pallas=False, max_workers=2,
                           pipeline_emulation=pipelined)
        recs[pipelined] = ex.run_points(points)
        assert not ex._pending          # all futures joined
    for sync_rec, async_rec in zip(recs[False], recs[True]):
        a, b = sync_rec["apps"]["pw1"], async_rec["apps"]["pw1"]
        assert a["success"] and b["success"]
        assert "emulation" in a and "emulation" in b
        assert a["emulation"]["out_checksum"] == \
            b["emulation"]["out_checksum"]
        assert a["emulation"]["depth"] == b["emulation"]["depth"]


def test_sweep_executor_point_with_batched_emulation(tmp_path):
    """One design point end to end on the executor: PnR, shared caches,
    batched emulation report, JSON persistence."""
    from repro.core.dse import SweepExecutor
    from repro.core.pnr.app import app_pointwise

    ex = SweepExecutor(apps={"pw1": lambda: app_pointwise(1)},
                       sa_steps=20, sa_batch=8, emulate_cycles=8,
                       use_pallas=False, max_workers=1)
    kw = dict(width=6, height=6, num_tracks=4, io_ring=True,
              reg_density=1.0)
    recs = ex.run_points([(kw, {"num_tracks": 4})])
    assert len(recs) == 1
    rec = recs[0]
    assert rec["num_tracks"] == 4 and rec["sb_area"] > 0
    app_rec = rec["apps"]["pw1"]
    assert app_rec["success"], app_rec["error"]
    assert app_rec["emulation"]["cycles"] == 8
    assert app_rec["emulation"]["depth"] >= 1
    # caches are shared across points with identical interconnects
    ic1 = ex.interconnect(**kw)
    assert ex.interconnect(**kw) is ic1
    assert ex.resources(ic1, ex._key(kw)) is ex.resources(ic1, ex._key(kw))
    path = ex.save_json(str(tmp_path / "sweep.json"))
    import json
    with open(path) as f:
        assert json.load(f)[0]["num_tracks"] == 4


def test_batched_vs_serial_emulation_equal_and_recorded():
    from repro.core.dse import batched_vs_serial_emulation

    rec = batched_vs_serial_emulation(width=4, height=4, num_tracks=2,
                                      batch=3, cycles=4, use_pallas=False)
    assert rec["batch"] == 3 and rec["serial_seconds"] > 0
    assert rec["batched_seconds"] > 0


def test_fused_vs_unfused_emulation_equal_and_recorded():
    """The benchmark engine asserts fused == unfused internally; the
    record carries the per-config depth spread it masked over."""
    from repro.core.dse import fused_vs_unfused_emulation

    rec = fused_vs_unfused_emulation(width=4, height=4, num_tracks=2,
                                     batch=3, cycles=4, use_pallas=False)
    assert rec["unfused_seconds"] > 0 and rec["fused_seconds"] > 0
    assert rec["min_depth"] >= 1
    assert rec["max_depth"] >= rec["min_depth"]


def test_sharded_vs_single_emulation_single_device_fallback():
    """On one visible device the sharded call must take the local path
    and stay bit-identical (asserted inside the engine)."""
    from repro.core.dse import sharded_vs_single_emulation

    rec = sharded_vs_single_emulation(width=4, height=4, num_tracks=2,
                                      batch=3, cycles=4,
                                      use_pallas=False)
    assert rec["devices"] >= 1
    assert rec["single_seconds"] > 0 and rec["sharded_seconds"] > 0
