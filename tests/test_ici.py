"""Canal-ICI pod fabric model tests."""
import numpy as np
import pytest

from repro.core.ici import (PodFabric, pod_collective_model,
                            route_traffic_canal)


def test_all_reduce_balanced_on_torus():
    fab = PodFabric(8, 8)
    fab.apply_all_reduce(1e9, "x")
    assert fab.congestion_factor() == pytest.approx(2.0, abs=0.01) or \
        fab.congestion_factor() >= 1.0
    # x-axis all-reduce puts zero load on y links
    y_loads = [v for (s, d), v in fab.link_bytes.items()
               if fab.coords(s)[0] == fab.coords(d)[0]]
    assert max(y_loads) == 0.0


def test_collective_model_congestion_vs_naive():
    out = pod_collective_model(
        {"all-reduce": 1e9, "all-gather": 5e8}, {"data": 16, "model": 16})
    assert out["max_link_bytes"] > 0
    assert out["collective_time_s"] > 0
    assert out["congestion_factor"] >= 1.0


def test_canal_router_on_pod():
    """The paper's PathFinder routes pod flows; hot flows spread across
    lanes (negotiated congestion)."""
    rng = np.random.default_rng(0)
    flows = [((int(rng.integers(0, 4)), int(rng.integers(0, 4))),
              (int(rng.integers(0, 4)), int(rng.integers(0, 4))))
             for _ in range(12)]
    flows = [(s, d) for s, d in flows if s != d]
    result, usage = route_traffic_canal(4, 4, flows, lanes=2)
    assert result.overuse_history[-1] == 0         # converged, no overuse
    assert usage.max() <= 2                        # 2 VCs per transit


def test_axis_order_dse_changes_congestion():
    """Mesh-axis assignment is a DSE knob: asymmetric traffic prefers the
    axis order that puts the heavy collective on the longer rings."""
    traffic = {"all-gather": 4e9, "all-reduce": 1e8}
    a = pod_collective_model(traffic, {"data": 16, "model": 16},
                             axis_order=("data", "model"))
    b = pod_collective_model(traffic, {"data": 16, "model": 16},
                             axis_order=("model", "data"))
    assert a["max_link_bytes"] != b["max_link_bytes"] or \
        a["collective_time_s"] == b["collective_time_s"]
