"""Per-architecture smoke tests: reduced same-family configs, one forward
and one serve path on CPU, asserting shapes + finiteness (assignment
requirement), plus prefill/decode vs full-forward consistency."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke, input_specs, list_archs, SHAPES
from repro.models import build_model

ARCHS = list_archs()


def make_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(3, min(200, cfg.vocab_size - 1), (b, s))
        .astype(np.int32))}
    batch["labels"] = jnp.asarray(
        rng.integers(3, min(200, cfg.vocab_size - 1), (b, s))
        .astype(np.int32))
    if cfg.vlm is not None:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.vlm.num_patches, cfg.vlm.d_patch))
            .astype(np.float32) * 0.1).astype(jnp.bfloat16)
    if cfg.encdec is not None:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encdec.encoder_seq,
                                 cfg.encdec.d_frame))
            .astype(np.float32) * 0.1).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_smoke(arch).replace(moe_groups=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits = model.logits(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_smoke(arch):
    cfg = get_smoke(arch).replace(moe_groups=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, s=8)
    batch.pop("labels")
    cache = model.init_cache(2, 64)
    logits, cache = model.prefill(params, cache, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    l2, cache = model.decode_step(
        params, cache, {"tokens": jnp.ones((2, 1), jnp.int32)})
    assert bool(jnp.isfinite(logits).all() and jnp.isfinite(l2).all())
    assert int(cache["index"]) >= 9


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "qwen3_14b",
                                  "mamba2_1_3b"])
def test_prefill_decode_matches_full_forward(arch):
    """Teacher forcing: decode token-by-token must equal the full causal
    forward (cache correctness)."""
    cfg = get_smoke(arch).replace(moe_groups=2)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    b, s = 2, 8
    batch = make_batch(cfg, b=b, s=s, seed=3)
    batch.pop("labels")
    full = model.logits(params, batch)              # (B, S, V)

    cache = model.init_cache(b, 32)
    lp, cache = model.prefill(params, cache,
                              {"tokens": batch["tokens"][:, :4]})
    np.testing.assert_allclose(np.asarray(lp[:, 0]),
                               np.asarray(full[:, 3]), atol=2e-2,
                               rtol=2e-2)
    for t in range(4, s):
        ld, cache = model.decode_step(
            params, cache, {"tokens": batch["tokens"][:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-2,
                                   rtol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_configs_match_assignment(arch):
    """The FULL configs carry the exact published numbers."""
    from repro.configs import get_config
    cfg = get_config(arch)
    expected = {
        "tinyllama_1_1b": (22, 2048, 32, 4, 5632, 32000),
        "phi3_mini_3_8b": (32, 3072, 32, 32, 8192, 32064),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19200, 32256),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "kimi_k2_1t_a32b": (61, 7168, 64, 8, 18432, 163840),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "internvl2_2b": (24, 2048, 16, 8, 8192, 92553),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "mamba2_1_3b": (48, 2048, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.kv_heads,
           cfg.d_ff if cfg.moe is None or arch == "kimi_k2_1t_a32b"
           else cfg.moe.d_ff_expert, cfg.vocab_size)
    if arch == "granite_moe_3b_a800m":
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.kv_heads,
               cfg.moe.d_ff_expert, cfg.vocab_size)
    assert got == expected
    if arch == "kimi_k2_1t_a32b":
        assert cfg.moe.num_experts == 384 and cfg.moe.top_k == 8
        assert cfg.moe.d_ff_expert == 2048
    if arch == "granite_moe_3b_a800m":
        assert cfg.moe.num_experts == 40 and cfg.moe.top_k == 8
    if arch == "mamba2_1_3b":
        assert cfg.ssm.state_dim == 128
    if arch == "qwen3_14b":
        assert cfg.qk_norm


def test_long_500k_applicability():
    from repro.configs import cell_is_runnable, get_config
    runnable = [a for a in ARCHS
                if cell_is_runnable(get_config(a), SHAPES["long_500k"])]
    assert sorted(runnable) == ["mamba2_1_3b", "recurrentgemma_2b"]


def test_input_specs_shapes():
    from repro.configs import get_config
    cfg = get_config("internvl2_2b")
    sp = input_specs(cfg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096)
    assert sp["patches"].shape == (256, 256, 1024)
    sp = input_specs(cfg, SHAPES["decode_32k"])
    assert sp["tokens"].shape == (128, 1)
    cfg_w = get_config("whisper_medium")
    sp = input_specs(cfg_w, SHAPES["prefill_32k"])
    assert sp["frames"].shape == (32, 1500, 128)
