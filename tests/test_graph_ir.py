"""Graph IR + eDSL unit/property tests (Canal §3.1–3.2)."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.edsl import (SB_TOPOLOGIES, SwitchBoxType,
                             create_uniform_interconnect)
from repro.core.spec import sides_for
from repro.core.graph import IO, Side


@given(st.integers(2, 10),
       st.sampled_from(list(SwitchBoxType)))
@settings(max_examples=20, deadline=None)
def test_topology_is_permutation(num_tracks, topo):
    """Every (from_side, to_side) pair maps tracks bijectively — this is
    what makes Wilton and Disjoint equal-area (paper §4.2.1)."""
    conns = SB_TOPOLOGIES[topo](num_tracks)
    by_pair = {}
    for (t_from, s_from, t_to, s_to) in conns:
        by_pair.setdefault((s_from, s_to), []).append((t_from, t_to))
    for (s_from, s_to), pairs in by_pair.items():
        assert s_from != s_to
        froms = sorted(t for t, _ in pairs)
        tos = sorted(t for _, t in pairs)
        assert froms == list(range(num_tracks))
        assert tos == list(range(num_tracks))


@given(st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_disjoint_keeps_track(num_tracks):
    for (t_from, _, t_to, _) in SB_TOPOLOGIES[SwitchBoxType.DISJOINT](
            num_tracks):
        assert t_from == t_to


def test_uniform_interconnect_structure():
    ic = create_uniform_interconnect(width=4, height=3, num_tracks=2,
                                     sb_type="wilton", reg_density=0.0)
    g = ic.graph(16)
    assert g.dims() == (4, 3)
    # interior SB_OUT fan-in: 3 topology edges + 2 core outputs (4 sides)
    sb = g.get_sb(1, 1, Side.NORTH, 0, IO.SB_OUT)
    assert len(sb.fan_in) == 5
    # edges between tiles: east out of (1,1) feeds west in of (2,1)
    out = g.get_sb(1, 1, Side.EAST, 0, IO.SB_OUT)
    nbr = g.get_sb(2, 1, Side.WEST, 0, IO.SB_IN)
    assert nbr in out.fan_out


def test_register_insertion_density():
    full = create_uniform_interconnect(width=4, height=4, num_tracks=2,
                                       reg_density=1.0)
    none = create_uniform_interconnect(width=4, height=4, num_tracks=2,
                                       reg_density=0.0)
    half = create_uniform_interconnect(width=4, height=4, num_tracks=2,
                                       reg_density=0.5)
    n_full = len(full.graph(16).registers)
    n_none = len(none.graph(16).registers)
    n_half = len(half.graph(16).registers)
    assert n_none == 0
    assert 0 < n_half < n_full


def test_side_reduction_order():
    # Fig. 12: 4 sides -> drop EAST -> drop SOUTH
    assert Side.EAST not in sides_for(3)
    assert Side.SOUTH not in sides_for(2)
    assert set(sides_for(4)) == set(Side)


def test_port_connection_depopulation():
    ic4 = create_uniform_interconnect(width=4, height=4, num_tracks=3,
                                      cb_sides=4)
    ic2 = create_uniform_interconnect(width=4, height=4, num_tracks=3,
                                      cb_sides=2)
    p4 = ic4.graph(16).get_port(1, 1, "data0")
    p2 = ic2.graph(16).get_port(1, 1, "data0")
    assert len(p4.fan_in) == 4 * 3
    assert len(p2.fan_in) == 2 * 3


def test_track_fc():
    ic = create_uniform_interconnect(width=4, height=4, num_tracks=4,
                                     cb_track_fc=0.5, sb_track_fc=0.5)
    p = ic.graph(16).get_port(1, 1, "data0")
    assert len(p.fan_in) == 4 * 2          # half the tracks, 4 sides


def test_width_mismatch_rejected():
    from repro.core.graph import PortNode
    a = PortNode("a", 0, 0, 16)
    b = PortNode("b", 0, 0, 1)
    with pytest.raises(ValueError):
        a.add_edge(b)


def test_low_level_edsl():
    """Paper Fig. 4 top: manual node creation + wiring."""
    from repro.core.edsl import make_sb_node
    from repro.core.graph import PortNode
    node = make_sb_node(x=1, y=1, side="south", track=1)
    ports = [PortNode(f"data{i}", 1, 1, 16) for i in range(4)]
    for p in ports:
        node.add_edge(p)
    assert all(node in p.fan_in for p in ports)
