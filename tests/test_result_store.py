"""The spec-addressed persistent result store and the DSE serving path:
round-trip, atomicity/corruption tolerance, the hardware-digest secondary
index, store-backed SweepExecutor (warm sweeps do zero PnR, concurrent
requests coalesce, save_json dedupes), digest forward-compatibility of
the folded PnR knobs, and DSEService hit/miss/coalescing accounting."""
import json
import os
import threading
import time

import pytest

import canal
from repro.core.dse import SweepExecutor, sweep_num_tracks
from repro.core.pnr.app import app_pointwise
from repro.core.spec import InterconnectSpec, spec_from_kwargs
from repro.core.store import SCHEMA_VERSION, ResultStore

SMOKE = dict(width=4, height=4, num_tracks=2, io_ring=True, reg_density=1.0)
FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "spec_digests.json")


def _executor(store, **kw):
    kw.setdefault("apps", {"pw": lambda: app_pointwise(1)})
    kw.setdefault("emulate_cycles", 6)
    kw.setdefault("use_pallas", False)
    kw.setdefault("max_workers", 1)
    return SweepExecutor(store=store, **kw)


# ---------------------------------------------------------------------------
# ResultStore basics
# ---------------------------------------------------------------------------

def test_store_round_trip(tmp_path):
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    rec = {"apps": {"pw": {"success": True}}, "sb_area": 1.5,
           "spec_digest": spec.digest()}
    digest = store.put(spec, rec)
    assert digest == spec.digest()
    assert store.get(spec.digest()) == rec
    assert store.get(spec) == rec                 # spec keys work too
    assert spec.digest() in store and len(store) == 1
    assert list(store.digests()) == [spec.digest()]
    st = store.stats()
    assert st["hits"] == 2 and st["writes"] == 1


def test_store_miss_and_bad_digest(tmp_path):
    store = ResultStore(str(tmp_path / "s"))
    assert store.get("0" * 64) is None
    assert store.stats()["misses"] == 1
    with pytest.raises(ValueError, match="sha256"):
        store.get("not-a-digest")
    with pytest.raises(ValueError, match="sha256"):
        store.put("nope", {})


def test_store_ignores_partial_and_corrupt_files(tmp_path):
    """Atomicity contract from the read side: truncated JSON, foreign
    schema versions, and digest-mismatched envelopes are all misses —
    never exceptions, never served."""
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    store.put(spec, {"apps": {}})
    records = os.path.join(store.root, "records")

    # a crashed writer's partial file under another digest's final path
    bad = "1" * 64
    with open(os.path.join(records, f"{bad}.json"), "w") as f:
        f.write('{"schema": 1, "record": {"apps"')     # truncated
    assert store.get(bad) is None
    assert store.stats()["corrupt"] >= 1

    # unknown schema version
    worse = "2" * 64
    with open(os.path.join(records, f"{worse}.json"), "w") as f:
        json.dump({"schema": SCHEMA_VERSION + 99, "spec_digest": worse,
                   "record": {}}, f)
    assert store.get(worse) is None

    # envelope that misrecords its own digest (e.g. renamed file)
    liar = "3" * 64
    with open(os.path.join(records, f"{liar}.json"), "w") as f:
        json.dump({"schema": SCHEMA_VERSION, "spec_digest": "4" * 64,
                   "record": {}}, f)
    assert store.get(liar) is None

    # the good record still loads; tmp droppings aren't listed (the
    # digest-named corrupt files are — listing is by name, loading is
    # what validates)
    assert store.get(spec) is not None
    with open(os.path.join(records, ".tmp-zzz.json"), "w") as f:
        f.write("{")
    listed = set(store.digests())
    assert spec.digest() in listed and len(listed) == 4
    assert ".tmp-zzz" not in {d[:8] for d in listed}


def test_store_hardware_index_enumerates_knob_variants(tmp_path):
    """Execution-knob variants of one hardware share hardware_digest();
    the secondary index returns all of them."""
    store = ResultStore(str(tmp_path / "s"))
    base = InterconnectSpec(**SMOKE)
    variants = [base.replace(route_strategy="python"),
                base.replace(route_strategy="minplus"),
                base.replace(sa_steps=10, alphas=(1.0, 2.0))]
    digests = {v.digest() for v in variants}
    assert len(digests) == 3                     # distinct addresses
    for i, v in enumerate(variants):
        store.put(v, {"i": i, "apps": {}})
    hw = base.hardware_digest()
    assert all(v.hardware_digest() == hw for v in variants)
    recs = store.for_hardware(hw)
    assert sorted(r["i"] for r in recs) == [0, 1, 2]
    assert store.for_hardware(base) == recs      # spec key accepted
    other = base.replace(num_tracks=3)
    assert store.for_hardware(other.hardware_digest()) == []


def test_store_dangling_index_marker_skipped(tmp_path):
    """The index marker is written before the record (a crash between
    the two leaves a dangling marker, never an unenumerable record);
    for_hardware must skip markers whose record never landed."""
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    store.put(spec, {"i": 0, "apps": {}})
    hw = spec.hardware_digest()
    dangling = os.path.join(store.root, "by_hardware", hw, "5" * 64)
    with open(dangling, "w"):
        pass
    recs = store.for_hardware(hw)
    assert [r["i"] for r in recs] == [0]


# ---------------------------------------------------------------------------
# Digest forward-compatibility (golden fixtures untouched)
# ---------------------------------------------------------------------------

def test_new_knobs_absent_from_canonical_json_when_default():
    spec = InterconnectSpec(**SMOKE)
    canon = json.loads(spec.canonical_json())
    for name in InterconnectSpec.DIGEST_OPTIONAL:
        assert name not in canon
    # ...but serialize once set, and round-trip
    pinned = spec.replace(sa_steps=30, alphas=(1.0, 2.0), reg_penalty=2.0)
    canon = json.loads(pinned.canonical_json())
    assert canon["sa_steps"] == 30 and canon["alphas"] == [1.0, 2.0]
    assert InterconnectSpec.from_json(pinned.to_json()) == pinned
    assert pinned.digest() != spec.digest()
    assert pinned.hardware_digest() == spec.hardware_digest()


def test_folded_knobs_leave_golden_fixture_valid():
    """The acceptance gate in miniature: digests recorded before the PnR
    knobs existed still verify — growing the spec never drifted them."""
    with open(FIXTURE) as f:
        golden = json.load(f)
    assert InterconnectSpec(**SMOKE).digest() == \
        golden["stock_4x4"]["spec_digest"]


def test_spec_from_kwargs_accepts_folded_knobs():
    spec = spec_from_kwargs(width=4, height=4, num_tracks=2,
                            reg_penalty=2.0, alphas=[1.0, 4.0],
                            sa_steps=25, sa_batch=4, seed=7,
                            split_fifo_ctrl_delay=0.1)
    assert spec.reg_penalty == 2.0 and spec.alphas == (1.0, 4.0)
    assert spec.sa_steps == 25 and spec.seed == 7


def test_with_execution_defaults_fills_only_unset():
    spec = InterconnectSpec(sa_steps=10, **SMOKE)
    r = spec.with_execution_defaults(sa_steps=99, seed=3, alphas=(2.0,))
    assert r.sa_steps == 10                      # spec wins
    assert r.seed == 3 and r.alphas == (2.0,)    # unset filled
    with pytest.raises(TypeError, match="not execution knobs"):
        spec.with_execution_defaults(width=9)


def test_executor_init_knobs_deprecated_pointing_at_spec():
    with pytest.warns(DeprecationWarning, match="spec .*'sa_steps'"):
        SweepExecutor(apps={}, sa_steps=30)
    with pytest.warns(DeprecationWarning, match="'reg_penalty'"):
        SweepExecutor(apps={}, reg_penalty=2.0)


def test_sweep_functions_do_not_warn_on_sa_steps():
    """The sweep functions' per-call sa_steps is their documented
    convenience contract — routing it through the executor default must
    not trip the __init__ deprecation (empty grid: construction only)."""
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        recs = sweep_num_tracks((), apps={"pw": lambda: app_pointwise(1)},
                                width=4, height=4, sa_steps=20)
    assert recs == []


# ---------------------------------------------------------------------------
# Store-backed SweepExecutor
# ---------------------------------------------------------------------------

def test_warm_sweep_recomputes_nothing(tmp_path):
    """THE acceptance criterion: a repeated sweep_num_tracks against a
    warm store performs zero PnR recomputation, asserted via the store
    hit counters, and serves identical records."""
    store = ResultStore(str(tmp_path / "s"))
    tracks = (2, 3)
    cold_ex = _executor(store, max_workers=2)
    cold = sweep_num_tracks(tracks, width=4, height=4, executor=cold_ex)
    assert cold_ex.pnr_computations == len(tracks)
    assert cold_ex.store_hits == 0

    warm_ex = _executor(ResultStore(str(tmp_path / "s")), max_workers=2)
    warm = sweep_num_tracks(tracks, width=4, height=4, executor=warm_ex)
    assert warm_ex.pnr_computations == 0         # zero PnR on warm store
    assert warm_ex.store_hits == len(tracks)
    assert warm_ex.store_misses == 0
    for c, w in zip(cold, warm):
        assert c["spec_digest"] == w["spec_digest"]
        assert c["num_tracks"] == w["num_tracks"]
        assert c["sb_area"] == w["sb_area"]
        assert c["apps"]["pw"]["emulation"]["out_checksum"] == \
            w["apps"]["pw"]["emulation"]["out_checksum"]


def test_store_mismatched_context_is_a_miss(tmp_path):
    """A record computed without emulation (or for different apps) must
    not satisfy an executor that needs more — it is recomputed."""
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    ex0 = _executor(store, emulate_cycles=0)
    ex0.run_point(spec)
    assert ex0.pnr_computations == 1

    ex1 = _executor(store)                        # wants emulation now
    rec = ex1.run_point(spec)
    assert ex1.store_misses == 1 and ex1.pnr_computations == 1
    assert "emulation" in rec["apps"]["pw"]

    ex2 = _executor(store)                        # same context: warm
    ex2.run_point(spec)
    assert ex2.store_hits == 1 and ex2.pnr_computations == 0

    ex3 = _executor(store, apps={"pw": lambda: app_pointwise(1),
                                 "pw2": lambda: app_pointwise(2)})
    ex3.run_point(spec)                           # different app set
    assert ex3.store_misses == 1 and ex3.pnr_computations == 1


def test_concurrent_same_digest_coalesces(tmp_path):
    """Two threads asking for the same digest: one computes, the other
    piggybacks on the in-flight future (no second PnR, no store race)."""
    store = ResultStore(str(tmp_path / "s"))
    gate = threading.Event()
    entered = threading.Event()

    def slow_app():
        entered.set()
        assert gate.wait(timeout=30)
        return app_pointwise(1)

    ex = _executor(store, apps={"pw": slow_app}, emulate_cycles=0)
    spec = InterconnectSpec(**SMOKE)
    recs = []

    def run():
        recs.append(ex.run_point(spec))

    t1 = threading.Thread(target=run)
    t1.start()
    assert entered.wait(timeout=30)               # leader inside PnR
    t2 = threading.Thread(target=run)
    t2.start()
    deadline = time.time() + 30                  # follower parked on the
    while not ex._inflight and time.time() < deadline:  # in-flight future
        time.sleep(0.01)
    gate.set()
    t1.join(timeout=60)
    t2.join(timeout=60)
    assert len(recs) == 2
    assert ex.pnr_computations == 1
    assert ex.coalesced + ex.store_hits == 1      # follower never computed
    assert recs[0]["spec_digest"] == recs[1]["spec_digest"]


def test_record_usable_accepts_deeper_emulation(tmp_path):
    """A stored record emulated for >= the requested cycles is a hit
    (the documented 'at least the requested emulation' contract); less
    emulation — or none recorded — stays a miss."""
    ex = _executor(ResultStore(str(tmp_path / "s")), emulate_cycles=6)
    rec = {"apps": {"pw": {}}, "emulate_cycles": 10}
    assert ex.record_usable(rec)
    assert ex.record_usable(dict(rec, emulate_cycles=6))
    assert not ex.record_usable(dict(rec, emulate_cycles=4))
    assert not ex.record_usable(dict(rec, emulate_cycles=None))
    ex0 = _executor(ResultStore(str(tmp_path / "s0")), emulate_cycles=0)
    assert ex0.record_usable({"apps": {"pw": {}}})


def test_store_deeper_emulation_serves_shallower_request(tmp_path):
    """Executors alternating emulate_cycles against one store converge on
    the deepest record instead of thrashing overwrites: a record emulated
    for 8 cycles serves a 4-cycle request with zero recomputation."""
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    ex8 = _executor(store, emulate_cycles=8)
    ex8.run_point(spec)
    assert ex8.pnr_computations == 1

    ex4 = _executor(store, emulate_cycles=4)
    rec = ex4.run_point(spec)
    assert ex4.store_hits == 1 and ex4.pnr_computations == 0
    assert rec["emulate_cycles"] == 8             # the stored, deeper run


def test_concurrent_run_points_own_their_pending_futures(tmp_path):
    """High-severity regression: with two run_points calls sharing one
    executor, each run joins exactly its own deferred emulation futures.
    Sweep B must return with its emulation merged while never popping
    (and awaiting, or orphaning) sweep A's still-pending future."""
    import itertools

    counter = itertools.count(1)
    count_lock = threading.Lock()
    gate = threading.Event()
    a_second_point = threading.Event()

    def mk():
        with count_lock:
            n = next(counter)
        if n == 2:                # sweep A's second point: park mid-run
            a_second_point.set()
            assert gate.wait(timeout=60)
        return app_pointwise(1)

    ex = _executor(ResultStore(str(tmp_path / "s")), apps={"pw": mk},
                   max_workers=1)
    assert ex.pipeline_emulation and ex.emulate_cycles > 0
    a_points = [(InterconnectSpec(**SMOKE), {}),
                (InterconnectSpec(**dict(SMOKE, num_tracks=4)), {})]
    b_points = [(InterconnectSpec(**dict(SMOKE, num_tracks=3)), {})]
    a_recs = []
    a_thread = threading.Thread(
        target=lambda: a_recs.extend(ex.run_points(a_points)))
    a_thread.start()
    try:
        # A has dispatched point 1's emulation and is parked inside
        # point 2's PnR; run sweep B to completion underneath it
        assert a_second_point.wait(timeout=120)
        b_recs = ex.run_points(b_points)
        assert "emulation" in b_recs[0]["apps"]["pw"]  # B joined its own
        assert a_thread.is_alive()                     # A still mid-run
        # B's join-own must have left A's point-1 future on the global
        # list (the old join-all popped it, handing A's future to B and
        # letting a sibling return records with emulation in flight)
        assert ex._pending
    finally:
        gate.set()
        a_thread.join(timeout=300)
    assert not a_thread.is_alive()
    assert len(a_recs) == 2
    for rec in a_recs:
        assert "emulation" in rec["apps"]["pw"]
    assert not ex._pending                             # A drained its own


def test_same_digest_coalesces_through_emulation_tail(tmp_path):
    """The in-flight entry survives until the deferred emulation (and
    its store write-back) lands: a same-digest request arriving in that
    tail coalesces onto the leader's record instead of missing the
    still-unwritten store and redoing PnR + emulation."""
    gate = threading.Event()
    ex = _executor(ResultStore(str(tmp_path / "s")))
    real = ex._emulate_batch

    def parked(fab, routed, device=None, io_chunk=None):
        out = real(fab, routed, device=device, io_chunk=io_chunk)
        assert gate.wait(timeout=60)
        return out

    ex._emulate_batch = parked
    spec = InterconnectSpec(**SMOKE)
    rec = ex.run_point(spec, defer_emulation=True)
    assert ex._inflight                           # alive through the tail
    follower = threading.Thread(target=lambda: ex.run_point(spec))
    follower.start()
    time.sleep(0.2)                               # let it reach the wait
    gate.set()
    follower.join(timeout=120)
    ex.join_pending()
    assert ex.pnr_computations == 1               # follower never computed
    # a late-scheduled follower may instead find the written-back store
    # record; either way the tail never triggers a recompute
    assert ex.coalesced + ex.store_hits == 1
    assert "emulation" in rec["apps"]["pw"]
    assert not ex._inflight and not ex._pending


def test_save_json_dedupes_repeated_sweeps(tmp_path):
    """Satellite fix: repeated sweep_* calls on one executor used to
    accumulate and re-persist overlapping records."""
    ex = _executor(ResultStore(str(tmp_path / "s")), emulate_cycles=0)
    tracks = (2, 3)
    sweep_num_tracks(tracks, width=4, height=4, executor=ex)
    sweep_num_tracks(tracks, width=4, height=4, executor=ex)
    assert len(ex.records) == 2 * len(tracks)     # raw accumulation
    path = ex.save_json(str(tmp_path / "out.json"))
    with open(path) as f:
        saved = json.load(f)
    assert len(saved) == len(tracks)              # deduped view
    assert [r["num_tracks"] for r in saved] == list(tracks)


def test_resolved_digest_pins_knobs_and_shares_hardware(tmp_path):
    """resolve() fills unset knobs from the executor; two executors with
    different defaults address different records for the same bare spec,
    while their artifact caches still share the hardware digest."""
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    ex_a = _executor(store, emulate_cycles=0)
    with pytest.warns(DeprecationWarning):
        ex_b = _executor(store, emulate_cycles=0, sa_steps=10)
    ra = ex_a.resolve(spec)
    rb = ex_b.resolve(spec)
    assert ra.digest() != rb.digest()
    assert ra.sa_steps == 60 and rb.sa_steps == 10
    assert ra.hardware_digest() == rb.hardware_digest() == spec.digest()
    ex_a.run_point(spec)
    ex_b.run_point(spec)
    assert ex_b.store_hits == 0                   # distinct addresses
    assert len(store.for_hardware(spec)) == 2     # both enumerable


# ---------------------------------------------------------------------------
# DSEService
# ---------------------------------------------------------------------------

def test_service_single_and_batch_queries(tmp_path):
    svc = canal.serve(store=str(tmp_path / "s"),
                      apps={"pw": lambda: app_pointwise(1)},
                      emulate_cycles=0, use_pallas=False, max_workers=1)
    spec = InterconnectSpec(**SMOKE)
    rec = svc.query(spec)                         # single in -> dict out
    assert rec["apps"]["pw"]["success"]
    st = svc.stats()
    assert st["misses"] == 1 and st["hits"] == 0

    out = svc.query([spec, spec.replace(num_tracks=3)])
    assert isinstance(out, list) and len(out) == 2
    st = svc.stats()
    assert st["hits"] == 1 and st["misses"] == 2  # first spec warm now
    assert st["queries"] == 2 and st["specs_served"] == 3
    assert st["latency_avg_s"] > 0
    assert st["executor"]["pnr_computations"] == 2
    svc.close()


def test_service_warm_query_hits_only(tmp_path):
    root = str(tmp_path / "s")
    apps = {"pw": lambda: app_pointwise(1)}
    specs = [InterconnectSpec(**SMOKE),
             InterconnectSpec(**dict(SMOKE, num_tracks=3))]
    svc1 = canal.serve(store=root, apps=apps, emulate_cycles=0,
                       use_pallas=False, max_workers=1)
    svc1.query(specs)
    svc1.close()

    svc2 = canal.serve(store=root, apps=apps, emulate_cycles=0,
                       use_pallas=False, max_workers=1)
    out = svc2.query(specs)                       # fresh process-alike
    st = svc2.stats()
    assert st["hits"] == 2 and st["misses"] == 0
    assert st["executor"]["pnr_computations"] == 0
    assert st["hit_rate"] == 1.0
    assert [r["spec_digest"] for r in out] == [
        svc2.executor.resolve(s).digest() for s in specs]
    svc2.close()


def test_service_duplicate_specs_in_one_query(tmp_path):
    svc = canal.serve(store=str(tmp_path / "s"),
                      apps={"pw": lambda: app_pointwise(1)},
                      emulate_cycles=0, use_pallas=False, max_workers=1)
    spec = InterconnectSpec(**SMOKE)
    out = svc.query([spec, dict(SMOKE), spec])    # legacy kwargs too
    assert len(out) == 3
    assert len({r["spec_digest"] for r in out}) == 1
    assert svc.stats()["executor"]["pnr_computations"] == 1
    svc.close()


def test_service_concurrent_queries_coalesce(tmp_path):
    """Two service queries for the same cold digest in flight at once:
    exactly one computation; the other request waits on it."""
    gate = threading.Event()
    entered = threading.Event()

    def slow_app():
        entered.set()
        assert gate.wait(timeout=30)
        return app_pointwise(1)

    svc = canal.serve(store=str(tmp_path / "s"), apps={"pw": slow_app},
                      emulate_cycles=0, use_pallas=False, max_workers=1)
    spec = InterconnectSpec(**SMOKE)
    f1 = svc.submit(spec)
    assert entered.wait(timeout=30)
    f2 = svc.submit(spec)
    deadline = time.time() + 30
    while not svc._inflight and time.time() < deadline:
        time.sleep(0.01)
    gate.set()
    r1, r2 = f1.result(timeout=60), f2.result(timeout=60)
    assert r1["spec_digest"] == r2["spec_digest"]
    st = svc.stats()
    assert st["executor"]["pnr_computations"] == 1
    # the second query either coalesced on the in-flight future or (if it
    # lost the race entirely) was served from the store
    assert st["coalesced"] + st["hits"] == 1
    svc.close()


def test_service_probe_failure_resolves_claimed_futures(tmp_path):
    """A store probe raising mid-query must not leak claimed in-flight
    futures (later queries for those digests would hang on them); the
    query surfaces the error and the service recovers."""
    root = str(tmp_path / "s")
    apps = {"pw": lambda: app_pointwise(1)}
    spec = InterconnectSpec(**SMOKE)
    specs = [spec, spec.replace(num_tracks=3)]
    warm = canal.serve(store=root, apps=apps, emulate_cycles=0,
                       use_pallas=False, max_workers=1)
    warm.query(spec)                              # a record to probe
    warm.close()

    svc = canal.serve(store=root, apps=apps, emulate_cycles=0,
                      use_pallas=False, max_workers=1)
    svc.executor.record_usable = \
        lambda rec: (_ for _ in ()).throw(TypeError("malformed record"))
    with pytest.raises(TypeError, match="malformed record"):
        svc.query(specs)
    assert not svc._inflight                      # nothing leaked
    del svc.executor.record_usable                # fault clears
    recs = svc.query(specs)
    assert all(r["apps"]["pw"]["success"] for r in recs)
    svc.close()


def test_service_cold_point_probes_store_exactly_once(tmp_path):
    """Regression (the double-probe bug): a cold query used to probe
    the store in the service AND again inside run_point — two disk
    reads and two miss increments per cold point. The probe verdict is
    now threaded through (``assume_cold``), so the counters are exact:
    one store miss per cold point, one store hit per warm one."""
    svc = canal.serve(store=str(tmp_path / "s"),
                      apps={"pw": lambda: app_pointwise(1)},
                      emulate_cycles=0, use_pallas=False, max_workers=1)
    specs = [InterconnectSpec(**SMOKE),
             InterconnectSpec(**dict(SMOKE, num_tracks=3))]
    svc.query(specs)
    assert svc.store.stats()["misses"] == len(specs)   # not 2x
    assert svc.store.stats()["hits"] == 0
    assert svc.executor.store_misses == len(specs)
    assert svc.executor.store_hits == 0
    svc.query(specs)
    assert svc.store.stats()["misses"] == len(specs)   # unchanged
    assert svc.store.stats()["hits"] == len(specs)
    assert svc.executor.store_hits == len(specs)
    assert svc.executor.pnr_computations == len(specs)
    svc.close()


def test_store_put_merges_app_records():
    """Unit contract of the ping-pong fix: put() on an existing digest
    unions app maps (newest wins per app), stamps per-app
    emulate_cycles claims, and recomputes the frontier metrics."""
    from repro.core.store import merge_records, record_metrics
    old = {"apps": {"a": {"success": True, "critical_path_ns": 2.0},
                    "b": {"success": False,
                          "critical_path_ns": float("inf")}},
           "emulate_cycles": 8, "sb_area": 10.0, "cb_area": 5.0,
           "metrics": record_metrics(
               {"apps": {}, "sb_area": 10.0, "cb_area": 5.0})}
    new = {"apps": {"b": {"success": True, "critical_path_ns": 3.0},
                    "c": {"success": True, "critical_path_ns": 1.0}},
           "emulate_cycles": 4, "sb_area": 10.0, "cb_area": 5.0}
    merged = merge_records(old, new)
    assert set(merged["apps"]) == {"a", "b", "c"}
    assert merged["apps"]["b"]["success"]              # newest wins
    assert merged["apps"]["a"]["emulate_cycles"] == 8  # old claim kept
    assert merged["apps"]["b"]["emulate_cycles"] == 4
    assert merged["emulate_cycles"] == 4               # top-level: newest
    m = merged["metrics"]
    assert m["routability"] == 1.0 and m["area"] == 15.0
    assert m["critical_path_ns"] == 3.0
    # the caller's dicts were not mutated
    assert "emulate_cycles" not in new["apps"]["b"]
    assert "c" not in old["apps"]


def test_store_alternating_app_sets_converge(tmp_path):
    """Regression (the app-set ping-pong bug): executors with different
    app sets sharing one store used to overwrite each other's records
    for the same digest forever — every lookup a miss, every miss a
    recompute. put() now merges, so after one computation per app set
    the record covers the union and both executor kinds hit."""
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    apps_a = {"pw": lambda: app_pointwise(1)}
    apps_b = {"pw2": lambda: app_pointwise(2)}
    ex_a = _executor(store, apps=apps_a)
    ex_b = _executor(store, apps=apps_b)
    ex_a.run_point(spec)
    ex_b.run_point(spec)
    assert ex_a.pnr_computations == 1 and ex_b.pnr_computations == 1

    # alternate fresh executors of both kinds: all hits, zero PnR —
    # the old last-writer-wins store would miss every single one
    for apps, names in ((apps_a, {"pw"}), (apps_b, {"pw2"}),
                        (apps_a, {"pw"}), (apps_b, {"pw2"})):
        ex = _executor(store, apps=apps)
        rec = ex.run_point(spec)
        assert ex.pnr_computations == 0 and ex.store_hits == 1
        assert set(rec["apps"]) == names        # filtered view
        assert "emulation" in rec["apps"][next(iter(names))]
    digest = ex_a.resolve(spec).digest()
    assert set(store.get(digest)["apps"]) == {"pw", "pw2"}

    # an executor wanting the union is also served by the merged record
    ex_ab = _executor(store, apps=dict(apps_a, **apps_b))
    ex_ab.run_point(spec)
    assert ex_ab.pnr_computations == 0 and ex_ab.store_hits == 1


def test_store_concurrent_alternating_app_sets(tmp_path):
    """The merge under concurrency: threads alternating two app sets
    against one shared store object converge to the union record with
    exactly one PnR per app set (coalescing + merge, no thrash)."""
    store = ResultStore(str(tmp_path / "s"))
    spec = InterconnectSpec(**SMOKE)
    apps_a = {"pw": lambda: app_pointwise(1)}
    apps_b = {"pw2": lambda: app_pointwise(2)}
    ex_a = _executor(store, apps=apps_a)
    ex_b = _executor(store, apps=apps_b)
    errs = []

    def run(ex):
        try:
            ex.run_point(spec)
        except BaseException as e:                # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=run, args=(ex,))
               for ex in (ex_a, ex_b, ex_a, ex_b)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
    assert not errs
    # per executor: one computation total (its duplicate request hit
    # the store or coalesced), never one per alternation
    assert ex_a.pnr_computations <= 1 and ex_b.pnr_computations <= 1
    digest = ex_a.resolve(spec).digest()
    assert set(store.get(digest)["apps"]) == {"pw", "pw2"}
    # convergence: fresh executors of both kinds are pure hits
    for apps in (apps_a, apps_b):
        ex = _executor(store, apps=apps)
        ex.run_point(spec)
        assert ex.pnr_computations == 0 and ex.store_hits == 1


def test_canal_serve_is_the_front_door(tmp_path):
    from repro.serve.dse_service import DSEService
    svc = canal.serve(store=str(tmp_path / "s"), apps={},
                      emulate_cycles=0, use_pallas=False)
    assert isinstance(svc, DSEService)
    assert svc.store.root == str(tmp_path / "s")
    svc.close()
