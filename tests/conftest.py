import os
import sys

# smoke tests and benches see 1 device (the dry-run sets 512 itself,
# in a subprocess)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
