import os
import sys

# smoke tests and benches see 1 device (the dry-run sets 512 itself,
# in a subprocess)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Pinned hypothesis profile for reproducible CI runs: derandomized (fixed
# seed), no per-example deadline (Pallas interpret + scan tracing dwarf the
# default 200ms budget). The _hypothesis_compat shim is deterministic by
# construction, so this only applies when the real engine is installed.
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", deadline=None, derandomize=True, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("ci")
except ModuleNotFoundError:
    pass
