"""Golden end-to-end test: app -> pack -> place -> route -> bitstream ->
fabric emulation matches the software dataflow semantics."""
import numpy as np
import pytest

from repro.core.bitstream import BitstreamCodec, deserialize, serialize
from repro.core.edsl import create_uniform_interconnect
from repro.core.lowering import compile_interconnect
from repro.core.pnr import place_and_route
from repro.core.pnr.app import app_pointwise, app_tree_reduce
from repro.core.pnr.packing import pack
from repro.fabric import AppEmulator


@pytest.fixture(scope="module")
def setup():
    ic = create_uniform_interconnect(width=6, height=6, num_tracks=4,
                                     sb_type="wilton", io_ring=True,
                                     reg_density=1.0)
    fab = compile_interconnect(ic)
    return ic, fab


def test_pointwise_chain(setup):
    ic, fab = setup
    app = app_pointwise(3)              # out = in + 1 + 2 + 3
    packed = pack(app)
    r = place_and_route(ic, app, alphas=(2.0,), sa_steps=50, sa_batch=8)
    assert r.success, r.error
    emu = AppEmulator.from_pnr(fab, packed, r)
    T = 16
    x = np.arange(20, 20 + T).astype(np.int32)
    outs = emu.run({r.placement["in0"]: x}, T)
    y = outs[r.placement["out0"]]
    nz = np.nonzero(y)[0]
    assert len(nz), "no output observed"
    lat = nz[0]
    np.testing.assert_array_equal(y[lat:lat + 8], x[:8] + 6)


def test_tree_reduce(setup):
    ic, fab = setup
    app = app_tree_reduce(4)
    packed = pack(app)
    r = place_and_route(ic, app, alphas=(2.0,), sa_steps=50, sa_batch=8)
    assert r.success, r.error
    emu = AppEmulator.from_pnr(fab, packed, r)
    T = 16
    ins = {r.placement[f"in{i}"]: np.full(T, 7 * (i + 1), np.int32)
           for i in range(4)}
    outs = emu.run(ins, T)
    assert outs[r.placement["out0"]][-1] == 7 * (1 + 2 + 3 + 4)


def test_bitstream_words_reproduce_route(setup):
    """Route -> words -> decode -> same fabric behaviour."""
    ic, fab = setup
    app = app_pointwise(2)
    packed = pack(app)
    r = place_and_route(ic, app, alphas=(2.0,), sa_steps=40, sa_batch=8)
    assert r.success
    codec = BitstreamCodec(fab)
    words = codec.words_for_route(r.route_edges())
    config_direct = fab.route_to_config(r.route_edges())
    config_decoded = codec.decode(deserialize(serialize(words)))
    np.testing.assert_array_equal(config_direct, config_decoded)
