"""Device-accelerated PathFinder (two-level routing, ``strategy="minplus"``).

Three contracts:

1. the batched min-plus cost fields are *exact* — equal to host Dijkstra
   over the same coarse weights — for random interconnects and random
   congestion histories (property test);
2. the fields are admissible lower bounds of the fine routed cost, so
   device-routed trees pass the existing legality/congestion checks
   bit-identically to the Python router's own invariants (capacity,
   endpoint exclusivity, connected route trees) with delays within
   margin;
3. the engine plumbing holds: per-tile field memoization, the
   ``(ic, reg_penalty)``-keyed resources cache, and ``auto`` dispatch.
"""
import functools
import heapq

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.edsl import SwitchBoxType, create_uniform_interconnect
from repro.core.pnr import place_and_route
from repro.core.pnr.app import BENCH_APPS
from repro.core.pnr.route import COARSE_INF, RoutingResources, route_nets


@functools.lru_cache(maxsize=None)
def _setup(width, height, num_tracks, reg_density=1.0):
    ic = create_uniform_interconnect(width=width, height=height,
                                     num_tracks=num_tracks,
                                     sb_type=SwitchBoxType.WILTON,
                                     io_ring=True,
                                     reg_density=reg_density)
    return ic, RoutingResources(ic)


def _dijkstra_to_sink(w: np.ndarray, sink: int) -> np.ndarray:
    """Host oracle: cost from every tile TO ``sink`` over dense coarse
    weights (runs on the transposed graph, like the device field)."""
    n = w.shape[0]
    dist = np.full(n, COARSE_INF, np.float64)
    dist[sink] = 0.0
    pq = [(0.0, sink)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u] + 1e-12:
            continue
        for v in range(n):
            wd = w[v, u]                       # edge v -> u, walking back
            if wd >= COARSE_INF:
                continue
            nd = d + wd
            if nd < dist[v] - 1e-12:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist


@given(st.sampled_from([(4, 4, 2), (5, 4, 3), (6, 6, 2)]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_minplus_fields_equal_dijkstra(dims, seed):
    """Batched device min-plus fixpoint == per-sink host Dijkstra on the
    congestion-weighted coarse graph of a random interconnect."""
    w_, h_, t_ = dims
    _, res = _setup(w_, h_, t_)
    coarse = res.coarse()
    rng = np.random.default_rng(seed)
    hist = rng.integers(0, 4, len(res.nodes)).astype(np.float64)
    sinks = rng.choice(len(res.nodes), size=3, replace=False)
    fields = coarse.sink_cost_fields(res, [int(s) for s in sinks],
                                     hist, hist_w=0.4)
    w = coarse.lower_bound_weights(res.base * (1.0 + 0.4 * hist))
    refund = np.where(coarse.is_exit,
                      coarse.exit_toll[coarse.tile_of], 0.0)
    for s in sinks:
        want_tiles = _dijkstra_to_sink(w, int(coarse.tile_of[s]))
        want = np.maximum(want_tiles[coarse.tile_of] - refund, 0.0)
        got = fields[int(s)]
        np.testing.assert_allclose(np.minimum(got, COARSE_INF),
                                   np.minimum(want, COARSE_INF),
                                   rtol=1e-5, atol=1e-5)


def test_base_field_memoized_across_calls():
    """Iteration-0 (history-free) fields are cached per sink tile: a
    second request must not touch the device again (rows are identical
    objects)."""
    _, res = _setup(4, 4, 2)
    coarse = res.coarse()
    hist = np.zeros(len(res.nodes))
    f1 = coarse.sink_cost_fields(res, [0], hist, 0.4)
    assert coarse._base_rows          # populated
    tile = int(coarse.tile_of[0])
    row_cached = coarse._base_rows[tile]
    f2 = coarse.sink_cost_fields(res, [0], hist, 0.4)
    assert coarse._base_rows[tile] is row_cached
    np.testing.assert_array_equal(f1[0], f2[0])


def _check_legal(result, res, capacity=1):
    """The Python router's legality invariants, applied to any result:
    per-node capacity, tree-connectivity of every net, exact endpoints."""
    usage = {}
    for net in result.nets:
        for nid in net.nodes_used():
            usage.setdefault(nid, set()).add(net.name)
    shared = {n: v for n, v in usage.items() if len(v) > capacity}
    assert not shared, f"overused nodes: {shared}"
    for net in result.nets:
        for sink in net.sinks:
            node, hops = sink, 0
            while node != net.src:
                assert node in net.tree, f"{net.name}: {sink} disconnected"
                parent = net.tree[node]
                assert (parent, node) in res.edge_delay_map, \
                    f"{net.name}: tree edge {parent}->{node} not in IR"
                node = parent
                hops += 1
                assert hops <= len(res.nodes), "tree cycle"


@pytest.mark.parametrize("app_name", ["fir", "tree_reduce"])
def test_minplus_routes_legal_and_delay_equivalent(app_name):
    """Device-routed trees pass the same legality/congestion checks as
    the Python oracle's, with delays in a tight band around the oracle:
    the admissible fields keep path costs optimal up to the bounded hop
    bias, and the bias prefers fewer-hop trees, so delays may only be
    equal or better beyond a 10% premium ceiling."""
    ic, res = _setup(6, 6, 4)
    results = {}
    for strat in ("python", "minplus"):
        r = place_and_route(ic, BENCH_APPS[app_name](), alphas=(2.0,),
                            sa_steps=40, sa_batch=8, resources=res,
                            route_strategy=strat)
        assert r.success, (strat, r.error)
        _check_legal(r.routing, res)
        results[strat] = r
    py, mp = results["python"], results["minplus"]
    assert mp.routing.iterations <= py.routing.iterations + 2
    # equal-cost tie-breaking may pick a different representative tree:
    # allow strictly better delays, bound any regression at 10%
    assert mp.timing["critical_path_ns"] <= \
        py.timing["critical_path_ns"] * 1.10 + 1e-9
    for net_py, net_mp in zip(py.routing.nets, mp.routing.nets):
        assert net_mp.delay <= net_py.delay * 1.10 + 0.25


def test_minplus_detects_unroutable_like_python():
    """Coarse-unreachable pruning must not mask real failures: Disjoint
    under track pressure fails on both engines (§4.2.1)."""

    ic = create_uniform_interconnect(
        width=8, height=8, num_tracks=4, sb_type=SwitchBoxType.DISJOINT,
        io_ring=True, reg_density=1.0, cb_track_fc=0.5, sb_track_fc=0.5)
    from repro.core.pnr.app import app_butterfly
    outcomes = {}
    for strat in ("python", "minplus"):
        r = place_and_route(ic, app_butterfly(3), alphas=(2.0,),
                            sa_steps=30, sa_batch=8, route_iters=10,
                            route_strategy=strat)
        outcomes[strat] = r.success
    assert outcomes["python"] == outcomes["minplus"]


def test_route_nets_auto_strategy_dispatch():
    """auto == python below the tile threshold, minplus at/above it —
    and both produce a result on a trivial net set."""
    from repro.core.pnr.route import _AUTO_MIN_TILES, _resolve_strategy

    _, small = _setup(4, 4, 2)
    assert small.coarse().n_tiles < _AUTO_MIN_TILES
    assert _resolve_strategy(small, "auto") == "python"
    _, big = _setup(8, 8, 2)
    assert big.coarse().n_tiles >= _AUTO_MIN_TILES
    assert _resolve_strategy(big, "auto") == "minplus"
    with pytest.raises(Exception):
        _resolve_strategy(small, "frobnicate")


def test_routing_resources_o_e_build_consistency():
    """The fan-in-position build must reproduce the IR exactly: every
    adjacency entry's delay equals the destination's edge_delay + delay,
    and edge_delay_map covers every edge."""
    _, res = _setup(4, 4, 2)
    n_edges = 0
    for i, nbrs in enumerate(res.adj):
        src_node = res.nodes[i]
        for j, d in nbrs:
            dst = res.nodes[j]
            k = dst.fan_in.index(src_node)
            assert d == dst.edge_delay_in[k] + dst.delay
            assert res.edge_delay_map[(i, j)] == dst.edge_delay_in[k]
            n_edges += 1
    assert n_edges == len(res.edge_delay_map)


def test_executor_resources_cache_keyed_on_reg_penalty():
    """The stale-cache hazard: same interconnect, different register
    penalty must hand back different RoutingResources; same penalty hits
    the shared cache."""
    from repro.core.dse import SweepExecutor

    ex = SweepExecutor(apps={}, max_workers=1)
    kw = dict(width=4, height=4, num_tracks=2, io_ring=True,
              reg_density=1.0)
    ic = ex.interconnect(**kw)
    key = ex._key(kw)
    r1 = ex.resources(ic, key)
    r2 = ex.resources(ic, key)
    assert r1 is r2
    r3 = ex.resources(ic, key, reg_penalty=0.0)
    assert r3 is not r1
    assert r3.reg_penalty == 0.0 and r1.reg_penalty == 4.0
    assert ex.resources(ic, key, reg_penalty=0.0) is r3


def test_exit_toll_disabled_when_crossings_land_on_exits():
    """Admissibility guard: on a graph where crossing destinations are
    themselves exits (every node both entry and exit, e.g. a chip
    torus), a tile can be transited through one node and the transit
    toll would double-charge it — the coarse graph must drop the toll
    there, and the fields must still match Dijkstra."""
    from repro.core.graph import Node

    class _N(Node):
        def node_key(self):
            return ("N", self.x, self.y)

    class _FakeIC:
        def __init__(self, nodes):
            self._nodes = nodes

        def nodes(self):
            return iter(self._nodes)

    nodes = [_N(x, y, 0, 16, delay=0.1) for x in range(3) for y in range(2)]
    for a in nodes:
        for b in nodes:
            if a is not b and abs(a.x - b.x) + abs(a.y - b.y) == 1:
                a.add_edge(b, delay=1.0)
    res = RoutingResources(_FakeIC(nodes), reg_penalty=0.0)
    coarse = res.coarse()
    assert coarse.is_exit.all()
    assert (coarse.exit_toll == 0.0).all()
    hist = np.zeros(len(res.nodes))
    fields = coarse.sink_cost_fields(res, [0], hist, 0.4)
    w = coarse.lower_bound_weights(res.base)
    want = _dijkstra_to_sink(w, int(coarse.tile_of[0]))
    np.testing.assert_allclose(fields[0], want[coarse.tile_of],
                               rtol=1e-5, atol=1e-5)
    # on the SB fabrics the precondition holds and the toll stays active
    _, sb_res = _setup(4, 4, 2)
    sbc = sb_res.coarse()
    assert not sbc.is_exit[sbc.e_dst_node].any()
    assert (sbc.exit_toll[np.unique(sbc.e_src_tile)] > 0.0).all()


def test_ici_router_still_green_on_python_path():
    """The ICI pod-fabric reuses route_nets with capacities; the refactor
    must keep that consumer working (torus coords, fake IC)."""
    from repro.core.ici import route_traffic_canal

    flows = [((0, 0), (1, 1)), ((1, 0), (0, 1)), ((0, 1), (1, 0))]
    result, usage = route_traffic_canal(2, 2, flows, lanes=2)
    assert len(result.nets) == len(flows)
    assert int(usage.max()) <= 2
