"""Training substrate: loss, grad accumulation, optimizers, data, ckpt,
fault tolerance, compression."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data import SyntheticTokens
from repro.models import build_model
from repro.optim import adafactor, adamw
from repro.train.step import (init_train_state, loss_fn, make_train_step,
                              train_state_specs)


@pytest.fixture(scope="module")
def tiny():
    cfg = get_smoke("tinyllama_1_1b").replace(ce_seq_chunk=16)
    model = build_model(cfg)
    return cfg, model


def rand_batch(cfg, b=4, s=32, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(3, cfg.vocab_size - 1, (b, s + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(t[:, :-1]),
            "labels": jnp.asarray(t[:, 1:])}


def test_chunked_ce_matches_naive(tiny):
    cfg, model = tiny
    params = model.init_params(jax.random.PRNGKey(0))
    batch = rand_batch(cfg)
    loss, metrics = loss_fn(model, params, batch)
    logits = model.logits(params, batch)
    logp = jax.nn.log_softmax(
        jnp.where(jnp.arange(cfg.padded_vocab)[None, None]
                  < cfg.vocab_size, logits, -1e30), -1)
    naive = -jnp.take_along_axis(logp, batch["labels"][..., None],
                                 -1).mean()
    np.testing.assert_allclose(float(loss), float(naive), rtol=2e-3)


def test_loss_decreases(tiny):
    cfg, model = tiny
    opt = adamw(3e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt))
    batch = rand_batch(cfg)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_grad_accumulation_equivalence(tiny):
    """microbatches=2 must match the full-batch gradient step closely."""
    cfg, model = tiny
    opt = adamw(1e-3)
    batch = rand_batch(cfg)
    s1 = init_train_state(model, opt, jax.random.PRNGKey(0))
    s2 = init_train_state(model, opt, jax.random.PRNGKey(0))
    st1, _ = jax.jit(make_train_step(model, opt, microbatches=1))(s1,
                                                                  batch)
    st2, _ = jax.jit(make_train_step(model, opt, microbatches=2))(s2,
                                                                  batch)
    a = jax.tree.leaves(st1.params)
    b = jax.tree.leaves(st2.params)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=5e-2)


def test_adafactor_trains_and_is_lean(tiny):
    cfg, model = tiny
    opt = adafactor(3e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    # factored second moment: opt state much smaller than adamw's
    n_params = sum(p.size for p in jax.tree.leaves(state.params))
    n_f32 = sum(v.size for v in jax.tree.leaves(state.opt)
                if v.dtype == jnp.float32)
    assert n_f32 < 0.25 * n_params
    step = jax.jit(make_train_step(model, opt))
    batch = rand_batch(cfg)
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_state_specs_match_structure(tiny):
    cfg, model = tiny
    for opt in (adamw(1e-3), adafactor(1e-3)):
        state = jax.eval_shape(
            lambda rng: init_train_state(model, opt, rng),
            jax.random.PRNGKey(0))
        specs = train_state_specs(model, opt)
        from jax.sharding import PartitionSpec as P
        assert (jax.tree.structure(state)
                == jax.tree.structure(jax.tree.map(
                    lambda s: 0, specs,
                    is_leaf=lambda x: isinstance(x, P))))


# ---------------------------------------------------------------- data
def test_data_determinism_and_sharding():
    ds0 = SyntheticTokens(1000, 64, 8, seed=1, process_index=0,
                          process_count=2)
    ds1 = SyntheticTokens(1000, 64, 8, seed=1, process_index=1,
                          process_count=2)
    a = ds0.batch(5)
    b = ds0.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])   # determinism
    c = ds1.batch(5)
    assert not np.array_equal(a["tokens"], c["tokens"])       # disjoint
    assert a["tokens"].shape == (4, 64)
    # labels are next-token shifted
    full0 = ds0.batch(7)
    assert (full0["tokens"][:, 1:] == full0["labels"][:, :-1]).all()


# ---------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip(tmp_path, tiny):
    from repro.ckpt import CheckpointManager
    cfg, model = tiny
    opt = adamw(1e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(10, state, blocking=True)
    mgr.save(20, state._replace(step=state.step + 20), blocking=True)
    mgr.save(30, state._replace(step=state.step + 30), blocking=True)
    assert mgr.available_steps() == [20, 30]       # keep=2 gc'd step 10
    restored, step = mgr.restore_latest(like=state)
    assert step == 30
    assert int(restored.step) == 30
    for x, y in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_detects_mismatch(tmp_path, tiny):
    from repro.ckpt import CheckpointManager
    cfg, model = tiny
    opt = adamw(1e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(1, like={"different": jnp.zeros(3)})


# ------------------------------------------------------------- runtime
def test_supervisor_restarts_from_checkpoint(tmp_path, tiny):
    from repro.ckpt import CheckpointManager
    from repro.runtime import Supervisor
    cfg, model = tiny
    opt = adamw(1e-3)
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt))
    ds = SyntheticTokens(cfg.vocab_size, 32, 4, seed=0)

    fail_at = {12}

    def injector(step):
        if step in fail_at:
            fail_at.discard(step)
            return RuntimeError("injected chip failure")
        return None

    sup = Supervisor(
        step_fn=step_fn,
        batch_fn=lambda s: {k: jnp.asarray(v)
                            for k, v in ds.batch(s).items()},
        ckpt=CheckpointManager(str(tmp_path)), ckpt_every=5,
        failure_injector=injector)
    final = sup.run(state, start_step=0, num_steps=20)
    assert int(final.step) == 20
    events = [h["event"] for h in sup.history]
    assert "restart" in events
    # steps 10..12 re-executed after restore from step 10
    steps_run = [h["step"] for h in sup.history if h["event"] == "step"]
    assert steps_run.count(11) == 2


def test_straggler_monitor():
    from repro.runtime import StragglerMonitor
    mon = StragglerMonitor(n_hosts=8, evict_after=3)
    times = np.ones(8)
    times[3] = 3.0
    reports = [mon.observe(times) for _ in range(4)]
    assert 3 in reports[-1]["stragglers"]
    assert 3 in reports[-1]["evict"]
    frac = reports[-1]["batch_fractions"]
    assert frac[3] < 1.0 / 8          # slow host gets less work
    np.testing.assert_allclose(frac.sum(), 1.0)


def test_int8_compression_error_feedback():
    from repro.runtime.compression import (ErrorFeedback, int8_compress,
                                           int8_decompress)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32))
    q, s = int8_compress(g)
    deq = int8_decompress(q, s)
    rel = float(jnp.linalg.norm(deq - g) / jnp.linalg.norm(g))
    assert rel < 0.02
    # error feedback: accumulated compressed updates converge to the truth
    res = ErrorFeedback.init({"g": g})
    total = jnp.zeros_like(g)
    for _ in range(20):
        comp, res = ErrorFeedback.apply({"g": g}, res)
        total = total + comp["g"]
    np.testing.assert_allclose(np.asarray(total / 20), np.asarray(g),
                               atol=1e-3)
