"""Per-kernel interpret-mode validation against the pure-jnp oracles,
sweeping shapes and dtypes (assignment requirement)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,f", [(64, 2), (700, 6), (1500, 9)])
def test_fabric_sweep(n, f):
    rng = np.random.default_rng(n)
    vals = jnp.asarray(rng.integers(0, 1000, n + 1).astype(np.int32))
    src = jnp.asarray(rng.integers(0, n + 1, (n, f)).astype(np.int32))
    sel = jnp.asarray(rng.integers(0, f, n).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.fabric_sweep(vals, src, sel)),
        np.asarray(ref.fabric_sweep_ref(vals, src, sel)))


@pytest.mark.parametrize("b", [1, 5, 9])
def test_fabric_sweep_batch(b):
    rng = np.random.default_rng(b)
    n, f = 300, 4
    vals = jnp.asarray(rng.integers(0, 99, (b, n + 1)).astype(np.int32))
    src = jnp.asarray(rng.integers(0, n + 1, (n, f)).astype(np.int32))
    sel = jnp.asarray(rng.integers(0, f, (b, n)).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(ops.fabric_sweep_batch(vals, src, sel)),
        np.asarray(ref.fabric_sweep_batch_ref(vals, src, sel)))


@given(st.integers(1, 10), st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_fabric_fused_batch_vs_oracle(b, seed):
    """Fused fixpoint kernel (gather-form PE placement, per-lane depth
    masking) vs the scatter-form pure-jnp oracle on random tables."""
    rng = np.random.default_rng(seed)
    n, f, n_pe, max_depth = 150, 3, 6, 7
    p = n_pe
    vals0 = rng.integers(0, 1000, (b, n)).astype(np.int32)
    sel = rng.integers(0, f, (b, n)).astype(np.int32)
    pin_mask = (rng.random(n) < 0.2).astype(np.int32)
    pin_vals = np.where(pin_mask[None, :] > 0, vals0, 0).astype(np.int32)
    depths = rng.integers(0, max_depth + 1, b).astype(np.int32)
    op = rng.integers(0, 14, (b, p)).astype(np.int32)
    const = rng.integers(0, 1000, (b, p)).astype(np.int32)
    imm_mask = (rng.random((b, p, 4)) < 0.25).astype(np.int32)
    imm_val = rng.integers(0, 1000, (b, p, 4)).astype(np.int32)
    src = rng.integers(0, n + 1, (n, f)).astype(np.int32)
    keep = (rng.random(n) < 0.15).astype(np.int32)
    pe_in = rng.integers(0, n + 1, (p, 4)).astype(np.int32)
    # distinct PE output nodes, kept un-pinned so both forms agree on
    # evaluation order (PE eval runs after pinning)
    out_nodes = rng.choice(n, size=2 * p, replace=False).astype(np.int32)
    pin_mask[out_nodes] = 0
    pe_out = out_nodes.reshape(p, 2)
    pe_res_idx = np.full(n, 2 * p, np.int32)
    for k_ in range(p):
        pe_res_idx[pe_out[k_, 0]] = 2 * k_
        pe_res_idx[pe_out[k_, 1]] = 2 * k_ + 1
    args = [jnp.asarray(x) for x in
            (vals0, sel, pin_vals, depths, op, const, imm_mask, imm_val,
             src, keep, pin_mask)]
    np.testing.assert_array_equal(
        np.asarray(ops.fabric_fused_batch(
            *args, jnp.asarray(pe_in), jnp.asarray(pe_res_idx),
            max_depth=max_depth)),
        np.asarray(ref.fabric_fused_batch_ref(
            *args, jnp.asarray(pe_in), jnp.asarray(pe_out),
            max_depth=max_depth)))


@given(st.integers(1, 400), st.integers(1, 9), st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_hpwl_property(n_nets, k, seed):
    rng = np.random.default_rng(seed)
    pins = jnp.asarray(rng.integers(0, 64, (n_nets, k, 2))
                       .astype(np.int32))
    mask = jnp.asarray((rng.random((n_nets, k)) < 0.7).astype(np.int32))
    got = np.asarray(ops.hpwl(pins, mask))
    want = np.asarray(ref.hpwl_ref(pins, mask))
    np.testing.assert_array_equal(got, want)
    assert (got >= 0).all()


@given(st.integers(1, 300), st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_net_bboxes_property(n_nets, k, seed):
    rng = np.random.default_rng(seed)
    pins = jnp.asarray(rng.integers(0, 64, (n_nets, k, 2))
                       .astype(np.int32))
    # sparse mask so fully-empty rows actually occur
    mask = jnp.asarray((rng.random((n_nets, k)) < 0.5).astype(np.int32))
    got = np.asarray(ops.net_bboxes(pins, mask))
    want = np.asarray(ref.net_bboxes_ref(pins, mask))
    np.testing.assert_array_equal(got, want)
    # bbox spans reproduce the HPWL kernel's reduction
    span = (got[:, 1] - got[:, 0]) + (got[:, 3] - got[:, 2])
    np.testing.assert_array_equal(span, np.asarray(ops.hpwl(pins, mask)))


def test_hpwl_empty_net_rows():
    """All-masked rows contribute zero HPWL and a zero bbox."""
    pins = jnp.asarray(np.arange(3 * 4 * 2, dtype=np.int32)
                       .reshape(3, 4, 2))
    mask = jnp.asarray(np.array([[1, 1, 0, 0],
                                 [0, 0, 0, 0],
                                 [1, 0, 1, 1]], np.int32))
    got = np.asarray(ops.hpwl(pins, mask))
    assert got[1] == 0
    np.testing.assert_array_equal(got, np.asarray(ref.hpwl_ref(pins, mask)))
    boxes = np.asarray(ops.net_bboxes(pins, mask))
    np.testing.assert_array_equal(boxes[1], np.zeros(4, np.int32))


def test_pack_nets_overflow():
    from repro.kernels.hpwl import pack_nets

    pin_net = [0, 0, 0]
    pin_xy = [(0, 0), (1, 1), (2, 2)]
    pins, mask = pack_nets(pin_net, pin_xy, n_nets=1, k_max=4)
    assert pins.shape == (1, 4, 2) and int(mask.sum()) == 3
    with pytest.raises(ValueError, match="exceeds"):
        pack_nets(pin_net, pin_xy, n_nets=1, k_max=2)


@pytest.mark.parametrize("n,b", [(64, 1), (200, 4), (300, 2)])
def test_minplus(n, b):
    rng = np.random.default_rng(n + b)
    d = jnp.asarray((rng.random((b, n)) * 10).astype(np.float32))
    w = np.where(rng.random((n, n)) < 0.05, rng.random((n, n)) * 3, 1e30)
    np.fill_diagonal(w, 0.0)
    w = jnp.asarray(w.astype(np.float32))
    np.testing.assert_allclose(np.asarray(ops.minplus_step(d, w)),
                               np.asarray(ref.minplus_ref(d, w)),
                               rtol=1e-5)


def test_minplus_fixpoint_is_shortest_path():
    """Iterated relaxation on a line graph gives hop-count distances."""
    n = 16
    w = np.full((n, n), 1e30, np.float32)
    np.fill_diagonal(w, 0.0)
    for i in range(n - 1):
        w[i, i + 1] = 1.0
    d0 = np.full((1, n), 1e30, np.float32)
    d0[0, 0] = 0.0
    out = np.asarray(ops.minplus_fixpoint(jnp.asarray(d0),
                                          jnp.asarray(w), n))
    np.testing.assert_allclose(out[0], np.arange(n, dtype=np.float32))


@pytest.mark.parametrize("engine", ["pallas", "ref"])
def test_minplus_wavefront_converges_to_bellman_ford(engine):
    """The adaptive wavefront (early-exit blocks) equals the full
    Bellman-Ford bound on a random sparse graph, on both engines."""
    from repro.kernels.minplus import minplus_wavefront

    n, b = 96, 3
    rng = np.random.default_rng(7)
    w = np.where(rng.random((n, n)) < 0.06, rng.random((n, n)) * 3 + 0.1,
                 3e37).astype(np.float32)
    np.fill_diagonal(w, 0.0)
    d0 = np.full((b, n), 3e37, np.float32)
    d0[np.arange(b), [0, 5, 11]] = 0.0
    got = np.asarray(minplus_wavefront(jnp.asarray(d0), jnp.asarray(w),
                                       engine=engine, interpret=True))
    want = np.asarray(ref.minplus_fixpoint_ref(jnp.asarray(d0),
                                               jnp.asarray(w), n - 1))
    np.testing.assert_allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("sq,skv,hq,hkv,dtype", [
    (128, 128, 4, 4, jnp.float32),
    (200, 200, 4, 2, jnp.float32),
    (256, 256, 8, 1, jnp.bfloat16),
    (130, 384, 2, 2, jnp.float32),
])
def test_flash_attention(sq, skv, hq, hkv, dtype):
    rng = np.random.default_rng(sq + skv)
    b, d = 2, 64
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)),
                    dtype=dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    kk = jnp.repeat(k, hq // hkv, 1)
    vv = jnp.repeat(v, hq // hkv, 1)
    want = ref.attention_ref(
        q.reshape(b * hq, sq, d).astype(jnp.float32),
        kk.reshape(b * hq, skv, d).astype(jnp.float32),
        vv.reshape(b * hq, skv, d).astype(jnp.float32),
        causal=True).reshape(b, hq, sq, d)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("l,chunk,p,n", [
    (128, 64, 8, 4), (256, 128, 16, 8), (100, 32, 4, 4),
])
def test_ssd_scan(l, chunk, p, n):
    rng = np.random.default_rng(l)
    bh = 3
    x = jnp.asarray(rng.standard_normal((bh, l, p)).astype(np.float32))
    dt = jnp.asarray((0.1 + rng.random((bh, l)) * 0.5).astype(np.float32))
    a = jnp.asarray((-0.5 - rng.random(bh)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((bh, l, n)).astype(np.float32)
                    * 0.3)
    c = jnp.asarray(rng.standard_normal((bh, l, n)).astype(np.float32)
                    * 0.3)
    out = ops.ssd_scan(x, dt, a, b, c, chunk=chunk)
    want = ref.ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_ssd_xla_path_matches_ref():
    """The models' jnp chunked SSD (used when attn_impl='xla') must match
    the naive recurrence too."""
    from repro.models.layers import _ssd_xla
    rng = np.random.default_rng(0)
    bh, l, p, n = 2, 96, 8, 4
    x = jnp.asarray(rng.standard_normal((bh, l, p)).astype(np.float32))
    dt = jnp.asarray((0.1 + rng.random((bh, l)) * 0.5).astype(np.float32))
    a = jnp.asarray((-0.5 - rng.random(bh)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((bh, l, n)).astype(np.float32)
                    * 0.3)
    c = jnp.asarray(rng.standard_normal((bh, l, n)).astype(np.float32)
                    * 0.3)
    got = _ssd_xla(x, dt, a, b, c, chunk=32)
    want = ref.ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)
