"""Loop-aware HLO cost model: the roofline's foundation."""
import pytest

import jax
import jax.numpy as jnp

from repro.roofline.hlo_cost import HloCostModel
from repro.roofline.hlo_parse import link_traffic_bytes, parse_collectives
from repro.roofline.analysis import roofline_terms


def _cost(f, *args):
    c = jax.jit(f).lower(*args).compile()
    return HloCostModel(c.as_text()).totals()


def test_scan_equals_unroll_flops():
    """The whole point: XLA's cost_analysis counts loop bodies once; the
    loop-aware model must make scanned == unrolled."""

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    def unrolled(x, w):
        for _ in range(12):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a = _cost(scanned, x, w)
    b = _cost(unrolled, x, w)
    assert a["flops"] == pytest.approx(b["flops"], rel=1e-6)
    expected = 2 * 64 * 256 * 256 * 12
    assert a["flops"] == pytest.approx(expected, rel=1e-6)


def test_nested_scan_multiplies():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _2):
                return ci @ w, None
            ci, _2 = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    t = _cost(nested, x, w)
    assert t["flops"] == pytest.approx(2 * 32 * 128 * 128 * 15, rel=1e-6)


def test_dus_fusion_counts_slice_not_buffer():
    """Scan stash writes must count the slice, not the carried buffer."""

    def stash(x, w):
        def body(c, _):
            y = jnp.tanh(c @ w)
            return y, y                     # stacked output = stash
        _, ys = jax.lax.scan(body, x, None, length=50)
        return ys

    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    t = _cost(stash, x, w)
    # legit traffic: 50 x dot (operands+out ~ 73KB) + 50 x 2 x 4KB slice
    # writes. Counting the full (50,8,128) buffer per iteration would add
    # 50 x 200KB = 10MB — assert we stay well under that.
    dot_b = 50 * (8 * 128 + 128 * 128 + 8 * 128) * 4
    slice_b = 8 * 128 * 4
    assert t["bytes"] < dot_b + 60 * 4 * slice_b
    assert t["bytes"] < 6e6


def test_collective_parse():
    hlo = """
ENTRY %main {
  %ar = bf16[1024,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag = f32[2048]{0} all-gather(%y), replica_groups=[8,4]<=[32]
}
"""
    recs = parse_collectives(hlo)
    assert len(recs) == 2
    ar = next(r for r in recs if r["kind"] == "all-reduce")
    assert ar["bytes"] == 1024 * 512 * 2
    assert ar["group"] == 4
    total, by_kind = link_traffic_bytes(recs)
    assert by_kind["all-reduce"] == pytest.approx(
        2 * 0.75 * 1024 * 512 * 2)


def test_roofline_terms_dominance():
    t = roofline_terms(per_device_flops=1e15, per_device_hbm_bytes=1e11,
                       per_chip_link_bytes=1e9)
    assert t["dominant"] == "compute_s"
    assert 0 < t["roofline_fraction"] <= 1.0
    t2 = roofline_terms(1e12, 1e13, 1e9)
    assert t2["dominant"] == "memory_s"
