"""Static backend: structural verification, config sweep, route delivery."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.edsl import create_uniform_interconnect
from repro.core.graph import IO, NodeKind, Side
from repro.core.lowering import compile_interconnect
from repro.core.verify import verify, verify_structural


@pytest.fixture(scope="module")
def small_ic():
    return create_uniform_interconnect(width=4, height=4, num_tracks=2,
                                       sb_type="wilton", io_ring=True,
                                       reg_density=1.0)


@pytest.fixture(scope="module")
def fabric(small_ic):
    return compile_interconnect(small_ic)


def test_structural_equivalence(small_ic, fabric):
    verify_structural(small_ic, fabric)


def test_config_sweep(small_ic, fabric):
    report = verify(small_ic, fabric)
    assert report["connections_checked"] > 500


def manual_east_route(ic, y=1, track=0):
    g = ic.graph(16)
    edges = []
    port = g.get_port(0, y, "io_out")
    sb_out = g.get_sb(0, y, Side.EAST, track, IO.SB_OUT)
    edges.append((port, sb_out))
    cur = sb_out
    w = ic.dims()[0]
    for x in range(1, w):
        rmux = [n for n in cur.fan_out if n.kind == NodeKind.REG_MUX][0]
        reg = [n for n in cur.fan_out if n.kind == NodeKind.REGISTER][0]
        edges += [(cur, reg), (reg, rmux)]
        sb_in = rmux.fan_out[0]
        edges.append((rmux, sb_in))
        if x < w - 1:
            nxt = g.get_sb(x, y, Side.EAST, track, IO.SB_OUT)
            edges.append((sb_in, nxt))
            cur = nxt
        else:
            edges.append((sb_in, g.get_port(x, y, "io_in")))
    return edges


def test_registered_route_delivers_with_latency(small_ic, fabric):
    edges = manual_east_route(small_ic)
    config = jnp.asarray(fabric.route_to_config(edges))
    io_idx = {c: i for i, c in enumerate(fabric.io_coords)}
    T = 10
    ext = np.zeros((T, fabric.num_io), np.int32)
    ext[:, io_idx[(0, 1)]] = np.arange(100, 100 + T)
    out = np.asarray(fabric.run(config, jnp.asarray(ext), depth=12))
    got = out[:, io_idx[(3, 1)]]
    lat = np.nonzero(got)[0][0]
    assert lat == 3                       # one register per hop
    assert list(got[lat:]) == list(range(100, 100 + T - lat))


def test_conflicting_route_rejected(small_ic, fabric):
    edges = manual_east_route(small_ic)
    g = small_ic.graph(16)
    # drive the same SB_OUT from a second source: conflicting mux select
    sb_out = g.get_sb(0, 1, Side.EAST, 0, IO.SB_OUT)
    other_src = [n for n in sb_out.fan_in
                 if n is not edges[0][0]][0]
    with pytest.raises(ValueError, match="conflict"):
        fabric.route_to_config(edges + [(other_src, sb_out)])


def test_pallas_fabric_sweep_matches_xla(small_ic):
    """use_pallas=True swaps the sweep for the Pallas kernel (interpret)."""
    fab_ref = compile_interconnect(small_ic, use_pallas=False)
    fab_pal = compile_interconnect(small_ic, use_pallas=True)
    edges = manual_east_route(small_ic)
    config = jnp.asarray(fab_ref.route_to_config(edges))
    io_idx = {c: i for i, c in enumerate(fab_ref.io_coords)}
    T = 6
    ext = np.zeros((T, fab_ref.num_io), np.int32)
    ext[:, io_idx[(0, 1)]] = np.arange(7, 7 + T)
    a = np.asarray(fab_ref.run(config, jnp.asarray(ext), depth=10))
    b = np.asarray(fab_pal.run(config, jnp.asarray(ext), depth=10))
    assert np.array_equal(a, b)
