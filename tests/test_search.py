"""Search-driven DSE: the SearchSpace axes, Pareto machinery, selector
policies, the search() driver (acceptance: greedy matches the best
grid-sweep point with fewer evaluations, repeats are zero-PnR), the
DSEService.recommend verb, and the canal.search CLI."""
import json
import random

import pytest
from _hypothesis_compat import given, settings, st

import canal
from repro.core.dse import SweepExecutor, sweep_num_tracks
from repro.core.pnr.app import app_pointwise
from repro.core.search import (SearchSpace, SelectorKind, dominates,
                               make_selector, pareto_frontier, search)
from repro.core.search.pareto import (Evaluated, best_point,
                                      objective_value, point_metrics,
                                      satisfies)
from repro.core.spec import (InterconnectSpec, SwitchBoxType,
                             mutate_spec, neighbor_specs, spec_axes)
from repro.core.store import ResultStore, record_metrics

BASE = InterconnectSpec(width=4, height=4, num_tracks=4, io_ring=True,
                        sb_type=SwitchBoxType.WILTON, reg_density=1.0,
                        cb_track_fc=1.0, sb_track_fc=1.0)


def _ev(digest, area, delay, routability, valid=True):
    return Evaluated(spec=BASE, digest=str(digest), record={},
                     metrics={"area": area, "critical_path_ns": delay,
                              "routability": routability}, valid=valid)


# ---------------------------------------------------------------------------
# Axis helpers (spec.py)
# ---------------------------------------------------------------------------

def test_spec_axes_validates_and_canonicalizes():
    axes = spec_axes(BASE, {"num_tracks": [2, 3, 3, 2],
                            "sb_type": ["wilton", "disjoint"]})
    assert axes["num_tracks"] == (2, 3)           # deduped, ordered
    assert axes["sb_type"] == (SwitchBoxType.WILTON,
                               SwitchBoxType.DISJOINT)
    with pytest.raises(TypeError, match="unknown spec axis"):
        spec_axes(BASE, {"num_trax": [2]})
    with pytest.raises(ValueError, match="num_tracks"):
        spec_axes(BASE, {"num_tracks": ["nope"]})
    with pytest.raises(ValueError, match="no values"):
        spec_axes(BASE, {"num_tracks": []})


def test_mutate_spec_moves_one_axis():
    axes = spec_axes(BASE, {"num_tracks": (2, 3, 4)})
    rng = random.Random(0)
    for _ in range(10):
        m = mutate_spec(BASE, axes, rng)
        assert m.num_tracks in (2, 3) and m != BASE
    # one-point space: unchanged
    assert mutate_spec(BASE, {"num_tracks": (4,)}, rng) == BASE


def test_neighbor_specs_adjacent_and_deterministic():
    axes = spec_axes(BASE, {"num_tracks": (2, 3, 4, 5, 6),
                            "sb_type": ("wilton", "disjoint")})
    nbrs = neighbor_specs(BASE, axes)
    assert [(n.num_tracks, n.sb_type) for n in nbrs] == [
        (3, SwitchBoxType.WILTON), (5, SwitchBoxType.WILTON),
        (4, SwitchBoxType.DISJOINT)]
    # off-axis current value: every axis value is a neighbor
    off = BASE.replace(num_tracks=9)
    nbrs = neighbor_specs(off, {"num_tracks": (2, 3)})
    assert [n.num_tracks for n in nbrs] == [2, 3]


# ---------------------------------------------------------------------------
# SearchSpace
# ---------------------------------------------------------------------------

def test_search_space_geometry():
    sp = SearchSpace(BASE, {"num_tracks": (2, 3, 4),
                            "sb_type": ("wilton", "disjoint")})
    assert sp.size() == 6 and len(sp) == 6
    grid = sp.grid()
    assert len(set(grid)) == 6
    assert all(sp.contains(s) for s in grid)
    assert not sp.contains(BASE.replace(num_tracks=9))
    assert not sp.contains(BASE.replace(width=5, num_tracks=2))
    org = sp.origin()
    assert org.num_tracks == 4                    # base value on-axis
    assert org.sb_type == SwitchBoxType.WILTON
    # base value off-axis: snaps to the middle value
    sp2 = SearchSpace(BASE, {"num_tracks": (5, 6, 7)})
    assert sp2.origin().num_tracks == 6
    with pytest.raises(ValueError, match="at least one axis"):
        SearchSpace(BASE, {})


def test_search_space_sampling_stays_in_space():
    sp = SearchSpace(BASE, {"num_tracks": (2, 3, 4)})
    rng = random.Random(1)
    for _ in range(20):
        assert sp.contains(sp.sample(rng))
        assert sp.contains(sp.mutate(sp.sample(rng), rng))


# ---------------------------------------------------------------------------
# Pareto machinery
# ---------------------------------------------------------------------------

def test_dominates_partial_order():
    a = {"area": 1.0, "critical_path_ns": 1.0, "routability": 1.0}
    b = {"area": 2.0, "critical_path_ns": 1.0, "routability": 1.0}
    c = {"area": 1.0, "critical_path_ns": 2.0, "routability": 0.5}
    assert dominates(a, b) and not dominates(b, a)
    assert dominates(a, c) and not dominates(c, a)
    assert not dominates(b, c) and not dominates(c, b)  # incomparable
    assert not dominates(a, a)                    # ties dominate nothing


def test_pareto_frontier_invariants():
    pts = [_ev(0, 10, 5, 1.0), _ev(1, 20, 5, 1.0),   # 1 dominated by 0
           _ev(2, 5, 9, 1.0),                        # tradeoff: kept
           _ev(3, 1, 1, 1.0, valid=False),           # invalid: excluded
           _ev(4, 10, 5, 1.0)]                       # metric tie: kept
    front = pareto_frontier(pts)
    assert [p.digest for p in front] == ["0", "2", "4"]


def test_best_point_constraints_and_fallback():
    pts = [_ev(0, 10, 9, 1.0), _ev(1, 20, 2, 1.0), _ev(2, 5, 1, 0.5)]
    assert best_point(pts, "area").digest == "2"
    c = {"min_routability": 1.0}
    assert best_point(pts, "area", c).digest == "0"
    assert best_point(pts, "critical_path_ns", c).digest == "1"
    tight = {"max_critical_path_ns": 0.5}
    assert best_point(pts, "area", tight) is None          # strict
    assert best_point(pts, "area", tight, strict=False).digest == "2"
    with pytest.raises(ValueError, match="unknown constraint"):
        satisfies(pts[0].metrics, {"max_delay": 1})
    with pytest.raises(ValueError, match="unknown objective"):
        objective_value(pts[0].metrics, "speed")


def test_point_metrics_prefers_stamp_and_rederives():
    rec = {"apps": {"a": {"success": True, "critical_path_ns": 2.5}},
           "sb_area": 7.0, "cb_area": 3.0}
    m = point_metrics(rec)
    assert m == {"area": 10.0, "critical_path_ns": 2.5,
                 "routability": 1.0}
    assert m == record_metrics(rec)
    stamped = dict(rec, metrics={"area": 99.0, "critical_path_ns": 1.0,
                                 "routability": 0.5})
    assert point_metrics(stamped)["area"] == 99.0


# ---------------------------------------------------------------------------
# Selectors
# ---------------------------------------------------------------------------

def test_random_selector_enumerates_small_space_exactly():
    sp = SearchSpace(BASE, {"num_tracks": (2, 3), "io_ring": (True,),
                            "sb_type": ("wilton", "disjoint")})
    sel = make_selector("random", sp, random.Random(0))
    seen = []
    while True:
        batch = sel.propose(3)
        if not batch:
            break
        seen.extend(batch)
        sel.observe([_ev(i, 1, 1, 1) for i in range(len(batch))])
    assert len(seen) == sp.size() == 4            # no dup, no miss
    assert len(set(seen)) == 4


def test_greedy_selector_walks_toward_the_optimum():
    sp = SearchSpace(BASE, {"num_tracks": (2, 3, 4, 5, 6)})
    sel = make_selector("greedy", sp, random.Random(0),
                        objective="area")
    first = sel.propose(2)
    assert [s.num_tracks for s in first] == [4]   # the origin
    # area grows with tracks: feed back and expect descent toward 2
    def feed(batch):
        evs = [Evaluated(spec=s, digest=str(s.num_tracks), record={},
                         metrics={"area": float(s.num_tracks),
                                  "critical_path_ns": 1.0,
                                  "routability": 1.0}, valid=True)
               for s in batch]
        sel.observe(evs)
    feed(first)
    second = sel.propose(2)
    assert sorted(s.num_tracks for s in second) == [3, 5]
    feed(second)
    third = sel.propose(2)
    assert [s.num_tracks for s in third] == [2]   # neighbor of 3
    feed(third)
    fourth = sel.propose(2)                       # local optimum: restart
    assert [s.num_tracks for s in fourth] == [6]  # the only unseen point
    feed(fourth)
    assert sel.propose(2) == []                   # space exhausted


def test_make_selector_rejects_unknown_kind():
    sp = SearchSpace(BASE, {"num_tracks": (2, 3)})
    with pytest.raises(ValueError, match="unknown selector"):
        make_selector("simulated-annealing", sp, random.Random(0))
    for kind in SelectorKind:
        assert make_selector(kind, sp, random.Random(0)) is not None


# ---------------------------------------------------------------------------
# search() driver on a fake executor (fast, deterministic)
# ---------------------------------------------------------------------------

class FakeExecutor:
    """Deterministic synthetic evaluator: metrics derived from the spec
    digest, ~1 in 5 points statically invalid. Counts evaluations."""

    def __init__(self):
        self.evals = 0

    def stats(self):
        return {"evaluations": self.evals}

    def run_specs(self, specs, record=False, assume_cold=False):
        recs = []
        for s in specs:
            self.evals += 1
            h = int(s.digest()[:8], 16)
            clean = h % 5 != 0
            success = clean and h % 3 != 0
            rec = {"spec_digest": s.digest(),
                   "sb_area": 10.0 + h % 7, "cb_area": float(h % 5),
                   "analysis": {"clean": clean},
                   "apps": {"a": {"success": success,
                                  "critical_path_ns":
                                      1.0 + h % 9 if success
                                      else float("inf")}}}
            if not clean:
                rec["apps"]["a"]["skipped"] = "static-analysis"
            rec["metrics"] = record_metrics(rec)
            recs.append(rec)
        return recs


@given(st.integers(0, 10 ** 6),
       st.sampled_from(["random", "greedy", "evolutionary"]),
       st.integers(1, 12))
@settings(max_examples=25, deadline=None)
def test_search_frontier_properties(seed, kind, budget):
    """The property the optimizer stands on: the returned frontier is
    mutually non-dominated, and every evaluated valid non-frontier
    point is strictly dominated by some frontier point; invalid points
    never surface; the budget is respected."""
    ex = FakeExecutor()
    res = search(BASE, {"num_tracks": (2, 3, 4, 5, 6),
                        "sb_type": ("wilton", "disjoint", "imran")},
                 selector=kind, budget=budget, batch_size=3, seed=seed,
                 executor=ex)
    assert len(res.evaluated) <= budget
    assert ex.evals == len(res.evaluated)         # driver never re-evals
    digests = [p.digest for p in res.evaluated]
    assert len(set(digests)) == len(digests)      # dedup held
    front = res.frontier
    assert all(p.valid for p in front)
    for p in front:
        assert not any(dominates(q.metrics, p.metrics) for q in front)
    in_front = {id(p) for p in front}
    for p in res.evaluated:
        if p.valid and id(p) not in in_front:
            assert any(dominates(q.metrics, p.metrics) for q in front)
    assert res.stats["evaluated"] == len(res.evaluated)
    assert res.stats["statically_invalid"] == \
        sum(1 for p in res.evaluated if not p.valid)


def test_search_same_seed_reproduces():
    runs = [search(BASE, {"num_tracks": (2, 3, 4, 5, 6)},
                   selector="evolutionary", budget=5, batch_size=2,
                   seed=7, executor=FakeExecutor())
            for _ in range(2)]
    assert [p.digest for p in runs[0].evaluated] == \
        [p.digest for p in runs[1].evaluated]


def test_search_argument_validation():
    with pytest.raises(TypeError, match="base \\+ axes"):
        search(selector="random", executor=FakeExecutor())
    sp = SearchSpace(BASE, {"num_tracks": (2, 3)})
    with pytest.raises(TypeError, match="not both"):
        search(BASE, {"num_tracks": (2,)}, space=sp,
               executor=FakeExecutor())
    with pytest.raises(ValueError, match="budget"):
        search(space=sp, budget=0, executor=FakeExecutor())
    with pytest.raises(TypeError, match="prebuilt executor"):
        search(space=sp, executor=FakeExecutor(), store="x")


# ---------------------------------------------------------------------------
# Acceptance: search vs the sweep_num_tracks grid, store-backed
# ---------------------------------------------------------------------------

def _grid_best(recs):
    routed = [r for r in recs
              if all(a["success"] for a in r["apps"].values())]
    return min(routed, key=lambda r: r["sb_area"] + r["cb_area"])


def test_greedy_search_matches_grid_best_with_fewer_evals(tmp_path):
    """THE acceptance criterion: greedy search over the
    sweep_num_tracks axis lands on the same best design point as the
    exhaustive grid while evaluating fewer candidates, and an identical
    re-run against the warm store performs zero new PnR."""
    apps = {"pw": lambda: app_pointwise(1)}
    tracks = (2, 3, 4, 5, 6)
    grid_ex = SweepExecutor(apps=apps, store=ResultStore(
        str(tmp_path / "grid")), emulate_cycles=0, use_pallas=False,
        max_workers=1)
    grid = sweep_num_tracks(tracks, width=4, height=4, executor=grid_ex)
    best_grid = _grid_best(grid)
    assert grid_ex.pnr_computations == len(tracks)

    store = str(tmp_path / "search")
    res = search(BASE, {"num_tracks": tracks}, selector="greedy",
                 objective="area",
                 constraints={"min_routability": 1.0},
                 budget=4, batch_size=2, seed=0, store=store,
                 apps=apps, use_pallas=False, max_workers=1)
    best = res.best("area", {"min_routability": 1.0})
    assert best is not None
    assert best.digest == best_grid["spec_digest"]     # same optimum
    assert len(res.evaluated) < len(tracks)            # fewer evals
    assert res.stats["executor"]["pnr_computations"] == \
        len(res.evaluated)

    res2 = search(BASE, {"num_tracks": tracks}, selector="greedy",
                  objective="area",
                  constraints={"min_routability": 1.0},
                  budget=4, batch_size=2, seed=0, store=store,
                  apps=apps, use_pallas=False, max_workers=1)
    assert res2.stats["executor"]["pnr_computations"] == 0  # zero PnR
    assert res2.stats["executor"]["store_hits"] == len(res2.evaluated)
    assert res2.best("area", {"min_routability": 1.0}).digest == \
        best.digest


def test_evolutionary_search_finds_grid_best(tmp_path):
    """The evolutionary selector also lands on the grid optimum on the
    single-axis space (random first generation covers it; the Pareto
    archive keeps it)."""
    apps = {"pw": lambda: app_pointwise(1)}
    res = search(BASE, {"num_tracks": (2, 3, 4)}, selector="evolutionary",
                 objective="area",
                 constraints={"min_routability": 1.0},
                 budget=3, batch_size=3, seed=0,
                 store=str(tmp_path / "s"), apps=apps,
                 use_pallas=False, max_workers=1)
    best = res.best("area", {"min_routability": 1.0})
    assert best is not None and best.spec.num_tracks == 2


def test_recommend_serving_verb(tmp_path):
    """DSEService.recommend: the cache is a recommendation engine —
    and its second recommendation is pure store hits."""
    svc = canal.serve(store=str(tmp_path / "s"),
                      apps={"pw": lambda: app_pointwise(1)},
                      emulate_cycles=0, use_pallas=False, max_workers=1)
    out = svc.recommend(BASE, {"num_tracks": [2, 3]},
                        objective="area",
                        constraints={"min_routability": 1.0},
                        budget=2, batch_size=2, selector="random")
    assert out["best"] is not None
    assert out["best"]["spec"]["num_tracks"] == 2
    assert out["frontier"] and out["stats"]["evaluated"] == 2
    again = svc.recommend(BASE, {"num_tracks": [2, 3]},
                          objective="area",
                          constraints={"min_routability": 1.0},
                          budget=2, batch_size=2, selector="random")
    assert again["stats"]["executor"]["pnr_computations"] == 0
    assert again["best"]["digest"] == out["best"]["digest"]
    svc.close()


def test_cli_emits_frontier_json(tmp_path):
    from repro.core.search.cli import run
    out = tmp_path / "frontier.json"
    code = run(["--width", "5", "--axes", '{"num_tracks": [2, 3]}',
                "--selector", "random", "--budget", "2", "--batch", "2",
                "--apps", "pointwise", "--seed", "0",
                "--store", str(tmp_path / "store"), "-o", str(out)])
    assert code == 0
    doc = json.loads(out.read_text())
    assert len(doc["frontier"]) >= 1
    assert doc["stats"]["evaluated"] == 2
    assert doc["best"] is not None
    assert doc["space"]["size"] == 2
    # warm re-run: zero PnR, still a frontier
    code = run(["--width", "5", "--axes", '{"num_tracks": [2, 3]}',
                "--selector", "greedy", "--budget", "2", "--batch", "2",
                "--apps", "pointwise", "--seed", "0",
                "--store", str(tmp_path / "store"), "-o", str(out)])
    assert code == 0
    doc = json.loads(out.read_text())
    assert doc["stats"]["executor"]["pnr_computations"] == 0


def test_cli_usage_errors(tmp_path):
    from repro.core.search.cli import run
    with pytest.raises(SystemExit) as e:
        run(["--axes", "not json"])
    assert e.value.code == 2
    with pytest.raises(SystemExit) as e:
        run(["--axes", '{"num_trax": [1]}', "--no-store"])
    assert e.value.code == 2
    with pytest.raises(SystemExit) as e:
        run(["--axes", '{"num_tracks": [2]}', "--apps", "nope"])
    assert e.value.code == 2


def test_load_bench_skips_null_metrics(tmp_path, monkeypatch):
    """Trajectory consumers must skip null metric values: a
    warm-first-pass run records ``store_warm_speedup: null`` (its ~1x
    'speedup' is meaningless next to real cold/warm measurements) and
    must not pollute medians."""
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "common.py")
    spec = importlib.util.spec_from_file_location("_bench_common", path)
    common = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(common)
    monkeypatch.setattr(common, "REPO_ROOT", str(tmp_path))
    (tmp_path / "BENCH_x.json").write_text(json.dumps([
        {"store_warm_speedup": 3000.0, "quick": True},
        {"store_warm_speedup": None, "quick": True},
        {"quick": True},
        {"store_warm_speedup": 2000.0, "quick": False}]))
    assert common.load_bench("BENCH_x", "store_warm_speedup") == \
        [3000.0, 2000.0]
    assert len(common.load_bench("BENCH_x")) == 4
    assert common.load_bench("BENCH_missing") == []
    assert common.load_bench("BENCH_missing", "anything") == []


def test_canal_front_door_exports():
    assert canal.search is not None and canal.SearchSpace is not None
    assert "search" in canal.__all__ and "SearchSpace" in canal.__all__
    sp = canal.SearchSpace(BASE, {"num_tracks": (2, 3)})
    assert sp.size() == 2
