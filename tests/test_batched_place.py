"""Differential gate for the device-resident batched annealing placer.

The ``"batched"`` engine (K parallel-tempering chains as one jitted
``lax.scan``, :mod:`repro.core.pnr.batched_anneal`) must produce *legal*
placements that route, at an Eq. 2 cost no worse than the host SA oracle
on an equal step budget, deterministically for a fixed seed — and the
``place_strategy`` knob must plumb spec-first through the compile and
DSE layers without disturbing default digests.
"""
import json
import subprocess
import sys

import pytest

from repro.core.compile import compile_spec
from repro.core.pnr.app import BENCH_APPS, app_stencil
from repro.core.pnr.batched_anneal import batched_place, eq2_cost
from repro.core.pnr.detailed_place import (detailed_place,
                                           place_auto_min_tiles_threshold,
                                           resolve_place_strategy)
from repro.core.pnr.global_place import (assign_ios, global_place,
                                         legalize)
from repro.core.pnr.packing import pack
from repro.core.spec import InterconnectSpec


def _baseline(app, width, height, mem_columns=(), seed=0):
    packed = pack(app)
    fixed = assign_ios(packed, width, height)
    cont = global_place(packed, width, height, mem_columns=mem_columns,
                        fixed=fixed, seed=seed)
    base = legalize(packed, cont, width, height,
                    mem_columns=mem_columns, io_ring=True, fixed=fixed)
    return packed, base


def _assert_legal(packed, pl, base, width, height, mem_columns=()):
    tiles = list(pl.values())
    assert len(set(tiles)) == len(tiles), "instances share a tile"
    for name, (x, y) in pl.items():
        kind = packed.placeable[name].kind
        if kind in ("pe", "mem"):
            assert 0 < x < width - 1 and 0 < y < height - 1, \
                f"{name} on the IO ring at {(x, y)}"
            if mem_columns:
                if kind == "mem":
                    assert x in mem_columns, f"mem {name} off-column"
                else:
                    assert x not in mem_columns, f"pe {name} on mem col"
        else:
            # IO instances are fixed — the anneal must not move them
            assert pl[name] == base[name], f"io {name} moved"


@pytest.mark.parametrize("width,height,mem_cols,app_name", [
    (4, 4, (2,), "stencil"),
    (8, 8, (), "butterfly"),
    (8, 8, (4,), "stencil"),
])
def test_batched_placement_legal(width, height, mem_cols, app_name):
    packed, base = _baseline(BENCH_APPS[app_name](), width, height,
                             mem_columns=mem_cols, seed=0)
    pl = batched_place(packed, base, width, height,
                       mem_columns=mem_cols, io_ring=True,
                       n_steps=60, n_chains=8, seed=0)
    _assert_legal(packed, pl, base, width, height, mem_columns=mem_cols)


def test_batched_cost_no_worse_than_host_oracle():
    """Equal step budget, equal chain population: the device chains must
    land at an Eq. 2 cost <= the host SA loop's."""
    width = height = 8
    packed, base = _baseline(BENCH_APPS["butterfly"](), width, height,
                             seed=0)
    pl_b, cost_b = batched_place(packed, base, width, height,
                                 io_ring=True, n_steps=120, n_chains=16,
                                 seed=0, return_cost=True)
    pl_h = detailed_place(packed, base, width, height, io_ring=True,
                          n_steps=120, batch=16, seed=0,
                          strategy="python")
    cost_h = eq2_cost(packed, pl_h, width, height)
    base_cost = eq2_cost(packed, base, width, height)
    assert cost_b <= cost_h + 1e-4, (cost_b, cost_h)
    assert cost_b <= base_cost + 1e-4
    # the returned cost is the true Eq. 2 cost of the placement
    assert abs(eq2_cost(packed, pl_b, width, height) - cost_b) < 1e-3


def test_batched_placement_routes():
    """The winning chain's placement must be routable on the fine IR."""
    spec = InterconnectSpec(width=8, height=8, num_tracks=5,
                            io_ring=True, mem_columns=(4,),
                            place_strategy="batched", sa_steps=60,
                            sa_batch=8, seed=0)
    r = compile_spec(spec).place_and_route(app_stencil())
    assert r.success, r.error
    assert r.place_strategy == "batched"
    assert r.routing is not None and len(r.routing.nets) > 0


_DETERMINISM_SNIPPET = """
import json, sys
from repro.core.pnr.app import BENCH_APPS
from repro.core.pnr.batched_anneal import batched_place
from repro.core.pnr.global_place import assign_ios, global_place, legalize
from repro.core.pnr.packing import pack
packed = pack(BENCH_APPS["fir"]())
fixed = assign_ios(packed, 8, 8)
cont = global_place(packed, 8, 8, fixed=fixed, seed=0)
base = legalize(packed, cont, 8, 8, io_ring=True, fixed=fixed)
pl = batched_place(packed, base, 8, 8, io_ring=True, n_steps=40,
                   n_chains=8, seed=7)
print(json.dumps(sorted((k, list(v)) for k, v in pl.items())))
"""


def test_batched_seeded_determinism_across_processes():
    """place_strategy="batched" with a fixed spec.seed is bit-identical
    across fresh interpreter processes (pure jax.random fold-in chain)."""
    outs = []
    for _ in range(2):
        p = subprocess.run([sys.executable, "-c", _DETERMINISM_SNIPPET],
                           capture_output=True, text=True, check=True)
        outs.append(json.loads(p.stdout.strip().splitlines()[-1]))
    assert outs[0] == outs[1]


def test_place_strategy_resolution():
    assert resolve_place_strategy(36, "python") == "python"
    assert resolve_place_strategy(36, "batched") == "batched"
    thr = place_auto_min_tiles_threshold()
    assert resolve_place_strategy(thr, "auto") == "batched"
    assert resolve_place_strategy(thr - 1, "auto") == "python"
    assert resolve_place_strategy(
        100, "auto", auto_min_tiles=101) == "python"
    with pytest.raises(ValueError, match="placement strategy"):
        resolve_place_strategy(36, "simulated")


def test_place_auto_threshold_env(monkeypatch):
    monkeypatch.setenv("CANAL_PLACE_AUTO_MIN_TILES", "9")
    assert place_auto_min_tiles_threshold() == 9
    assert resolve_place_strategy(9, "auto") == "batched"
    # a malformed env var falls back to the module default
    from repro.core.pnr.detailed_place import _PLACE_AUTO_MIN_TILES
    monkeypatch.setenv("CANAL_PLACE_AUTO_MIN_TILES", "not-an-int")
    assert place_auto_min_tiles_threshold() == _PLACE_AUTO_MIN_TILES
    # explicit override beats the env var
    assert place_auto_min_tiles_threshold(explicit=3) == 3


def test_spec_place_strategy_validation_and_digest():
    with pytest.raises(ValueError, match="place_strategy"):
        InterconnectSpec(width=4, height=4, place_strategy="anneal")
    a = InterconnectSpec(width=8, height=8)
    b = InterconnectSpec(width=8, height=8, place_strategy=None)
    c = InterconnectSpec(width=8, height=8, place_strategy="batched")
    # default-valued knob is digest-invisible (golden fixtures stable)
    assert a.digest() == b.digest()
    assert "place_strategy" not in a.canonical_json()
    assert c.digest() != a.digest()
    # ...but it is an execution knob: same hardware either way
    assert c.hardware_digest() == a.hardware_digest()


def test_executor_resolve_folds_place_strategy():
    from repro.core.dse import SweepExecutor
    ex = SweepExecutor(apps={"stencil": app_stencil},
                       place_strategy="batched", store=False)
    spec = ex.resolve(InterconnectSpec(width=6, height=6))
    assert spec.place_strategy == "batched"
    # a point that pins its own engine wins over the executor default
    pinned = InterconnectSpec(width=6, height=6, place_strategy="python")
    assert ex.resolve(pinned).place_strategy == "python"
