"""Property-test layer: real ``hypothesis`` when installed, a tiny
deterministic fallback otherwise.

The seed container does not ship ``hypothesis``; hard imports made three
test modules fail *collection* (taking the whole suite down). Importing
``given``/``settings``/``st`` from here keeps the property tests running
everywhere: with ``hypothesis`` (see requirements-dev.txt) the real engine
shrinks failures; without it, each ``@given`` test is driven with
``max_examples`` pseudo-random draws from a per-test deterministic seed.
Only the strategies the suite uses (``integers``, ``sampled_from``) are
shimmed.
"""
from __future__ import annotations

import functools
import inspect
import zlib

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:                       # pragma: no cover - env
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _StrategiesShim:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            items = list(elements)
            return _Strategy(
                lambda rng: items[int(rng.integers(len(items)))])

    st = _StrategiesShim()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            n = getattr(fn, "_max_examples", 10)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            # like hypothesis: trailing parameters are drawn, leading ones
            # (pytest fixtures) pass through
            split = len(params) - len(strats)
            drawn_names = [p.name for p in params[split:]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = {name: s.sample(rng)
                             for name, s in zip(drawn_names, strats)}
                    fn(*args, **kwargs, **drawn)
            # pytest must only see the fixture parameters; the drawn ones
            # would be mistaken for fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=params[:split])
            return wrapper
        return deco
