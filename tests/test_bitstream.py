"""Bitstream codec round-trips (§3.3)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bitstream import (BitstreamCodec, ConfigWord, deserialize,
                                  serialize)
from repro.core.edsl import create_uniform_interconnect
from repro.core.lowering import compile_interconnect


@pytest.fixture(scope="module")
def codec():
    ic = create_uniform_interconnect(width=3, height=3, num_tracks=2,
                                     io_ring=True, reg_density=1.0)
    fab = compile_interconnect(ic)
    return BitstreamCodec(fab)


def test_roundtrip_zero(codec):
    config = np.zeros(codec.fabric.num_config, np.int32)
    words = codec.encode(config)
    assert words == []                      # zeros elided
    assert np.array_equal(codec.decode(words), config)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_roundtrip_random(codec, seed):
    rng = np.random.default_rng(seed)
    fab = codec.fabric
    config = np.array([rng.integers(0, max(s.fanin, 1))
                       for s in fab.config_slots], np.int32)
    words = codec.encode(config)
    back = codec.decode(words)
    assert np.array_equal(back, config)
    # wire-format roundtrip too
    assert np.array_equal(codec.decode(deserialize(serialize(words))),
                          config)


def test_unknown_address_rejected(codec):
    with pytest.raises(ValueError, match="unknown config address"):
        codec.decode([ConfigWord(0xFFFFFFF0, 1)])


def test_out_of_range_select_rejected(codec):
    fab = codec.fabric
    config = np.zeros(fab.num_config, np.int32)
    config[0] = 1
    w = codec.encode(config)[0]
    bad = ConfigWord(w.addr, 255)
    with pytest.raises(ValueError, match="out of range"):
        codec.decode([bad])


def test_addresses_are_unique(codec):
    fab = codec.fabric
    config = np.array([max(s.fanin - 1, 0) for s in fab.config_slots],
                      np.int32)
    words = codec.encode(config)
    addrs = [w.addr for w in words]
    assert len(addrs) == len(set(addrs))
