"""Spec-golden gate: digests of the stock configs are committed as
fixtures, so any silent drift in spec serialization (cache-key breakage)
or in the compiled IR (connectivity / mux-input-order / config-semantics
drift) fails CI loudly.

If a change is *intentional* (new spec field, deliberate IR change),
regenerate the fixture:

    PYTHONPATH=src python tests/test_spec_golden.py --regen
"""
import json
import os
import sys

import pytest

from repro.configs.cgra_amber import FULL, smoke
from repro.core.passes import PassManager, ir_digest
from repro.core.spec import InterconnectSpec

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "spec_digests.json")

#: the stock design points pinned by the golden fixture. amber_full's IR
#: is not built here (32x32x5 is benchmark-scale); its spec digest still
#: guards serialization drift.
GOLDEN_SPECS = {
    "stock_4x4": InterconnectSpec(width=4, height=4, num_tracks=2,
                                  io_ring=True, reg_density=1.0),
    "stock_8x8": InterconnectSpec(width=8, height=8, num_tracks=5,
                                  io_ring=True, reg_density=1.0),
    "amber_smoke": smoke(),
    "amber_full": FULL,
}
IR_BUILT = ("stock_4x4", "stock_8x8", "amber_smoke")


def _current() -> dict:
    out = {}
    for name, spec in GOLDEN_SPECS.items():
        ird = (ir_digest(PassManager().run(spec)) if name in IR_BUILT
               else None)
        out[name] = {"spec_digest": spec.digest(), "ir_digest": ird}
    return out


def _load() -> dict:
    with open(FIXTURE) as f:
        return json.load(f)


@pytest.mark.parametrize("name", sorted(GOLDEN_SPECS))
def test_spec_digest_golden(name):
    """Spec serialization is stable: digest matches the committed value
    (which also proves process-restart stability — the fixture was
    written by a different interpreter run)."""
    golden = _load()
    assert name in golden, f"regenerate the fixture (missing {name})"
    assert GOLDEN_SPECS[name].digest() == golden[name]["spec_digest"], (
        f"{name}: spec digest drifted from the committed golden — if the "
        "spec schema changed intentionally, regenerate via "
        "`python tests/test_spec_golden.py --regen`")


@pytest.mark.parametrize("name", IR_BUILT)
def test_ir_digest_golden(name):
    """The compiled IR is stable: the pass pipeline produces connectivity
    (mux input order included, i.e. config-bit semantics) identical to
    the committed golden."""
    golden = _load()
    ic = PassManager().run(GOLDEN_SPECS[name])
    assert ir_digest(ic) == golden[name]["ir_digest"], (
        f"{name}: compiled IR drifted from the committed golden — "
        "bitstreams/configs for this design point are no longer "
        "compatible. If intentional, regenerate via "
        "`python tests/test_spec_golden.py --regen`")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        cur = _current()
        with open(FIXTURE, "w") as f:
            json.dump(cur, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {FIXTURE}")
        print(json.dumps(cur, indent=2, sort_keys=True))
    else:
        print(__doc__)
