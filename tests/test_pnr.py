"""PnR pipeline: packing, placement, routing, timing (§3.4)."""
import pytest

from repro.core.edsl import SwitchBoxType, create_uniform_interconnect
from repro.core.pnr import place_and_route
from repro.core.pnr.app import (BENCH_APPS, app_butterfly, app_fir,
                                app_tree_reduce)
from repro.core.pnr.global_place import assign_ios, global_place, legalize
from repro.core.pnr.packing import pack


@pytest.fixture(scope="module")
def ic():
    return create_uniform_interconnect(width=6, height=6, num_tracks=4,
                                       sb_type="wilton", io_ring=True,
                                       reg_density=1.0)


def test_packing_folds_constants_and_registers():
    packed = pack(app_fir(4))
    # every const feeding one PE input is folded
    assert all(i.kind != "const" for i in packed.placeable.values())
    assert packed.const_ports          # PE immediates recorded
    # the tail register of the delay line is absorbed into its PE
    assert packed.reg_ports
    # fan-out nets were merged per driver port
    seen = set()
    for net in packed.nets:
        assert net.src not in seen
        seen.add(net.src)


def test_global_place_and_legalize(ic):
    packed = pack(app_tree_reduce(8))
    fixed = assign_ios(packed, 6, 6)
    pos = global_place(packed, 6, 6, fixed=fixed)
    pl = legalize(packed, pos, 6, 6, io_ring=True, fixed=fixed)
    assert len(set(pl.values())) == len(pl)        # no overlaps
    for name, inst in packed.placeable.items():
        x, y = pl[name]
        border = x in (0, 5) or y in (0, 5)
        if inst.kind.startswith("io"):
            assert border
        else:
            assert not border


@pytest.mark.parametrize("app_name", ["pointwise", "tree_reduce", "fir",
                                      "butterfly"])
def test_apps_route_on_wilton(ic, app_name):
    r = place_and_route(ic, BENCH_APPS[app_name](), alphas=(2.0,),
                        sa_steps=40, sa_batch=8)
    assert r.success, r.error
    assert r.timing["critical_path_ns"] > 0
    assert r.wirelength > 0


def test_disjoint_fails_under_track_pressure():
    """§4.2.1: Disjoint cannot re-permute tracks at turns; with Fc=0.5
    endpoints it fails where Wilton routes."""
    results = {}
    for topo in (SwitchBoxType.WILTON, SwitchBoxType.DISJOINT):
        icx = create_uniform_interconnect(
            width=8, height=8, num_tracks=4, sb_type=topo, io_ring=True,
            reg_density=1.0, cb_track_fc=0.5, sb_track_fc=0.5)
        r = place_and_route(icx, app_butterfly(3), alphas=(2.0,),
                            sa_steps=60, sa_batch=8, route_iters=25)
        results[topo.value] = r.success
    assert results["wilton"] and not results["disjoint"]


def test_route_result_is_legal(ic):
    """No IR node carries two different nets (capacity 1)."""
    r = place_and_route(ic, app_tree_reduce(8), alphas=(2.0,),
                        sa_steps=40, sa_batch=8)
    assert r.success
    usage = {}
    for net in r.routing.nets:
        for nid in net.nodes_used():
            usage.setdefault(nid, set()).add(net.name)
    shared = {n: v for n, v in usage.items() if len(v) > 1}
    assert not shared


def test_alpha_sweep_picks_best(ic):
    r = place_and_route(ic, BENCH_APPS["fir"](), alphas=(1.0, 2.0, 4.0),
                        sa_steps=30, sa_batch=8)
    assert r.success
    assert r.alpha in (1.0, 2.0, 4.0)
