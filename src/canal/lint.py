"""``python -m canal.lint`` — static analysis CLI over design points.

Thin entry point; the implementation lives in
:mod:`repro.core.analysis.lint`. See that module (or ``--help``) for
targets, output formats and the CI exit-code contract.
"""
from repro.core.analysis.lint import build_parser, run  # noqa: F401

if __name__ == "__main__":
    raise SystemExit(run())
