"""Canal's public front door.

One import, two objects:

    import canal

    spec = canal.InterconnectSpec(width=8, height=8, num_tracks=5,
                                  sb_type="wilton", io_ring=True)
    fab = canal.compile(spec)            # pass pipeline -> CompiledFabric
    result = fab.place_and_route(app)
    outs = fab.emulate(result, {"in0": stream}, cycles=32)
    words = fab.bitstream(result)
    area = fab.area()

``InterconnectSpec`` is frozen, hashable and JSON-round-trippable —
``spec.digest()`` is the canonical design-point cache key. ``compile``
runs the named IR passes (``materialize_tiles -> apply_sb_topology ->
insert_pipeline_registers -> connect_core_ports ->
readyvalid_transform? -> prune_dead_muxes -> freeze``); customize the
pipeline via :class:`PassManager`. Sweeps are declarative grids:
``spec_grid(base, {"num_tracks": (2, 4, 6)})`` feeds
:class:`SweepExecutor.run_points`.

Compiles run the static analyzer by default
(``compile(spec, analyze="error"|"warn"|"off")``, report on
``fab.diagnostics``); ``canal.analyze(ic_or_fabric)`` runs it directly
and ``python -m canal.lint`` is the CLI over spec files and importable
configs.

Beyond grids, ``canal.search(base, axes, selector="greedy", ...)``
runs the search-driven DSE optimizer (random / greedy / evolutionary
selectors, Pareto frontier over area, critical-path delay and
routability, store-memoized evaluation); ``python -m canal.search`` is
its CLI and ``canal.serve(...).recommend(...)`` the serving verb.

Everything here re-exports from :mod:`repro.core`; the legacy
``repro.core.edsl.create_uniform_interconnect`` entry point still works
as a deprecation shim over the same pipeline.
"""
from repro.core.analysis import (AnalysisError, AnalysisPass,  # noqa: F401
                                 AnalysisReport, Diagnostic, Severity,
                                 analyze, register_rule, rule_table)
from repro.core.compile import (CompiledFabric,  # noqa: F401
                                compile_spec as compile)  # noqa: A001
from repro.core.passes import (DEFAULT_PASSES, IRPass,  # noqa: F401
                               PassContext, PassManager, ir_digest)
from repro.core.spec import (InterconnectSpec, SwitchBoxType,  # noqa: F401
                             sides_for, spec_from_kwargs, spec_grid)
from repro.core.store import ResultStore  # noqa: F401


def serve(store=None, **kwargs):
    """Start a DSE serving front end (`repro.serve.DSEService`): a
    coalescing ``query(spec | [specs]) -> records`` service over the
    spec-addressed persistent result store, with one shared
    ``SweepExecutor`` batching the misses.

        svc = canal.serve(store=".canal_store", emulate_cycles=16)
        record = svc.query(canal.InterconnectSpec(width=8, height=8))

    Lazy import: serving pulls in the JAX-backed execution stack, which
    spec-only users (digests, grids) should not pay for."""
    from repro.serve.dse_service import serve as _serve
    return _serve(store=store, **kwargs)


def search(base=None, axes=None, **kwargs):
    """Search-driven DSE (`repro.core.search.search`): a selector
    (``"random"`` / ``"greedy"`` / ``"evolutionary"``) proposes
    candidate specs over ``axes`` around ``base``, a store-memoized
    executor evaluates them in batches, and the Pareto frontier over
    (area, critical-path delay, routability) comes back as a
    ``SearchResult``.

        result = canal.search(base, {"num_tracks": (2, 3, 4, 5, 6)},
                              selector="greedy", objective="area",
                              constraints={"min_routability": 1.0},
                              budget=8, store=".canal_store")
        best = result.best("area", {"min_routability": 1.0})

    Lazy import, like :func:`serve`: searching pulls in the JAX-backed
    execution stack.

    Note ``import canal.search`` names the CLI *module* (the
    ``python -m canal.search`` entry point) and shadows this function
    on the package — call ``canal.search(...)`` without importing the
    submodule, or use ``repro.core.search.search`` directly."""
    from repro.core.search import search as _search
    return _search(base, axes, **kwargs)


def SearchSpace(base, axes):
    """Build a `repro.core.search.SearchSpace` (lazy import — see
    :func:`search`)."""
    from repro.core.search import SearchSpace as _SearchSpace
    return _SearchSpace(base, axes)


__all__ = [
    "AnalysisError", "AnalysisPass", "AnalysisReport", "CompiledFabric",
    "Diagnostic", "Severity", "analyze", "register_rule", "rule_table",
    "compile", "DEFAULT_PASSES", "IRPass", "PassContext",
    "PassManager", "ir_digest", "InterconnectSpec", "SwitchBoxType",
    "sides_for", "spec_from_kwargs", "spec_grid", "ResultStore", "serve",
    "search", "SearchSpace",
]
