"""``python -m canal.search`` — search-driven DSE CLI.

Thin entry point; the implementation lives in
:mod:`repro.core.search.cli`. See that module (or ``--help``) for the
axes/selector/constraint flags and the exit-code contract. Note the
function ``canal.search(...)`` (the library API) is defined on the
``canal`` package itself, not in this module.
"""
from repro.core.search.cli import build_parser, run  # noqa: F401

if __name__ == "__main__":
    raise SystemExit(run())
