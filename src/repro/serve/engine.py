"""Batched serving engine: prefill + greedy decode over request batches.

Slot-based continuous batching lite: a fixed-size batch of request slots;
finished requests are replaced by queued ones at step granularity (the
cache is per-slot, index masking keeps per-request positions). Suitable
for the decode_* assigned shapes and the serve example.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class Request:
    prompt: np.ndarray
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, batch_size: int, max_seq: int,
                 eos_id: int = 2):
        self.model = model
        self.params = params
        self.batch = batch_size
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._decode = jax.jit(
            lambda p, c, b: model.decode_step(p, c, b))
        self._prefill = jax.jit(
            lambda p, c, b: model.prefill(p, c, b))

    def generate(self, prompts: List[np.ndarray],
                 max_new_tokens: int = 16,
                 extra_inputs: Optional[Dict] = None) -> List[List[int]]:
        """Greedy-decode a list of prompts (padded into one batch)."""
        out: List[List[int]] = []
        for i in range(0, len(prompts), self.batch):
            chunk = prompts[i:i + self.batch]
            out.extend(self._generate_batch(chunk, max_new_tokens,
                                            extra_inputs))
        return out

    def _generate_batch(self, prompts, max_new_tokens, extra_inputs):
        b = len(prompts)
        pad_b = self.batch
        plen = max(len(p) for p in prompts)
        tokens = np.zeros((pad_b, plen), np.int32)
        for j, p in enumerate(prompts):
            tokens[j, plen - len(p):] = p          # left-pad
        cache = self.model.init_cache(pad_b, self.max_seq)
        batch = {"tokens": jnp.asarray(tokens)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in
                          extra_inputs.items()})
        logits, cache = self._prefill(self.params, cache, batch)
        results = [[] for _ in range(b)]
        done = np.zeros(b, bool)
        cur = np.asarray(jnp.argmax(logits[:, -1], -1))
        for _ in range(max_new_tokens):
            for j in range(b):
                if not done[j]:
                    results[j].append(int(cur[j]))
                    if cur[j] == self.eos_id:
                        done[j] = True
            if done.all():
                break
            logits, cache = self._decode(
                self.params, cache,
                {"tokens": jnp.asarray(cur[:, None].astype(np.int32))
                 if len(cur) == pad_b else
                 jnp.asarray(np.pad(cur, (0, pad_b - b))[:, None]
                             .astype(np.int32))})
            cur = np.asarray(jnp.argmax(logits[:, -1], -1))
        return results
