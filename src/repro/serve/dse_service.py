"""DSE serving front end: spec in, record out, store-backed.

The ROADMAP's north star is serving DSE results at traffic, not just
computing them in batch jobs. :class:`DSEService` is that serving path:
a thread-safe query front end over the spec-addressed persistent
:class:`repro.core.store.ResultStore`, with one shared
:class:`repro.core.dse.SweepExecutor` behind it.

Request lifecycle for ``query(spec | [specs])``:

1. every spec is resolved against the executor defaults and addressed
   by its digest;
2. digests already in flight (another query computing them right now)
   are *coalesced* — the request piggybacks on the existing computation
   instead of duplicating it;
3. remaining digests are probed in the store (warm hits return without
   touching PnR at all);
4. only the residue of true misses is batched through the executor in
   one ``run_points`` call (shared caches, concurrent points, batched
   device emulation), and written back to the store for the next query.

``submit`` returns a future (the service runs queries on an internal
pool), ``query_async`` bridges that future into asyncio, and
``stats()`` reports hit/miss/coalescing counts and query latency.
``recommend(...)`` runs the search-driven optimizer
(:mod:`repro.core.search`) on the service's executor — the cache
becomes a recommendation engine.

Construct via ``canal.serve(...)``.

"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Union

from repro.core.dse import SweepExecutor
from repro.core.spec import InterconnectSpec
from repro.core.store import ResultStore

Request = Union[InterconnectSpec, Dict, Sequence]


class DSEService:
    """Coalescing query service over the persistent DSE result store."""

    def __init__(self, store: Optional[ResultStore] = None,
                 executor: Optional[SweepExecutor] = None,
                 max_query_workers: int = 4,
                 **executor_kwargs):
        if executor is not None and executor_kwargs:
            raise TypeError("pass executor kwargs or a prebuilt executor, "
                            "not both")
        if executor is None:
            executor = SweepExecutor(
                store=store if store is not None else ResultStore(),
                **executor_kwargs)
        elif store is not None and executor.store is not store:
            raise ValueError("executor already carries a different store")
        # a caller-provided executor is taken as configured — including
        # store=False/None (deliberately cold runs); the service then
        # still coalesces, it just never serves from disk
        self.executor = executor
        self.store = executor.store
        self._pool = ThreadPoolExecutor(max_workers=max_query_workers,
                                        thread_name_prefix="dse-serve")
        self._lock = threading.Lock()
        self._inflight: Dict[str, Future] = {}
        self.queries = 0
        self.specs_served = 0
        self.hits = 0            # served straight from the store
        self.misses = 0          # required a PnR computation
        self.coalesced = 0       # piggybacked on an in-flight digest
        self._latency_total = 0.0
        self._latency_max = 0.0

    # ---------------------------------------------------------------- query
    def query(self, request: Request) -> Union[Dict, List[Dict]]:
        """Resolve one spec (or a batch of specs / legacy kwargs dicts)
        to DSE records. Single request in -> single record out; sequence
        in -> list out, order preserved."""
        single = isinstance(request, (InterconnectSpec, dict))
        reqs = [request] if single else list(request)
        t0 = time.perf_counter()
        recs = self._query_batch(reqs)
        dt = time.perf_counter() - t0
        with self._lock:
            self.queries += 1
            self.specs_served += len(reqs)
            self._latency_total += dt
            self._latency_max = max(self._latency_max, dt)
        return recs[0] if single else recs

    def _query_batch(self, reqs: List[Request]) -> List[Dict]:
        resolved = [self.executor.resolve(r) for r in reqs]
        digests = [s.digest() for s in resolved]
        results: Dict[str, Dict] = {}
        waits: Dict[str, Future] = {}
        # claims carry (spec, digest, the Future *this query* installed):
        # the digest is never recomputed on the hot path, and every
        # release is identity-checked against that future — a claim slot
        # a later query re-filled for the same digest is never popped or
        # poisoned by this one
        claims: List[tuple] = []
        # classification is O(1) per digest under the lock; store probes
        # (disk reads) happen outside it so concurrent queries don't
        # serialize on each other's I/O
        with self._lock:
            claimed = set()
            for spec, digest in zip(resolved, digests):
                if digest in waits or digest in claimed:
                    continue
                fut = self._inflight.get(digest)
                if fut is not None:
                    waits[digest] = fut
                    self.coalesced += 1
                else:
                    fut = self._inflight[digest] = Future()
                    claimed.add(digest)
                    claims.append((spec, digest, fut))

        def release(digest: str, fut: Future) -> None:
            with self._lock:
                if self._inflight.get(digest) is fut:
                    del self._inflight[digest]

        misses: List[tuple] = []
        failure: Optional[BaseException] = None
        try:
            # the probe loop runs inside the same try/finally as the
            # executor pass: a failure anywhere after claiming (a store
            # probe raising, an interrupt) must still resolve every
            # claimed in-flight future, or later queries for those
            # digests would park on them forever
            for spec, digest, fut in claims:
                rec = self._probe_store(digest)
                if rec is not None:
                    results[digest] = rec
                    with self._lock:
                        self.hits += 1
                    release(digest, fut)
                    fut.set_result(rec)
                else:
                    misses.append((spec, digest, fut))
                    with self._lock:
                        self.misses += 1
            if misses:
                # one batched executor pass over the misses only: shared
                # IR/resource caches, concurrent points, device emulation.
                # record=False: the serving path must not grow the batch
                # workflow's save_json accumulator without bound.
                # assume_cold: the probe loop above already consulted the
                # store for each of these digests — the executor trusts
                # that verdict instead of probing a second time, so a
                # cold point costs exactly one store read
                recs = self.executor.run_points(
                    [(s, {}) for s, _, _ in misses], record=False,
                    assume_cold=True)
                for (spec, digest, fut), rec in zip(misses, recs):
                    results[digest] = rec
                    release(digest, fut)
                    fut.set_result(rec)
        except BaseException as e:
            failure = e
            raise
        finally:
            # failure path: unblock coalesced waiters on every digest
            # this query claimed and did not resolve — with the real
            # exception instead of hanging them (or hiding the cause)
            for spec, digest, fut in claims:
                if not fut.done():
                    release(digest, fut)
                    fut.set_exception(failure or RuntimeError(
                        f"computation for {digest} abandoned"))
        for digest, fut in waits.items():
            results[digest] = fut.result()
        return [dict(results[d]) for d in digests]

    def _probe_store(self, digest: str) -> Optional[Dict]:
        """Warm-path probe, delegating to :meth:`SweepExecutor.probe` —
        one definition of "covers this workload" (app set + emulation
        context, :meth:`SweepExecutor.record_usable`), one store read,
        one hit/miss increment on the executor counters. Misses are
        handed to ``run_points(..., assume_cold=True)``, which trusts
        this verdict instead of probing again — each cold point hits
        the store exactly once."""
        return self.executor.probe(digest)

    # ---------------------------------------------------------------- async
    def submit(self, request: Request) -> Future:
        """Asynchronous :meth:`query`: returns a
        :class:`concurrent.futures.Future` resolving to the record(s)."""
        return self._pool.submit(self.query, request)

    async def query_async(self, request: Request):
        """:meth:`query` bridged into asyncio (awaitable)."""
        import asyncio
        return await asyncio.wrap_future(self.submit(request))

    # ------------------------------------------------------------ recommend
    def recommend(self, base=None, axes: Optional[Dict] = None, *,
                  objective: str = "area",
                  constraints: Optional[Dict] = None,
                  space: Any = None, selector: str = "greedy",
                  budget: int = 32, batch_size: int = 4, seed: int = 0,
                  selector_options: Optional[Dict] = None
                  ) -> Dict[str, Any]:
        """The serving verb for search-driven DSE: "cheapest spec that
        routes these apps under delay D". Runs :func:`repro.core.search.
        search` over ``axes`` around ``base`` (or a prebuilt ``space``)
        on this service's executor — so candidates are store-memoized,
        statically-invalid ones are pruned free, and repeated
        recommendations are all store hits. Returns ``{"best": ...,
        "frontier": [...], "stats": {...}}``; ``best`` is None when no
        evaluated point satisfies ``constraints`` (e.g.
        ``{"max_critical_path_ns": D, "min_routability": 1.0}``)."""
        from repro.core.search import search
        result = search(base, axes, space=space, selector=selector,
                        objective=objective, constraints=constraints,
                        budget=budget, batch_size=batch_size, seed=seed,
                        executor=self.executor,
                        selector_options=selector_options)
        best = result.best(objective, constraints)
        return {"best": best.to_dict() if best is not None else None,
                "frontier": [p.to_dict() for p in result.frontier],
                "stats": result.stats}

    # ----------------------------------------------------------------- misc
    def warm(self, requests: Sequence[Request]) -> Dict[str, int]:
        """Cache-warming pass: compute-and-store every request, report
        how much was already warm. The hit delta is snapshotted around
        this call's query, so with *concurrent* queries in flight their
        hits can land inside the window and inflate ``already_warm`` —
        warm during quiet periods for exact numbers."""
        with self._lock:
            before = self.hits
        self.query(list(requests))
        with self._lock:
            delta = self.hits - before
        return {"requested": len(requests), "already_warm": delta}

    def stats(self) -> Dict[str, Any]:
        # the store scan (an os.listdir walk for the record count) runs
        # outside the query lock: stats polling on a large store must
        # not serialize the query path behind disk I/O
        store_stats = (self.store.stats() if self.store is not None
                       else None)
        with self._lock:
            q = max(self.queries, 1)
            return {
                "queries": self.queries,
                "specs_served": self.specs_served,
                "hits": self.hits, "misses": self.misses,
                "coalesced": self.coalesced,
                "hit_rate": self.hits / max(self.hits + self.misses, 1),
                "latency_avg_s": self._latency_total / q,
                "latency_max_s": self._latency_max,
                "executor": self.executor.stats(),
                "store": store_stats,
            }

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "DSEService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve(store: Optional[Union[ResultStore, str]] = None,
          **kwargs) -> DSEService:
    """Build a :class:`DSEService` (exported as ``canal.serve``).

    ``store`` is a :class:`ResultStore`, a root path, or None (honor
    ``CANAL_RESULT_STORE``, else ``.canal_store``); remaining kwargs go
    to the underlying :class:`SweepExecutor` (``apps=``,
    ``emulate_cycles=``, ...)."""
    if isinstance(store, str):
        store = ResultStore(store)
    return DSEService(store=store, **kwargs)
