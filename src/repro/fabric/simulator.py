"""Application emulation on a generated fabric.

Given a placed-and-routed application (see ``repro.core.pnr``), drive the
static fabric cycle by cycle: external streams enter at IO tiles, PEs
compute, and the emulator collects outputs. Used by the integration tests
to check that *applications* (not just connections) behave correctly on
the generated interconnect.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.graph import Node
from repro.core.lowering import FabricModule, PE_OP_IDS


class AppEmulator:
    """Binds a routed application to a fabric and runs it."""

    def __init__(self, fabric: FabricModule,
                 route_edges: Sequence[Tuple[Node, Node]],
                 pe_ops: Dict[Tuple[int, int], Tuple[str, int]],
                 pe_imms: Optional[Dict[Tuple[int, int],
                                        Dict[int, int]]] = None,
                 depth: Optional[int] = None):
        self.fabric = fabric
        self.config = jnp.asarray(fabric.route_to_config(route_edges))
        n = max(fabric.num_pe, 1)
        ops = np.full(n, PE_OP_IDS["pass"], np.int32)
        consts = np.zeros(n, np.int32)
        imm_mask = np.zeros((n, 4), np.int32)
        imm_val = np.zeros((n, 4), np.int32)
        coord_to_pe = {c: i for i, c in enumerate(fabric.pe_coords)}
        for coord, (op, const) in pe_ops.items():
            ops[coord_to_pe[coord]] = PE_OP_IDS[op]
            consts[coord_to_pe[coord]] = const
        for coord, ports in (pe_imms or {}).items():
            for port_idx, val in ports.items():
                imm_mask[coord_to_pe[coord], port_idx] = 1
                imm_val[coord_to_pe[coord], port_idx] = val
        self.pe_cfg = {"op": jnp.asarray(ops), "const": jnp.asarray(consts),
                       "imm_mask": jnp.asarray(imm_mask),
                       "imm_val": jnp.asarray(imm_val)}
        self.io_index = {c: i for i, c in enumerate(fabric.io_coords)}
        # fixpoint sweeps: longest register-free chain of the routed tree
        # (replaces the conservative len(route_edges) + 4 bound)
        self.depth = (depth if depth is not None
                      else fabric.depth_for_route(route_edges))

    @classmethod
    def from_pnr(cls, fabric: FabricModule, packed, result,
                 depth: Optional[int] = None) -> "AppEmulator":
        """Bind a PnRResult directly (packing-aware)."""
        pe_ops: Dict[Tuple[int, int], Tuple[str, int]] = {}
        pe_imms: Dict[Tuple[int, int], Dict[int, int]] = {}
        for name, inst in packed.placeable.items():
            if inst.kind != "pe":
                continue
            xy = result.placement[name]
            pe_ops[xy] = (inst.op, inst.const)
            for port, val in packed.const_ports.get(name, {}).items():
                pe_imms.setdefault(xy, {})[int(port[-1])] = val
        return cls(fabric, result.route_edges(), pe_ops, pe_imms,
                   depth=depth)

    def ext_stream(self, inputs: Dict[Tuple[int, int], np.ndarray],
                   cycles: int) -> np.ndarray:
        """Dense (cycles, num_io) drive matrix; streams longer than the
        emulation window are truncated."""
        ext = np.zeros((cycles, self.fabric.num_io), np.int32)
        for coord, stream in inputs.items():
            stream = np.asarray(stream)[:cycles]
            ext[:len(stream), self.io_index[coord]] = stream
        return ext

    def run(self, inputs: Dict[Tuple[int, int], np.ndarray], cycles: int
            ) -> Dict[Tuple[int, int], np.ndarray]:
        ext = self.ext_stream(inputs, cycles)
        obs = self.fabric.run(self.config, jnp.asarray(ext),
                              pe_cfg=self.pe_cfg, depth=self.depth)
        obs = np.asarray(obs)
        return {c: obs[:, i] for c, i in self.io_index.items()}


def run_apps_batch(emulators: Sequence[AppEmulator],
                   inputs_list: Sequence[Dict[Tuple[int, int], np.ndarray]],
                   cycles: int,
                   shard: Optional[bool] = None,
                   io_chunk: Optional[int] = None
                   ) -> List[Dict[Tuple[int, int], np.ndarray]]:
    """Emulate several routed applications on the *same* fabric as one
    batch: all configs/PE programs/IO streams advance together through a
    single ``FabricModule.run_batch`` scan (the fused batched Pallas
    kernel when the fabric was compiled with ``use_pallas=True``).

    Each app sweeps exactly its own routed combinational depth — lanes
    with shallower routes freeze early instead of padding to the batch
    max — so this is bit-identical to ``[e.run(i, cycles) for e, i in
    zip(...)]`` while compiling one program for the whole batch — the DSE
    bulk-evaluation path. ``shard`` forwards to ``run_batch``: the app
    axis is split across devices when more than one is visible.
    ``io_chunk`` forwards too: on the Pallas fused engine, long stimulus
    traces stream from HBM in chunks of that many cycles instead of
    materializing (B, T, io) next to the value matrices."""
    if not emulators:
        return []
    fab = emulators[0].fabric
    if any(e.fabric is not fab for e in emulators):
        raise ValueError("batched emulation requires a shared fabric")
    ext = np.stack([e.ext_stream(i, cycles)
                    for e, i in zip(emulators, inputs_list)])   # (B, T, io)
    configs = jnp.stack([e.config for e in emulators])
    pe_cfgs = {k: jnp.stack([e.pe_cfg[k] for e in emulators])
               for k in emulators[0].pe_cfg}
    depths = np.array([e.depth for e in emulators], dtype=np.int32)
    obs = np.asarray(fab.run_batch(configs, jnp.asarray(ext),
                                   pe_cfgs=pe_cfgs, depth=depths,
                                   shard=shard, io_chunk=io_chunk))
    return [{c: obs[b, :, i] for c, i in e.io_index.items()}
            for b, e in enumerate(emulators)]
