from .simulator import AppEmulator  # noqa: F401
from .ready_valid import RVFabric   # noqa: F401
