from .simulator import AppEmulator, run_apps_batch  # noqa: F401
from .ready_valid import RVFabric   # noqa: F401
