"""Ready-valid (statically configured NoC) backend — Canal §3.3,
Figs. 5–6.

Same IR, different lowering:

* **valid** flows with the data: identical gather network, 1-bit values.
* **ready** flows *backwards*; at every fan-in point the joining logic
  reuses the data mux's one-hot select (Fig. 5): the ready contribution of
  consumer ``d`` to producer ``n`` is ``R(d) OR (sel(d) != index(n))`` —
  i.e. high when ``d`` is ready *or* the route through ``d`` does not use
  ``n``. Producer ready is the AND over all consumers. No LUTs.
* **registers become FIFOs**. Two modes (Fig. 6 / Fig. 8):
  - ``full``: every register node is a depth-2 FIFO with *registered*
    occupancy-based ready (cuts the control timing path; +54% SB area);
  - ``split``: each register keeps its single slot, and the *chain* of two
    adjacent single-slot stages behaves as one depth-2 FIFO. Ready is
    pop-aware (``~occ OR popping``), i.e. a combinational control chain —
    exactly the paper's noted drawback (unregistered control at tile
    boundaries) in exchange for +32% instead of +54% area.

The step function is a synchronous two-phase evaluation per cycle:
forward fixpoint sweeps for (data, valid), backward fixpoint sweeps for
ready, then FIFO push/pop state update. Everything is jit-able.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.graph import Interconnect
from repro.core.lowering import FabricModule


class RVFabric(FabricModule):
    """Hybrid ready-valid interconnect functional model."""

    def __init__(self, ic: Interconnect, fifo_mode: str = "split",
                 use_pallas: bool = False):
        if fifo_mode not in ("full", "split"):
            raise ValueError("fifo_mode must be 'full' or 'split'")
        self.fifo_mode = fifo_mode
        self.fifo_depth = 2 if fifo_mode == "full" else 1
        super().__init__(ic, use_pallas=use_pallas)
        self._build_reverse_tables()

    # ------------------------------------------------------------------ build
    def _build_reverse_tables(self) -> None:
        a = self.arrays
        n = a.num_nodes
        cons_lists: List[List[Tuple[int, int]]] = [[] for _ in range(n)]
        for i, node in enumerate(self.nodes):
            for j, srcn in enumerate(node.fan_in):
                cons_lists[self.node_id[srcn]].append((i, j))
        max_c = max(1, max(len(c) for c in cons_lists))
        # consumer node id, padded with n (sentinel: always-ready consumer)
        cons = np.full((n, max_c), n, dtype=np.int32)
        cons_idx = np.zeros((n, max_c), dtype=np.int32)
        for i, lst in enumerate(cons_lists):
            for k, (ci, cj) in enumerate(lst):
                cons[i, k] = ci
                cons_idx[i, k] = cj
        self.cons = cons
        self.cons_idx = cons_idx
        self.max_cons = max_c
        self.is_reg_arr = a.is_reg.copy()
        # map node id -> register slot index
        self.reg_slot = np.full(n, -1, dtype=np.int32)
        for r, i in enumerate(a.reg_ids):
            self.reg_slot[i] = r
        # PE handshake: outputs' ready joins into all inputs
        # (handled via dedicated pe pass below)

    # -------------------------------------------------------------- interface
    def init_state(self) -> Dict[str, jnp.ndarray]:
        r = len(self.arrays.reg_ids)
        return {
            "slots": jnp.zeros((r, 2), dtype=jnp.int32),   # FIFO storage
            "occ": jnp.zeros((r,), dtype=jnp.int32),       # occupancy
            "mem": jnp.zeros(max(self.num_mem, 1), dtype=jnp.int32),
        }

    # ------------------------------------------------------------- evaluation
    def _forward(self, sel: jnp.ndarray, data0: jnp.ndarray,
                 valid0: jnp.ndarray, pin_data: jnp.ndarray,
                 pin_valid: jnp.ndarray, pin_mask: jnp.ndarray,
                 pe_cfg: Dict[str, jnp.ndarray],
                 depth: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Fixpoint forward sweeps for (data, valid). ``pin_*`` hold register
        outputs / external inputs fixed every sweep."""
        a = self.arrays
        src = jnp.asarray(a.src)
        keep = jnp.asarray(~a.is_driven)

        def body(_, dv):
            d, v = dv
            d_ext = jnp.concatenate([d, jnp.zeros(1, jnp.int32)])
            v_ext = jnp.concatenate([v, jnp.zeros(1, jnp.int32)])
            src_sel = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
            nd = jnp.where(keep, d, d_ext[src_sel])
            nv = jnp.where(keep, v, v_ext[src_sel])
            nd = jnp.where(pin_mask, pin_data, nd)
            nv = jnp.where(pin_mask, pin_valid, nv)
            nd = self._eval_pes(nd, pe_cfg)
            nv = self._eval_pe_valid(nv)
            return nd, nv

        return jax.lax.fori_loop(0, depth, body, (data0, valid0))

    def _eval_pe_valid(self, valid: jnp.ndarray) -> jnp.ndarray:
        """PE fires when all its (connected) inputs are valid."""
        if self.num_pe == 0:
            return valid
        v_ext = jnp.concatenate([valid, jnp.ones(1, jnp.int32)])
        ins = v_ext[jnp.asarray(self.pe_in)]              # (n_pe, 4)
        fire = jnp.min(ins[:, :2], axis=1)                # binary AND of a,b
        out_ids = jnp.asarray(self.pe_out)
        valid = valid.at[out_ids[:, 0]].set(fire)
        if self.pe_out.shape[1] > 1:
            valid = valid.at[out_ids[:, 1]].set(fire)
        return valid

    def _backward(self, sel: jnp.ndarray, ready0: jnp.ndarray,
                  reg_ready: jnp.ndarray, sink_ready: jnp.ndarray,
                  sink_mask: jnp.ndarray, depth: int) -> jnp.ndarray:
        """Fixpoint backward sweeps for ready with one-hot join (Fig. 5).

        reg_ready: per-node pinned ready for register nodes (computed from
        occupancy; in split mode it still participates in the chain via the
        pop-aware term added by the caller). sink_mask pins external sinks.
        """
        a = self.arrays
        cons = jnp.asarray(self.cons)
        cons_idx = jnp.asarray(self.cons_idx)
        is_reg = jnp.asarray(a.is_reg)
        has_cons = jnp.asarray((self.cons < a.num_nodes).any(axis=1))

        def body(_, r):
            r_ext = jnp.concatenate([r, jnp.ones(1, jnp.int32)])
            cr = r_ext[cons]                        # (N, C) consumer ready
            csel = jnp.concatenate([sel, jnp.zeros(1, jnp.int32)])[cons]
            used = (csel == cons_idx) & (cons < a.num_nodes)
            # Fig. 5: ready_j OR not-used_j, ANDed across consumers
            contrib = jnp.where(used, cr, 1)
            nr = jnp.min(contrib, axis=1)
            nr = jnp.where(has_cons, nr, 1)
            nr = jnp.where(is_reg, reg_ready, nr)
            nr = jnp.where(sink_mask, sink_ready, nr)
            return nr

        return jax.lax.fori_loop(0, depth, body, ready0)

    def step(self, state: Dict[str, jnp.ndarray], ext_in: jnp.ndarray,
             ext_valid: jnp.ndarray, config: jnp.ndarray,
             pe_cfg: Optional[Dict[str, jnp.ndarray]] = None,
             ext_sink_ready: Optional[jnp.ndarray] = None,
             depth: int = 24
             ) -> Tuple[Dict[str, jnp.ndarray],
                        Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]:
        """One NoC cycle. Returns (state', (io_data, io_valid, io_in_ready)).

        io_in_ready is the backpressure the fabric presents to external
        producers (at io_out ports).
        """
        if pe_cfg is None:
            pe_cfg = self.default_pe_cfg()
        a = self.arrays
        n = a.num_nodes
        sel = self._selects(config)
        occ = state["occ"]
        slots = state["slots"]
        r_ids = jnp.asarray(a.reg_ids) if len(a.reg_ids) else None

        # ---------------- forward: data & valid --------------------------
        pin_mask = jnp.zeros(n, dtype=bool)
        pin_data = jnp.zeros(n, dtype=jnp.int32)
        pin_valid = jnp.zeros(n, dtype=jnp.int32)
        if r_ids is not None:
            head = slots[:, 0]
            pin_mask = pin_mask.at[r_ids].set(True)
            pin_data = pin_data.at[r_ids].set(head)
            pin_valid = pin_valid.at[r_ids].set((occ > 0).astype(jnp.int32))
        if self.num_io:
            ion = jnp.asarray(self.io_in_nodes)
            pin_mask = pin_mask.at[ion].set(True)
            pin_data = pin_data.at[ion].set(ext_in.astype(jnp.int32))
            pin_valid = pin_valid.at[ion].set(ext_valid.astype(jnp.int32))
        data0 = jnp.where(pin_mask, pin_data, 0)
        valid0 = jnp.where(pin_mask, pin_valid, 0)
        data, valid = self._forward(sel, data0, valid0, pin_data, pin_valid,
                                    pin_mask, pe_cfg, depth)

        # ---------------- backward: ready --------------------------------
        sink_mask = jnp.zeros(n, dtype=bool)
        sink_ready = jnp.ones(n, dtype=jnp.int32)
        if self.num_io:
            ioo = jnp.asarray(self.io_out_nodes)
            sink_mask = sink_mask.at[ioo].set(True)
            if ext_sink_ready is not None:
                sink_ready = sink_ready.at[ioo].set(
                    ext_sink_ready.astype(jnp.int32))
        ready0 = jnp.ones(n, dtype=jnp.int32)
        if self.fifo_mode == "full":
            # registered control: ready depends only on occupancy (< 2)
            reg_ready_vec = (occ < 2).astype(jnp.int32)
            reg_ready = jnp.ones(n, jnp.int32)
            if r_ids is not None:
                reg_ready = reg_ready.at[r_ids].set(reg_ready_vec)
            ready = self._backward(sel, ready0, reg_ready, sink_ready,
                                   sink_mask, depth)
        else:
            # split mode: pop-aware combinational control chain. Iterate the
            # backward sweep with reg_ready recomputed from downstream ready
            # (the unregistered tile-boundary control path, Fig. 6).
            def rbody(_, r):
                pop = self._reg_pop(r, sel, occ)
                reg_ready_vec = jnp.where(occ < 1, 1, pop).astype(jnp.int32)
                reg_ready = jnp.ones(n, jnp.int32)
                rr = reg_ready.at[r_ids].set(reg_ready_vec) \
                    if r_ids is not None else reg_ready
                return self._backward(sel, r, rr, sink_ready, sink_mask, 1)

            ready = jax.lax.fori_loop(0, depth, rbody, ready0)

        # ---------------- sequential update -------------------------------
        new_state = dict(state)
        if r_ids is not None:
            pop = self._reg_pop(ready, sel, occ) * (occ > 0).astype(jnp.int32)
            # the value at the register's input after the forward pass
            d_ext = jnp.concatenate([data, jnp.zeros(1, jnp.int32)])
            v_ext = jnp.concatenate([valid, jnp.zeros(1, jnp.int32)])
            in_data = d_ext[jnp.asarray(a.reg_src)]
            in_valid = v_ext[jnp.asarray(a.reg_src)]
            r_ext = jnp.concatenate([ready, jnp.ones(1, jnp.int32)])
            my_ready = r_ext[r_ids]
            push = in_valid * my_ready
            occ_after_pop = occ - pop
            # shift-down FIFO: on pop, slot1 -> slot0
            slots = jnp.where((pop > 0)[:, None],
                              jnp.stack([slots[:, 1],
                                         jnp.zeros_like(slots[:, 1])], 1),
                              slots)
            write_idx = jnp.clip(occ_after_pop, 0, 1)
            do_push = (push > 0) & (occ_after_pop < self.fifo_depth)
            slots = jnp.where(
                do_push[:, None],
                slots.at[jnp.arange(slots.shape[0]), write_idx]
                     .set(in_data, mode="drop"),
                slots)
            occ = occ_after_pop + do_push.astype(jnp.int32)
            new_state["slots"] = slots
            new_state["occ"] = occ

        io_data = (data[jnp.asarray(self.io_out_nodes)]
                   if self.num_io else jnp.zeros(0, jnp.int32))
        io_valid = (valid[jnp.asarray(self.io_out_nodes)]
                    if self.num_io else jnp.zeros(0, jnp.int32))
        io_ready = (ready[jnp.asarray(self.io_in_nodes)]
                    if self.num_io else jnp.zeros(0, jnp.int32))
        return new_state, (io_data, io_valid, io_ready)

    def _reg_pop(self, ready: jnp.ndarray, sel: jnp.ndarray,
                 occ: jnp.ndarray) -> jnp.ndarray:
        """Whether each register's head is consumed this cycle: its (single)
        consumer mux selects it AND that consumer is ready."""
        a = self.arrays
        if not len(a.reg_ids):
            return jnp.zeros(0, jnp.int32)
        cons = jnp.asarray(self.cons)[jnp.asarray(a.reg_ids)]      # (R, C)
        cons_idx = jnp.asarray(self.cons_idx)[jnp.asarray(a.reg_ids)]
        r_ext = jnp.concatenate([ready, jnp.ones(1, jnp.int32)])
        s_ext = jnp.concatenate([sel, jnp.zeros(1, jnp.int32)])
        used = (s_ext[cons] == cons_idx) & (cons < a.num_nodes)
        consumed = jnp.where(used, r_ext[cons], 1)
        return jnp.min(consumed, axis=1).astype(jnp.int32)

    def run_stream(self, config: jnp.ndarray, ext_data: jnp.ndarray,
                   ext_valid: jnp.ndarray,
                   ext_sink_ready: Optional[jnp.ndarray] = None,
                   pe_cfg: Optional[Dict[str, jnp.ndarray]] = None,
                   depth: int = 24):
        """Run T cycles of the NoC. ext_data/ext_valid: (T, num_io).
        ext_sink_ready: (T, num_io) backpressure from external consumers."""
        state = self.init_state()
        if ext_sink_ready is None:
            ext_sink_ready = jnp.ones_like(ext_valid)

        def scan_fn(st, xs):
            d, v, r = xs
            st, out = self.step(st, d, v, config, pe_cfg,
                                ext_sink_ready=r, depth=depth)
            return st, out

        _, outs = jax.lax.scan(scan_fn, state,
                               (ext_data, ext_valid, ext_sink_ready))
        return outs


    def run_with_sources(self, config: jnp.ndarray, streams: jnp.ndarray,
                         stream_lens: jnp.ndarray, sink_ready: jnp.ndarray,
                         pe_cfg: Optional[Dict[str, jnp.ndarray]] = None,
                         depth: int = 24):
        """Run with handshake-respecting sources: each IO presents
        ``streams[ptr, io]`` and only advances its pointer when the fabric
        accepts (valid & ready). This is the latency-insensitive testbench
        the hybrid interconnect is designed for.

        streams: (T, num_io) data; stream_lens: (num_io,) items per source;
        sink_ready: (T, num_io) external consumer backpressure.
        Returns (io_data, io_valid, accepted_mask) each (T, num_io).
        """
        state = self.init_state()
        t_max = streams.shape[0]
        n_io = self.num_io
        io_arange = jnp.arange(n_io)

        def scan_fn(carry, xs):
            st, ptr = carry
            s_ready = xs
            d = streams[jnp.clip(ptr, 0, t_max - 1), io_arange]
            v = (ptr < stream_lens).astype(jnp.int32)
            st, (od, ov, orr) = self.step(st, d, v, config, pe_cfg,
                                          ext_sink_ready=s_ready,
                                          depth=depth)
            ptr = ptr + v * orr
            accepted = ov * s_ready
            return (st, ptr), (od, ov, accepted)

        (_, ptr), outs = jax.lax.scan(scan_fn, (state, jnp.zeros(n_io,
                                                                 jnp.int32)),
                                      sink_ready)
        return outs


def compile_ready_valid(ic: Interconnect, fifo_mode: str = "split",
                        use_pallas: bool = False) -> RVFabric:
    """Ready-valid backend entry point (the hybrid interconnect, §3.3)."""
    return RVFabric(ic, fifo_mode=fifo_mode, use_pallas=use_pallas)
