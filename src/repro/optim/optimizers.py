"""Optimizers: AdamW (f32 states) and Adafactor (factored second moment,
bf16 first moment) — the latter is what makes the 1T-param Kimi cell fit
512 x 16 GiB (DESIGN.md). Global-norm clipping included. Optax-style
(init/update) pure functions so states shard like params."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    #: returns PartitionSpec tree for the optimizer state, given param specs
    state_specs: Callable[[Any], Any]


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params),
        }

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * gf
            v_new = b2 * v + (1 - b2) * gf * gf
            m_hat = m_new / (1 - b1 ** t)
            v_hat = v_new / (1 - b2 ** t)
            delta = m_hat / (jnp.sqrt(v_hat) + eps) \
                + weight_decay * p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    def state_specs(param_specs):
        return {"m": param_specs, "v": param_specs}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored v for matrices, bf16 m) — memory-lean for 1T params
# ---------------------------------------------------------------------------

def adafactor(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
              b1: float = 0.9, decay: float = 0.99, eps: float = 1e-30,
              weight_decay: float = 0.0, clip_norm: float = 1.0
              ) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.float32(lr))

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def per_param(p):
            if _factored(p):
                return {
                    "m": jnp.zeros(p.shape, jnp.bfloat16),
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32),
                }
            return {"m": jnp.zeros(p.shape, jnp.bfloat16),
                    "v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(per_param, params)

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr_t = lr_fn(step)

        def upd(g, st, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                vr = decay * st["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * st["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(
                             jnp.mean(vr, axis=-1, keepdims=True)[..., None],
                             eps))
                precond = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_st = {"vr": vr, "vc": vc}
            else:
                v = decay * st["v"] + (1 - decay) * g2
                precond = gf * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_st = {"v": v}
            m = b1 * st["m"].astype(jnp.float32) + (1 - b1) * precond
            new_st["m"] = m.astype(jnp.bfloat16)
            delta = m + weight_decay * p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), new_st

        flat = jax.tree.map(upd, grads, state, params,
                            is_leaf=lambda x: isinstance(x, dict)
                            and ("m" in x))
        updates = jax.tree.map(lambda o: o[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda o: o[1], flat,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return updates, new_state

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def per_spec(s):
            if not isinstance(s, P):
                return s
            if len(s) >= 2:
                return {"m": s, "vr": P(*s[:-1]),
                        "vc": P(*(s[:-2] + (s[-1],)))}
            return {"m": s, "v": s}

        return jax.tree.map(per_spec, param_specs,
                            is_leaf=lambda x: isinstance(x, P))

    return Optimizer(init, update, state_specs)
