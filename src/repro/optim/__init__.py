from .optimizers import adamw, adafactor, Optimizer  # noqa: F401
from .schedules import cosine_schedule, linear_warmup  # noqa: F401
