"""RecurrentGemma-2B — RG-LRU + local attention 1:2 [arXiv:2402.19427; hf].
Sub-quadratic: long_500k decode runs (O(1) LRU state + 2048 window)."""
from repro.models.config import HybridConfig, ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, max_seq=8192,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "local_attn"),
                        window=2048, lru_width=2560),
    activation="gelu", remat="dots", sub_quadratic=True,
)


def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=5, d_model=64, num_heads=4, kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, max_seq=256, remat="none",
        hybrid=HybridConfig(pattern=("rglru", "rglru", "local_attn"),
                            window=32, lru_width=64))
