"""Whisper-medium — enc-dec, conv frontend STUB [arXiv:2212.04356;
unverified]. decode/prefill "seq_len" = decoder self-attention length;
encoder fixed at 1500 frames (see DESIGN.md)."""
from repro.models.config import EncDecConfig, ModelConfig

FULL = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, num_heads=16, kv_heads=16,
    d_ff=4096, vocab_size=51865, max_seq=32768,
    encdec=EncDecConfig(encoder_layers=24, encoder_seq=1500, d_frame=128),
    activation="gelu", remat="dots",
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, kv_heads=4,
                        d_ff=128, vocab_size=256, max_seq=128, remat="none",
                        encdec=EncDecConfig(encoder_layers=2,
                                            encoder_seq=30, d_frame=16))
