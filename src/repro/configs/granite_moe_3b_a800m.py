"""IBM Granite 3B-A800M MoE — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.models.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, kv_heads=8,
    d_ff=512, vocab_size=49155, max_seq=4096,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512,
                  capacity_factor=1.25, first_k_dense=0),
    activation="swiglu", remat="dots",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=64, num_heads=4, kv_heads=2, d_ff=64,
        vocab_size=256, max_seq=128, remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=1.25, first_k_dense=0))
