"""Mamba2-1.3B — SSD (state-space duality) [arXiv:2405.21060; unverified].
Attention-free; long_500k decode runs (O(1) SSD state)."""
from repro.models.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, kv_heads=0,
    d_ff=0, vocab_size=50280, max_seq=8192,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128,
                  conv_width=4),
    remat="dots", sub_quadratic=True,
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, vocab_size=256,
                        max_seq=256, remat="none",
                        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2,
                                      chunk=32, conv_width=4))
