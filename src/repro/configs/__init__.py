"""Assigned-architecture configs (``--arch <id>``).

Each module defines ``FULL`` (the exact published config from the
assignment table) and ``smoke()`` (a reduced same-family config for CPU
tests). ``get_config(name)`` / ``list_archs()`` are the public API;
``input_specs`` builds ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

_ARCHS = [
    "tinyllama_1_1b",
    "phi3_mini_3_8b",
    "deepseek_coder_33b",
    "qwen3_14b",
    "kimi_k2_1t_a32b",
    "granite_moe_3b_a800m",
    "internvl2_2b",
    "recurrentgemma_2b",
    "whisper_medium",
    "mamba2_1_3b",
    "cgra_amber",            # the paper's own CGRA config (Canal side)
]

ALIASES = {name.replace("_", "-"): name for name in _ARCHS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {_ARCHS}")
    return name


def list_archs(lm_only: bool = True) -> List[str]:
    return [a for a in _ARCHS if not (lm_only and a == "cgra_amber")]


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.FULL


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke()


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell
    (weak-type correct, shardable, no device allocation)."""
    b = shape.global_batch
    if shape.kind == "train":
        s = shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    elif shape.kind == "prefill":
        s = shape.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    else:  # decode: one new token against a seq_len-deep cache
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    if cfg.vlm is not None and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.vlm.num_patches, cfg.vlm.d_patch), jnp.bfloat16)
    if cfg.encdec is not None and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encdec.encoder_seq, cfg.encdec.d_frame), jnp.bfloat16)
    return specs


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (DESIGN.md
    §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False
    return True
