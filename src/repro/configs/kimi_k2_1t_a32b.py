"""Kimi K2 — trillion-param MoE, 384 experts top-8, 1 leading dense layer
(paper-table) [arXiv:2501.kimi2; unverified]. d_ff=2048 is the per-expert
width; the leading dense layer and the shared expert use the published
18432/2048 widths. Trained with Adafactor-style factored optimizer states
(AdamW f32 states for 1T params cannot fit 512 x 16 GiB; see DESIGN.md).
"""
from repro.models.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, kv_heads=8, head_dim=112,
    d_ff=18432, vocab_size=163840, max_seq=4096,
    moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25, first_k_dense=1, d_ff_shared=2048),
    activation="swiglu", remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(
        num_layers=3, d_model=64, num_heads=4, kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, max_seq=128, remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      capacity_factor=1.25, first_k_dense=1,
                      d_ff_shared=32))
