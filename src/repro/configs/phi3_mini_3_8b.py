"""Phi-3-mini 3.8B — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=32, kv_heads=32,
    d_ff=8192, vocab_size=32064, max_seq=4096,
    activation="swiglu", remat="dots",
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, kv_heads=4,
                        d_ff=128, vocab_size=256, max_seq=128, remat="none")
