"""InternVL2-2B — InternViT + InternLM2 [arXiv:2404.16821; hf].
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, 256, 1024); a linear projection maps them into the LM."""
from repro.models.config import ModelConfig, VLMConfig

FULL = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, kv_heads=8,
    d_ff=8192, vocab_size=92553, max_seq=4096,
    vlm=VLMConfig(num_patches=256, d_patch=1024),
    activation="swiglu", remat="dots",
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, kv_heads=2,
                        d_ff=128, vocab_size=256, max_seq=128, remat="none",
                        vlm=VLMConfig(num_patches=8, d_patch=32))
