"""The paper's own artifact: an Amber-style CGRA interconnect config
(32x32 array, five 16-bit tracks, Wilton SBs, MEM columns) — the Canal
side of the framework. Not an LM; selected via the Canal DSE/benchmarks.

Both configs are frozen :class:`InterconnectSpec` design points: hash them
(``FULL.digest()``) to address caches, or compile them through the front
door (``canal.compile(FULL)`` / :func:`compiled_smoke`).
"""
from repro.core.spec import InterconnectSpec, SwitchBoxType

FULL = InterconnectSpec(
    width=32, height=32, track_width=16, num_tracks=5,
    sb_type=SwitchBoxType.WILTON, reg_density=1.0,
    cb_sides=4, sb_sides=4, mem_columns=(4, 12, 20, 28), io_ring=True,
)


def smoke() -> InterconnectSpec:
    return InterconnectSpec(width=6, height=6, track_width=16, num_tracks=3,
                            sb_type=SwitchBoxType.WILTON, reg_density=1.0,
                            io_ring=True)


def compiled_smoke(use_pallas: bool = False):
    """The smoke design point through the compile front door."""
    from repro.core.compile import compile_spec
    return compile_spec(smoke(), use_pallas=use_pallas)
