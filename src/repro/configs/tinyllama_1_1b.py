"""TinyLlama-1.1B — llama2-arch small [arXiv:2401.02385; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, kv_heads=4,
    d_ff=5632, vocab_size=32000, max_seq=4096,
    activation="swiglu", remat="dots",
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, kv_heads=2,
                        d_ff=128, vocab_size=256, max_seq=128, remat="none")
