"""DeepSeek-Coder 33B — llama-arch [arXiv:2401.14196; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, kv_heads=8,
    d_ff=19200, vocab_size=32256, max_seq=4096,
    activation="swiglu", remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=8, kv_heads=2,
                        d_ff=160, vocab_size=256, max_seq=128, remat="none")
