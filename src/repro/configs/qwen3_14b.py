"""Qwen3-14B — qk-norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, max_seq=4096,
    qk_norm=True, activation="swiglu", remat="full",
)


def smoke() -> ModelConfig:
    return FULL.replace(num_layers=2, d_model=64, num_heads=4, kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512, max_seq=128,
                        remat="none")
