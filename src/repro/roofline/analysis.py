"""Three-term roofline from the compiled dry-run artifact (§Roofline).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = link_bytes_per_chip / (links x link_bw)

``cost_analysis()`` on a partitioned executable reports *per-device*
numbers, so chips divide out of the first two terms; the collective term
comes from the HLO parse (already per chip). MODEL_FLOPS = 6·N·D (dense)
or 6·N_active·D (MoE) measures how much of the compiled compute is
"useful" (catches remat/dispatch waste).
"""
from __future__ import annotations

from typing import Dict

from .hw import ChipSpec, TPU_V5E


def model_flops(n_params_active: float, tokens: float,
                kind: str = "train") -> float:
    """6·N·D for training (fwd 2ND + bwd 4ND); 2·N·D for inference."""
    if kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens


def roofline_terms(per_device_flops: float, per_device_hbm_bytes: float,
                   per_chip_link_bytes: float,
                   chip: ChipSpec = TPU_V5E) -> Dict[str, float]:
    compute_s = per_device_flops / chip.peak_flops_bf16
    memory_s = per_device_hbm_bytes / chip.hbm_bw
    collective_s = per_chip_link_bytes / (chip.ici_links * chip.ici_link_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = max(bound, 1e-30)
    terms["dominant"] = dom
    terms["bound_s"] = bound
    # fraction of roofline the *compute* achieves if perfectly overlapped
    terms["roofline_fraction"] = compute_s / total
    return terms


def count_params(abstract_params) -> float:
    import jax
    return float(sum(p.size for p in jax.tree.leaves(abstract_params)))


def active_params(cfg, total_params: float) -> float:
    """MoE: only top-k of the expert params are active per token."""
    if cfg.moe is None or cfg.moe.num_experts == 0:
        return total_params
    m = cfg.moe
    # expert weights: 3 matrices per expert per MoE layer
    n_moe_layers = cfg.num_layers - m.first_k_dense
    expert_p = n_moe_layers * m.num_experts * 3 * cfg.d_model \
        * m.d_ff_expert
    active_expert_p = expert_p * m.top_k / m.num_experts
    return total_params - expert_p + active_expert_p
