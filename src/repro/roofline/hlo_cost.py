"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts every op ONCE, even inside
``while`` loops — so a scanned-L-layer model under-reports FLOPs, bytes
and collective traffic by ~L×. This module re-derives the three roofline
inputs from the optimized HLO text with *execution multipliers*:

  * build the computation call graph (entry → while bodies/conds;
    fusion/reduce bodies are marked inline);
  * extract while trip counts from their condition computations
    (``compare(gte(iter), constant(N)), direction=LT`` — the shape jax
    scans lower to);
  * FLOPs: 2 × prod(out) × contracted-dims for every ``dot`` (operand
    shapes resolved through a per-computation symbol table; dots inside
    fusion bodies included), × multiplier;
  * bytes: Σ (operand bytes + output bytes) over ops of non-inline
    computations (fusions counted at their call site — XLA's own
    "bytes accessed" convention), × multiplier;
  * collectives: tensor bytes × ring factor × multiplier.

Validated in tests: scanned and unrolled versions of the same model must
report equal FLOPs.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_def(line: str):
    """Parse '%name = TYPE opcode(...)' robustly: tuple types contain
    spaces and '=' inside /*index=N*/ comments, so the type span is found
    by paren balancing rather than regex."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = None
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i + 1
                    break
        if end is None:
            return None
        type_str = rest[:end]
        tail = rest[end:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        tail = rest[sp:]
    om = _OPCODE_RE.match(tail)
    if not om:
        return None
    return name, type_str, om.group(1)
_ENTRY_RE = re.compile(r"ENTRY\s+%?([\w\.\-]+)")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_TRIP_RE = re.compile(r"constant\((\d+)\)")
_DOT_LHS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

#: computations referenced from these opcodes are fused/applied inline —
#: their per-op bytes must not be double counted
_INLINE_CALLERS = {"fusion", "reduce", "map", "reduce-window", "scatter",
                   "select-and-scatter", "sort", "reduce-scatter",
                   "all-reduce", "all-reduce-start", "custom-call"}

_COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter",
                   "all-to-all", "collective-permute", "all-reduce-start",
                   "all-gather-start", "collective-permute-start"}


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        size = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * size
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims.strip() else []


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    symtab: Dict[str, str] = field(default_factory=dict)


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, _Computation] = {}
        self.caller_ops: Dict[str, Set[str]] = defaultdict(set)
        self.while_links: List[Tuple[str, str, str]] = []  # comp, body, cond
        self._parse(hlo_text)
        m = _ENTRY_RE.search(hlo_text)
        self.entry = m.group(1) if m else next(iter(self.computations))
        self.multipliers = self._compute_multipliers()

    # -------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        cur: Optional[_Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            s = line.strip()
            if cur is None:
                if s.endswith("{") and "(" in s and "=" not in \
                        s.split("(", 1)[0]:
                    m = _COMP_START_RE.match(s)
                    if m:
                        cur = _Computation(m.group(1))
                        self.computations[cur.name] = cur
                continue
            if s == "}":
                cur = None
                continue
            d = _parse_def(line)
            if not d:
                continue
            name, type_str, opcode = d
            op = _Op(name, type_str, opcode, line)
            cur.ops.append(op)
            cur.symtab[name] = type_str
            # record called computations
            for key in ("body", "condition", "to_apply", "calls"):
                for cm in re.finditer(rf"{key}=%?([\w\.\-]+)", line):
                    self.caller_ops[cm.group(1)].add(opcode)
            bm = re.search(r"body=%?([\w\.\-]+)", line)
            cm_ = re.search(r"condition=%?([\w\.\-]+)", line)
            if opcode == "while" and bm and cm_:
                self.while_links.append((cur.name, bm.group(1),
                                         cm_.group(1), line))

    def _is_inline(self, comp_name: str) -> bool:
        callers = self.caller_ops.get(comp_name)
        if not callers:
            return False
        return callers <= _INLINE_CALLERS

    # -------------------------------------------- while trip-count detection
    _KNOWN_TRIP_RE = re.compile(
        r'known_trip_count.{0,16}?[\'"]?n[\'"]?\s*:\s*[\'"]?(\d+)')

    def _trip_count(self, cond_name: str, while_line: str) -> int:
        # 1. XLA-annotated trip count (backend_config)
        kt = self._KNOWN_TRIP_RE.search(while_line)
        if kt:
            return max(int(kt.group(1)), 1)
        # 2. analyse the condition computation (+ one level of fusions)
        cond = self.computations.get(cond_name)
        if cond is None:
            return 1
        ops = list(cond.ops)
        for op in cond.ops:
            cm = re.search(r"calls=%?([\w\.\-]+)", op.line)
            if cm and cm.group(1) in self.computations:
                ops += self.computations[cm.group(1)].ops
        limit = None
        for op in ops:
            if op.opcode == "constant":
                c = _CONST_TRIP_RE.search(op.line)
                if c:
                    limit = int(c.group(1))
        has_lt = any(op.opcode == "compare" and "direction=LT" in op.line
                     for op in ops)
        if limit is not None and has_lt:
            return max(limit, 1)
        return 1

    def _compute_multipliers(self) -> Dict[str, float]:
        mult: Dict[str, float] = defaultdict(float)
        # map comp -> list of (child, trip multiplier)
        children: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
        linked: Set[Tuple[str, str]] = set()
        for comp, body, cond, wline in self.while_links:
            trip = self._trip_count(cond, wline)
            children[comp].append((body, float(trip)))
            children[comp].append((cond, float(trip + 1)))
            linked.add((comp, body))
            linked.add((comp, cond))
        for child, callers in self.caller_ops.items():
            for comp in self.computations.values():
                for op in comp.ops:
                    if re.search(rf"(?:body|condition|to_apply|calls)="
                                 rf"%?{re.escape(child)}\b", op.line):
                        if (comp.name, child) not in linked \
                                and op.opcode != "while":
                            children[comp.name].append((child, 1.0))
                            linked.add((comp.name, child))

        def visit(name: str, m: float, stack=()):
            if name in stack or name not in self.computations:
                return
            mult[name] += m
            for child, factor in children.get(name, []):
                visit(child, m * factor, stack + (name,))

        visit(self.entry, 1.0)
        return dict(mult)

    # ----------------------------------------------------------------- cost
    def _op_flops(self, op: _Op, symtab: Dict[str, str]) -> float:
        if op.opcode != "dot":
            return 0.0
        out = _first_shape_dims(op.type_str)
        out_n = 1
        for d in out:
            out_n *= d
        cd = _DOT_LHS_RE.search(op.line)
        if not cd:
            return 0.0
        try:
            args = op.line.split("dot(", 1)[1].split(")", 1)[0]
            refs = _OPERAND_RE.findall(args)
            lhs_dims = _first_shape_dims(symtab.get(refs[0], "")) \
                if refs else []
        except IndexError:
            lhs_dims = []
        contract = 1
        for ci in (int(i) for i in cd.group(1).split(",") if i):
            if ci < len(lhs_dims):
                contract *= lhs_dims[ci]
        return 2.0 * out_n * contract

    #: fused-TPU HBM traffic model: only ops that fundamentally round-trip
    #: HBM count (operands + outputs); elementwise/layout ops are assumed
    #: fused into their neighbours (XLA's raw "bytes accessed" counts every
    #: op boundary and over-reports 10-50x on CPU-style unfused HLO).
    _HBM_OPS = {"dot", "convolution", "gather", "scatter",
                "dynamic-slice", "dynamic-update-slice", "sort",
                "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start",
                "all-gather-start", "collective-permute-start", "fusion",
                "custom-call"}
    _HBM_OUT_ONLY = {"reduce", "concatenate", "pad", "reduce-window"}

    def _operand_bytes(self, op: _Op, symtab: Dict[str, str]) -> List[int]:
        try:
            args = op.line.split(f"{op.opcode}(", 1)[1].split(")", 1)[0]
            return [_tensor_bytes(symtab[r])
                    for r in _OPERAND_RE.findall(args) if r in symtab]
        except IndexError:
            return []

    def _op_bytes(self, op: _Op, symtab: Dict[str, str]) -> float:
        if op.opcode in self._HBM_OUT_ONLY:
            return float(_tensor_bytes(op.type_str))
        if op.opcode not in self._HBM_OPS:
            return 0.0
        if op.opcode == "dynamic-update-slice":
            # in-place update: only the slice is read + written
            ops_b = self._operand_bytes(op, symtab)
            return float(2 * ops_b[1]) if len(ops_b) > 1 else 0.0
        if op.opcode == "dynamic-slice":
            return float(2 * _tensor_bytes(op.type_str))
        out_b = _tensor_bytes(op.type_str)
        if op.opcode in ("fusion", "custom-call"):
            # in-place-update fusions (scan stash writes) only touch the
            # updated slice, not the whole carried buffer
            dus_b = self._fusion_dus_bytes(op)
            if dus_b is not None:
                return float(dus_b)
            # elementwise chains fuse on TPU: count the write, not reads
            # (CPU HLO wraps single elementwise ops as kLoop fusions)
            return float(out_b)
        return float(out_b + sum(self._operand_bytes(op, symtab)))

    def _fusion_dus_bytes(self, op: _Op) -> Optional[float]:
        """If the fusion body is a dynamic-update-slice (scan stash /
        KV-cache write), traffic = 2x the update slice, not the buffer."""
        cm = re.search(r"calls=%?([\w\.\-]+)", op.line)
        if not cm:
            return None
        body = self.computations.get(cm.group(1))
        if body is None:
            return None
        dus = [o for o in body.ops if o.opcode == "dynamic-update-slice"]
        if not dus:
            return None
        total = 0.0
        for d in dus:
            ops_b = self._operand_bytes(d, body.symtab)
            if len(ops_b) > 1:
                total += 2.0 * ops_b[1]          # update read + write
        return total if total > 0 else None

    def totals(self) -> Dict[str, float]:
        from .hlo_parse import link_traffic_bytes
        flops = 0.0
        bytes_ = 0.0
        coll_records: List[Dict] = []
        for name, comp in self.computations.items():
            m = self.multipliers.get(name, 0.0)
            if m <= 0:
                continue
            inline = self._is_inline(name)
            for op in comp.ops:
                flops += m * self._op_flops(op, comp.symtab)
                if not inline:
                    bytes_ += m * self._op_bytes(op, comp.symtab)
                if op.opcode in _COLLECTIVE_OPS:
                    b = _tensor_bytes(op.type_str)
                    g = _GROUP_RE.search(op.line)
                    if g:
                        group = len(g.group(1).split(","))
                    else:
                        g2 = _GROUP_V2_RE.search(op.line)
                        group = int(g2.group(2)) if g2 else 1
                    coll_records.append({
                        "kind": op.opcode.replace("-start", ""),
                        "bytes": b * m, "group": max(group, 1)})
        link_bytes, by_kind = link_traffic_bytes(coll_records)
        return {
            "flops": flops,
            "bytes": bytes_,
            "link_bytes": link_bytes,
            "collectives_by_kind": by_kind,
            "n_collective_ops": len(coll_records),
        }
