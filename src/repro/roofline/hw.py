"""Hardware constants for the roofline analysis (assignment-specified)."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    hbm_bw: float               # bytes/s per chip
    ici_link_bw: float          # bytes/s per link
    ici_links: int              # links per chip (2D torus: 4)
    hbm_bytes: float            # capacity per chip
    dci_bw: float               # inter-pod bytes/s per chip (approx)


TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024**3,
    dci_bw=6.25e9,
)
