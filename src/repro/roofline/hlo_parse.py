"""Parse collective traffic out of (optimized, SPMD-partitioned) HLO text.

``cost_analysis()`` does not report collective bytes, so we regex the HLO:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes its tensor bytes, converted to
*per-chip link traffic* with the standard ring factors:

    all-reduce:      2 (N-1)/N x bytes    (reduce-scatter + all-gather)
    all-gather:        (N-1)/N x bytes    (bytes = full output)
    reduce-scatter:    (N-1)/N x bytes    (bytes = full input ~ out x N)
    all-to-all:        (N-1)/N x bytes
    collective-permute:          bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.7 = bf16[16,2048]{1,0} all-reduce(%x), replica_groups=
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype, 4)
    if dims.strip() == "":
        return size
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * size


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Returns one record per collective op: kind, bytes, group size."""
    out: List[Dict] = []
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            nbytes = sum(_shape_bytes(dt, dm)
                         for dt, dm in _TUPLE_ELEM_RE.findall(tuple_body))
        else:
            nbytes = _shape_bytes(dtype, dims)
        g = _GROUP_RE.search(line)
        if g:
            group = len(g.group(1).split(","))
        else:
            g2 = _GROUP_V2_RE.search(line)
            group = int(g2.group(2)) if g2 else 1
        out.append({"kind": kind, "bytes": int(nbytes),
                    "group": max(group, 1)})
    return out


def link_traffic_bytes(records: List[Dict]) -> Tuple[float, Dict[str,
                                                                  float]]:
    """Per-chip link traffic with ring factors; returns (total, by_kind)."""
    by_kind: Dict[str, float] = defaultdict(float)
    for r in records:
        n = r["group"]
        fac = (n - 1) / n if n > 1 else 0.0
        b = r["bytes"]
        if r["kind"] == "all-reduce":
            t = 2.0 * fac * b
        elif r["kind"] == "all-gather":
            t = fac * b                      # bytes = full output
        elif r["kind"] == "reduce-scatter":
            t = fac * b                      # bytes = full input
        elif r["kind"] == "all-to-all":
            t = fac * b
        else:                                # collective-permute
            t = float(b)
        by_kind[r["kind"]] += t
    return sum(by_kind.values()), dict(by_kind)
