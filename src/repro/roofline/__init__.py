from .hw import TPU_V5E  # noqa: F401
from .analysis import roofline_terms, model_flops  # noqa: F401
