"""Production training launcher.

On real hardware this runs under multi-process JAX (one process per host;
jax.distributed.initialize from the cluster env) against the production
mesh; in this container it runs smoke-scale configs on the host mesh.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time



def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--grad-compression", type=str, default=None,
                    choices=(None, "int8_ef"))
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.ckpt import CheckpointManager
    from repro.configs import get_config, get_smoke
    from repro.data import SyntheticTokens
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.optim import adamw, cosine_schedule
    from repro.runtime import StragglerMonitor, Supervisor
    from repro.train.step import init_train_state, make_train_step

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = cfg.replace(ce_seq_chunk=min(args.seq, 512), moe_groups=2)
    model = build_model(cfg)
    opt = adamw(cosine_schedule(3e-3 if args.smoke else 3e-4, 20,
                                args.steps))

    mesh = make_host_mesh()
    with mesh:
        state = init_train_state(model, opt, jax.random.PRNGKey(0))
        step_fn = jax.jit(make_train_step(
            model, opt, microbatches=args.microbatches,
            grad_compression=args.grad_compression))

        ds = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             global_batch=args.batch, seed=0,
                             process_index=jax.process_index(),
                             process_count=jax.process_count())
        sup = Supervisor(
            step_fn=step_fn,
            batch_fn=lambda s: {k: jnp.asarray(v)
                                for k, v in ds.batch(s).items()},
            ckpt=CheckpointManager(args.ckpt_dir, keep=3),
            ckpt_every=args.ckpt_every,
            monitor=StragglerMonitor(n_hosts=max(jax.process_count(), 1)))

        # resume if a checkpoint exists (restart semantics)
        restored = sup.ckpt.restore_latest(like=state)
        start = 0
        if restored is not None:
            state, start = restored
            print(f"[train] resuming from step {start}")
        t0 = time.perf_counter()
        state = sup.run(state, start_step=start, num_steps=args.steps)
        dt = time.perf_counter() - t0

    losses = [h["metrics"]["loss"] for h in sup.history
              if h["event"] == "step"]
    print(f"[train] {len(losses)} steps in {dt:.1f}s "
          f"({dt / max(len(losses), 1):.2f} s/step); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
