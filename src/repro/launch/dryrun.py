import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  with mesh:
      lowered = jax.jit(step, in_shardings=..., out_shardings=...) \
          .lower(**input_specs(arch))
      compiled = lowered.compile()
      print(compiled.memory_analysis())   # proves it fits
      print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

plus collective-byte parsing from the partitioned HLO. Results land in
``experiments/dryrun/<mesh>/<arch>/<shape>.json`` for the roofline table.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
(--all iterates the full assigned matrix, one subprocess per cell for
memory isolation.)
"""
import argparse
import json
import subprocess
import sys
import time
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, cell_is_runnable, get_config,
                           input_specs, list_archs)
from repro.launch.mesh import batch_spec, make_production_mesh, \
    tree_shardings
from repro.models import build_model
from repro.optim import adamw, adafactor, cosine_schedule
from repro.roofline.analysis import (active_params, count_params,
                                     model_flops, roofline_terms)
from repro.train.step import (init_train_state, make_train_step,
                              train_state_specs)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def prune_specs(spec_tree, abstract_tree, mesh):
    """Drop sharding on dims the shape can't divide (batch=1 decode cells,
    odd head counts): pjit arg shardings require divisibility."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def prune(spec, ab):
        if not isinstance(spec, P):
            return spec
        shape = ab.shape
        new = []
        for i, axes in enumerate(spec):
            if axes is None or i >= len(shape):
                new.append(None if i >= len(shape) else axes)
                continue
            ax_tuple = axes if isinstance(axes, tuple) else (axes,)
            n = 1
            for a in ax_tuple:
                n *= axis_size[a]
            new.append(axes if shape[i] % n == 0 else None)
        return P(*new)

    return jax.tree.map(prune, spec_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, P))


def pick_optimizer(cfg):
    """Adafactor for the 1T cell (memory: DESIGN.md), AdamW elsewhere."""
    sched = cosine_schedule(3e-4, 100, 10_000)
    if cfg.moe is not None and cfg.moe.num_experts >= 256:
        return adafactor(sched)
    return adamw(sched)


def microbatches_for(cfg, shape) -> int:
    """Grad-accum so one microbatch of activations fits 16 GiB/chip."""
    if shape.kind != "train":
        return 0
    tokens = shape.global_batch * shape.seq_len
    # heuristic: big models need more accumulation
    if cfg.d_model >= 7168:
        mb = 8
    elif cfg.d_model >= 5120:
        mb = 4
    else:
        mb = 2 if tokens >= 2**20 else 0
    if cfg.moe is not None and cfg.moe.num_experts:
        mb = max(mb, 4)               # dispatch buffers scale with tokens
    return mb


def build_cell(arch: str, shape_name: str, mesh, *,
               attn_impl: Optional[str] = None,
               remat: Optional[str] = None,
               extra_tags: Optional[Dict] = None,
               cfg_overrides: Optional[Dict] = None):
    """Returns (lowered, meta) for one cell."""
    cfg = get_config(arch)
    if attn_impl:
        cfg = cfg.replace(attn_impl=attn_impl)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    # full remat is the safe memory default for the 1M-token train cells;
    # lighter policies are hillclimb knobs (--remat)
    cfg = cfg.replace(remat=remat or
                      ("full" if shape.kind == "train" else "none"))
    if "pod" in mesh.axis_names:
        cfg = cfg.replace(batch_axes=("pod", "data"))
    if not cell_is_runnable(cfg, shape):
        raise ValueError(f"{arch} x {shape_name} skipped "
                         f"(full attention at 512k: DESIGN.md)")
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    bspec = batch_spec(mesh)
    batch_specs = prune_specs({k: bspec for k in specs}, specs, mesh)
    batch_sh = tree_shardings(mesh, batch_specs)

    abstract_params = jax.eval_shape(model.init_params,
                                     jax.random.PRNGKey(0))
    n_params = count_params(abstract_params)
    n_active = active_params(cfg, n_params)
    param_specs = prune_specs(model.param_specs(), abstract_params, mesh)
    param_sh = tree_shardings(mesh, param_specs)

    if shape.kind == "train":
        opt = pick_optimizer(cfg)
        mb = microbatches_for(cfg, shape)
        step_fn = make_train_step(model, opt, microbatches=mb)
        abstract_state = jax.eval_shape(
            lambda rng: init_train_state(model, opt, rng),
            jax.random.PRNGKey(0))
        state_specs = prune_specs(train_state_specs(model, opt),
                                  abstract_state, mesh)
        state_sh = tree_shardings(mesh, state_specs)
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "accuracy": NamedSharding(mesh, P())}
        jitted = jax.jit(step_fn,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, metrics_sh),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(abstract_state, specs)
        tokens = shape.global_batch * shape.seq_len
        mflops = model_flops(n_active, tokens, "train")
    else:
        cache_len = shape.seq_len
        if cfg.vlm is not None:        # vision prefix occupies cache slots
            cache_len += cfg.vlm.num_patches
        abstract_cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cache_len))
        cache_specs = prune_specs(model.cache_specs(), abstract_cache,
                                  mesh)
        cache_sh = tree_shardings(mesh, cache_specs)
        logits_sh = NamedSharding(
            mesh, bspec if shape.global_batch % 16 == 0 else P())

        if shape.kind == "prefill":
            def serve_fn(params, cache, batch):
                return model.prefill(params, cache, batch)
            tokens = shape.global_batch * shape.seq_len
        else:
            def serve_fn(params, cache, batch):
                return model.decode_step(params, cache, batch)
            tokens = shape.global_batch          # one new token each

        jitted = jax.jit(serve_fn,
                         in_shardings=(param_sh, cache_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(abstract_params, abstract_cache, specs)
        mflops = model_flops(n_active, tokens, "serve")

    meta = {
        "arch": arch, "shape": shape_name,
        "kind": shape.kind,
        "n_params": n_params, "n_params_active": n_active,
        "tokens": tokens, "model_flops": mflops,
        "mesh_axes": dict(zip(mesh.axis_names,
                              mesh.devices.shape)),
        "n_devices": int(mesh.devices.size),
    }
    if extra_tags:
        meta.update(extra_tags)
    return lowered, meta


def analyze(lowered, meta: Dict, verbose: bool = True) -> Dict:
    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:                                # pragma: no cover
        mem["error"] = str(e)
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and
                k in ("flops", "bytes accessed", "transcendentals",
                      "utilization operand 0 {}", "optimal_seconds")}
    except Exception as e:                                # pragma: no cover
        cost["error"] = str(e)

    # loop-aware HLO cost model (XLA cost_analysis counts scan bodies once)
    from repro.roofline.hlo_cost import HloCostModel
    hlo = compiled.as_text()
    t0 = time.perf_counter()
    totals = HloCostModel(hlo).totals()
    parse_s = time.perf_counter() - t0

    n_dev = meta["n_devices"]
    flops_dev = totals["flops"]
    bytes_dev = totals["bytes"]
    link_bytes = totals["link_bytes"]
    terms = roofline_terms(flops_dev, bytes_dev, link_bytes)
    useful = meta["model_flops"] / max(flops_dev * n_dev, 1e-30)

    rec = dict(meta)
    rec.update({
        "compile_seconds": compile_s,
        "hlo_parse_seconds": parse_s,
        "memory_analysis": mem,
        "cost_analysis_raw": cost,     # XLA's (loop-uncorrected) numbers
        "per_device_flops": flops_dev,
        "per_device_hbm_bytes": bytes_dev,
        "per_chip_link_bytes": link_bytes,
        "collectives": {
            "count": totals["n_collective_ops"],
            "by_kind_traffic": totals["collectives_by_kind"],
        },
        "roofline": terms,
        "useful_flops_ratio": useful,
    })
    if verbose:
        print(f"  compiled in {compile_s:.1f}s; "
              f"mem(args={mem.get('argument_size_in_bytes', 0) / 2**30:.2f}"
              f"GiB temp={mem.get('temp_size_in_bytes', 0) / 2**30:.2f}GiB)"
              f"/dev")
        print(f"  flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
              f"link_bytes/chip={link_bytes:.3e}")
        print(f"  roofline: compute={terms['compute_s']:.4f}s "
              f"memory={terms['memory_s']:.4f}s "
              f"collective={terms['collective_s']:.4f}s "
              f"-> {terms['dominant']} bound, "
              f"fraction={terms['roofline_fraction']:.2f}, "
              f"useful_flops={useful:.2f}")
    return rec


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = OUT_DIR, **build_kw) -> Dict:
    from repro.configs import canonical
    arch = canonical(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    print(f"[dryrun] {arch} x {shape_name} on {mesh_kind} "
          f"({mesh.devices.size} chips)", flush=True)
    lowered, meta = build_cell(arch, shape_name, mesh, **build_kw)
    meta["mesh"] = mesh_kind
    rec = analyze(lowered, meta)
    path = os.path.join(out_dir, mesh_kind, arch)
    os.makedirs(path, exist_ok=True)
    tag = rec.get("tag", "")
    fname = f"{shape_name}{('_' + tag) if tag else ''}.json"
    with open(os.path.join(path, fname), "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def iter_cells(mesh_kinds):
    for arch in list_archs():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if not cell_is_runnable(cfg, shape):
                continue
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str, choices=list(SHAPES))
    ap.add_argument("--mesh", type=str, default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true",
                    help="run the full assigned matrix in subprocesses")
    ap.add_argument("--attn-impl", type=str, default=None)
    ap.add_argument("--remat", type=str, default=None)
    ap.add_argument("--tag", type=str, default=None,
                    help="suffix for the result file (perf experiments)")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (perf experiments), "
                         "e.g. --override attn_scores_f32=false")
    ap.add_argument("--out", type=str, default=OUT_DIR)
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            v = v.lower() == "true"
        else:
            try:
                v = int(v)
            except ValueError:
                pass
        overrides[k] = v

    mesh_kinds = (("single", "multi") if args.mesh == "both"
                  else (args.mesh,))

    if args.all:
        failures = []
        for arch, shape_name, mk in iter_cells(mesh_kinds):
            res_path = os.path.join(args.out, mk, arch,
                                    f"{shape_name}.json")
            if os.path.exists(res_path):
                print(f"[skip] {arch} x {shape_name} x {mk} (done)")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name, "--mesh", mk,
                   "--out", args.out]
            r = subprocess.run(cmd, cwd=os.getcwd())
            if r.returncode != 0:
                failures.append((arch, shape_name, mk))
        if failures:
            sys.exit(f"dry-run failures: {failures}")
        print("[dryrun] full matrix complete")
        return

    extra = {}
    if args.tag:
        extra = {"extra_tags": {"tag": args.tag}}
    build_kw = dict(attn_impl=args.attn_impl, remat=args.remat,
                    cfg_overrides=overrides or None, **extra)
    run_cell(args.arch, args.shape, mesh_kinds[0], out_dir=args.out,
             **build_kw)


if __name__ == "__main__":
    main()
