"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
pure data parallelism over the (slower) inter-pod links, which is why
gradient compression targets it (runtime/compression.py).

Functions, not module constants: importing this module never touches JAX
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"))


def batch_spec(mesh: Mesh) -> PartitionSpec:
    """Batch dim sharded over every data-parallel axis present."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    return PartitionSpec(tuple(axes) if len(axes) > 1 else axes[0])


def logical_to_physical(mesh: Mesh, spec: PartitionSpec) -> PartitionSpec:
    """Map canonical ('data'/'model') specs onto this mesh: on the
    multi-pod mesh, parameters stay sharded only over (data, model) —
    the pod axis replicates them (pure DP)."""
    return spec


def sharding(mesh: Mesh, spec: PartitionSpec) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
