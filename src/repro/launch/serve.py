"""Serving launcher: batched greedy decode with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, get_smoke
    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_size=args.batch,
                         max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab_size - 1,
                            size=int(rng.integers(3, 12)))
               .astype(np.int32) for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = engine.generate(prompts, max_new_tokens=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"[serve] {args.requests} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / dt:.1f} tok/s on CPU smoke)")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: {o}")


if __name__ == "__main__":
    main()
