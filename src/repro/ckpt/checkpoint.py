"""Sharded checkpointing with atomic manifests and async writes.

Layout:  <dir>/step_<N>.tmp/ -> atomically renamed to <dir>/step_<N>/
         leaf files: <flat-key>.npy ;  manifest.json: treedef + dtypes +
         shapes + step. A LATEST file points at the newest complete step.

On restore, arrays are device_put against the *target* example pytree's
shardings, so a checkpoint written on one mesh restores onto another
(elastic restart / topology change). Writes happen on a background thread
(training continues; `wait()` joins before the next save or exit).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, List, Optional, Tuple

import numpy as np

import jax


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        items.append((key, leaf))
    return items, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        self.wait()
        items, _ = _flatten(state)
        host_items = []
        for k, v in items:
            arr = np.asarray(v)
            # np.save can't represent bfloat16: store the bit pattern
            if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
                host_items.append((k, arr.view(np.uint16), "bfloat16"))
            else:
                host_items.append((k, arr, str(arr.dtype)))

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": []}
            for i, (key, arr, dtype_name) in enumerate(host_items):
                fname = f"leaf_{i}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append(
                    {"key": key, "file": fname,
                     "shape": list(arr.shape), "dtype": dtype_name})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic publish
            with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, "LATEST.tmp"),
                       os.path.join(self.dir, "LATEST"))
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.available_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def available_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            step = int(f.read().strip())
        if step in self.available_steps():
            return step
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure/shardings of ``like``."""
        final = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        items, treedef = _flatten(like)
        if len(items) != len(manifest["leaves"]):
            raise ValueError("checkpoint/state structure mismatch")
        leaves = []
        for (key, target), meta in zip(items, manifest["leaves"]):
            if meta["key"] != key:
                raise ValueError(
                    f"leaf order mismatch: {meta['key']} != {key}")
            arr = np.load(os.path.join(final, meta["file"]))
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(np.shape(target)):
                raise ValueError(f"shape mismatch at {key}")
            sharding = getattr(target, "sharding", None)
            if sharding is not None:
                leaves.append(jax.device_put(arr, sharding))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like: Any) -> Optional[Tuple[Any, int]]:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, like), step
