"""Gradient compression for cross-pod reductions.

The inter-pod links (DCI) are an order of magnitude slower than intra-pod
ICI, so the pod-axis gradient all-reduce is the bandwidth hot spot at
multi-pod scale. We compress it with per-tensor int8 quantization and
error feedback: quantization residual is added back into the next step's
gradient, so the scheme is unbiased in the long run (standard EF-SGD
argument).

Usage inside a pjit'd step: gradients arrive already summed over the
mesh's data axis by autodiff; ``compressed_grad_sync`` is applied inside
a shard_map over the ``pod`` axis to replace the plain psum.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def int8_compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Quantize -> all-reduce int8 (as int32 accumulate) -> dequantize.

    The scale is max-reduced first so all pods share one grid.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    q_sum = jax.lax.psum(q, axis_name)
    return q_sum.astype(jnp.float32) * scale


def compressed_grad_sync(grads: Any, axis_name: str) -> Any:
    """Apply compressed_psum leaf-wise (mean over the pod axis)."""
    n = jax.lax.psum(1, axis_name)

    def sync(g):
        return (compressed_psum(g, axis_name) / n).astype(g.dtype)

    return jax.tree.map(sync, grads)


class ErrorFeedback:
    """Host-side error-feedback wrapper: carry quantization residuals.

    state = pytree of f32 residuals (same structure as grads).
    """

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def apply(grads: Any, residual: Any) -> Tuple[Any, Any]:
        """Returns (compressed+corrected grads, new residual)."""

        def leaf(g, r):
            corrected = g.astype(jnp.float32) + r
            q, scale = int8_compress(corrected)
            deq = int8_decompress(q, scale)
            return deq.astype(g.dtype), corrected - deq

        out = jax.tree.map(leaf, grads, residual)
        comp = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree.map(lambda o: o[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return comp, new_res
