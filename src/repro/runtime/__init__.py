from .compression import compressed_grad_sync, int8_compress, int8_decompress  # noqa: F401
from .straggler import StragglerMonitor  # noqa: F401
from .supervisor import Supervisor, TrainingFailure  # noqa: F401
