from .compression import (compressed_grad_sync,  # noqa: F401
                          int8_compress, int8_decompress)
from .straggler import StragglerMonitor  # noqa: F401
from .supervisor import Supervisor, TrainingFailure  # noqa: F401
