"""Straggler detection & mitigation.

At thousand-node scale, slow hosts (thermal throttling, failing NICs)
stretch every synchronous step to the slowest participant. The monitor
keeps an EWMA of per-host step times, flags hosts slower than
``threshold`` x the median, and proposes mitigations:

* re-balance: shrink the flagged host's microbatch share (returned as a
  per-host batch-fraction vector the data pipeline consumes);
* evict: after ``evict_after`` consecutive flags, the host should be
  removed and the job restarted from checkpoint at the reduced scale
  (elastic down-scale; Supervisor handles the restart).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass
class StragglerMonitor:
    n_hosts: int
    alpha: float = 0.3               # EWMA coefficient
    threshold: float = 1.5           # x median = straggler
    evict_after: int = 5             # consecutive flags before eviction
    ewma: Optional[np.ndarray] = None
    flags: Optional[np.ndarray] = None

    def __post_init__(self):
        self.ewma = np.zeros(self.n_hosts)
        self.flags = np.zeros(self.n_hosts, np.int64)

    def observe(self, step_times: np.ndarray) -> Dict[str, object]:
        """step_times: (n_hosts,) seconds for the last step."""
        if self.ewma.sum() == 0:
            self.ewma[:] = step_times
        else:
            self.ewma = (1 - self.alpha) * self.ewma \
                + self.alpha * step_times
        med = float(np.median(self.ewma))
        is_straggler = self.ewma > self.threshold * med
        self.flags = np.where(is_straggler, self.flags + 1, 0)
        evict = np.nonzero(self.flags >= self.evict_after)[0].tolist()

        # microbatch re-balance: give slow hosts proportionally less work
        speed = 1.0 / np.maximum(self.ewma, 1e-9)
        frac = speed / speed.sum()
        return {
            "median_s": med,
            "stragglers": np.nonzero(is_straggler)[0].tolist(),
            "evict": evict,
            "batch_fractions": frac,
        }
