"""Fault-tolerant training supervisor: checkpoint/restart with failure
injection, elastic down-scale on eviction, straggler monitoring.

The supervisor owns the outer loop; the inner jit'd step is pure. On any
``TrainingFailure`` (injected in tests; real jobs surface XLA/host errors
here) it restores the latest checkpoint and resumes — the data pipeline
is step-addressable so resume is exactly-once. This is the
checkpoint/restart contract a thousand-node deployment needs; scale-out
only changes who calls it (one supervisor per job controller).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.ckpt import CheckpointManager
from .straggler import StragglerMonitor


class TrainingFailure(RuntimeError):
    pass


@dataclass
class Supervisor:
    step_fn: Callable[[Any, Dict], Any]      # (state, batch) -> (state, mx)
    batch_fn: Callable[[int], Dict]          # step -> batch
    ckpt: CheckpointManager
    ckpt_every: int = 50
    max_restarts: int = 3
    monitor: Optional[StragglerMonitor] = None
    #: test hook: map step -> exception to inject
    failure_injector: Optional[Callable[[int], Optional[Exception]]] = None
    history: List[Dict] = field(default_factory=list)

    def run(self, state: Any, start_step: int, num_steps: int) -> Any:
        restarts = 0
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                state, step = self._run_span(state, step, end)
            except TrainingFailure as e:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                restored = self.ckpt.restore_latest(like=state)
                if restored is None:
                    raise TrainingFailure(
                        "failure before first checkpoint") from e
                state, step = restored
                self.history.append(
                    {"event": "restart", "at_step": step,
                     "cause": str(e)})
        return state

    def _run_span(self, state, step, end):
        while step < end:
            if self.failure_injector is not None:
                exc = self.failure_injector(step)
                if exc is not None:
                    raise TrainingFailure(str(exc))
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            state, metrics = self.step_fn(state, batch)
            dt = time.perf_counter() - t0
            self.history.append({"event": "step", "step": step,
                                 "seconds": dt,
                                 "metrics": {k: float(v) for k, v in
                                             metrics.items()}})
            if self.monitor is not None:
                # single-host container: synthesize per-host times
                report = self.monitor.observe(
                    np.full(self.monitor.n_hosts, dt))
                if report["evict"]:
                    self.history.append({"event": "evict",
                                         "hosts": report["evict"]})
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
        return state, step
