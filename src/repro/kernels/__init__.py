"""Pallas TPU kernels (validated in interpret mode on CPU).

- fabric_step: batched CGRA fabric sweep (the paper's generated hardware)
- hpwl: per-net bounding-box reduction for SA placement
- minplus: tropical relaxation for batched routing wavefronts
- flash_attention: LM prefill attention
- ssd_scan: Mamba-2 chunked state-space scan
"""
from . import ops, ref  # noqa: F401
