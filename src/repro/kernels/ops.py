"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on TPU the same
``pallas_call`` lowers to Mosaic. ``interpret`` is resolved from the
backend on every call, so a mid-process platform swap (tests forcing
``jax.default_backend``) picks the right mode.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import fabric_step as _fabric
from . import flash_attention as _flash
from . import hpwl as _hpwl
from . import minplus as _minplus
from . import ssd_scan as _ssd
from .fabric_step import _default_interpret as _interpret


def fabric_sweep(vals_ext: jnp.ndarray, src: jnp.ndarray,
                 sel: jnp.ndarray) -> jnp.ndarray:
    return _fabric.fabric_sweep(vals_ext, src, sel, interpret=_interpret())


def fabric_sweep_batch(vals_ext: jnp.ndarray, src: jnp.ndarray,
                       sel: jnp.ndarray) -> jnp.ndarray:
    return _fabric.fabric_sweep_batch(vals_ext, src, sel,
                                      interpret=_interpret())


def fabric_fused_batch(vals0: jnp.ndarray, sel: jnp.ndarray,
                       pin_vals: jnp.ndarray, depths: jnp.ndarray,
                       op: jnp.ndarray, const: jnp.ndarray,
                       imm_mask: jnp.ndarray, imm_val: jnp.ndarray,
                       src: jnp.ndarray, keep: jnp.ndarray,
                       pin_mask: jnp.ndarray, pe_in: jnp.ndarray,
                       pe_res_idx: jnp.ndarray, max_depth: int,
                       word: int = 0xFFFF) -> jnp.ndarray:
    """Fused batched fixpoint: masked sweeps + in-kernel PE evaluation."""
    return _fabric.fabric_fused_batch(
        vals0, sel, pin_vals, depths, op, const, imm_mask, imm_val,
        src, keep, pin_mask, pe_in, pe_res_idx, max_depth=max_depth,
        word=word, interpret=_interpret())


def fabric_fused_run(sel: jnp.ndarray, ext: jnp.ndarray,
                     depths: jnp.ndarray, op: jnp.ndarray,
                     const: jnp.ndarray, imm_mask: jnp.ndarray,
                     imm_val: jnp.ndarray, src: jnp.ndarray,
                     keep: jnp.ndarray, pin_mask: jnp.ndarray,
                     pin_src: jnp.ndarray, pe_in: jnp.ndarray,
                     pe_res_idx: jnp.ndarray, reg_src: jnp.ndarray,
                     mem_in: jnp.ndarray, io_out: jnp.ndarray,
                     n_reg: int, n_io: int, n_mem: int, max_depth: int,
                     chunk: int = 8, word: int = 0xFFFF) -> jnp.ndarray:
    """Streamed fused emulation: T cycles in one kernel, ext-IO gridded
    from HBM in ``chunk``-cycle blocks."""
    return _fabric.fabric_fused_run(
        sel, ext, depths, op, const, imm_mask, imm_val, src, keep,
        pin_mask, pin_src, pe_in, pe_res_idx, reg_src, mem_in, io_out,
        n_reg=n_reg, n_io=n_io, n_mem=n_mem, max_depth=max_depth,
        chunk=chunk, word=word, interpret=_interpret())


def hpwl(pins: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    return _hpwl.hpwl(pins, mask, interpret=_interpret())


def net_bboxes(pins: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Per-net (xmin, xmax, ymin, ymax) pin bounding boxes."""
    return _hpwl.net_bboxes(pins, mask, interpret=_interpret())


def minplus_step(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return _minplus.minplus_step(d, w, interpret=_interpret())


def minplus_fixpoint(d0: jnp.ndarray, w: jnp.ndarray,
                     iters: int) -> jnp.ndarray:
    return _minplus.minplus_fixpoint(d0, w, iters, interpret=_interpret())


def minplus_wavefront(d0: jnp.ndarray, w: jnp.ndarray,
                      engine: str = "auto") -> jnp.ndarray:
    """Converged batched shortest-path cost fields (the router's batched
    wavefront engine): Pallas kernel on TPU, jitted dense reference
    elsewhere."""
    return _minplus.minplus_wavefront(d0, w, engine=engine,
                                      interpret=_interpret())


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True) -> jnp.ndarray:
    """GQA-aware wrapper. q: (B, Hq, S, D); k/v: (B, Hkv, S, D)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hq, -1, d)
    vf = v.reshape(b * hq, -1, d)
    out = _flash.flash_attention(qf, kf, vf, causal=causal,
                                 interpret=_interpret())
    return out.reshape(b, hq, sq, d)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, chunk: int = 128
             ) -> jnp.ndarray:
    return _ssd.ssd_scan(x, dt, a, b, c, chunk=chunk,
                         interpret=_interpret())
