"""Pallas kernel: per-net half-perimeter wirelength (HPWL).

The detailed-placement annealer (§3.4, Eq. 2) evaluates batches of
candidate moves; each evaluation reduces every net's pin bounding box. In
dense form the net pins are padded to (n_nets, K, 2) with +/- sentinel
coordinates, and the kernel is a pure VPU reduction, tiled over nets —
the ideal TPU shape for this workload (no scatter, no host sync).

Validated in interpret mode against ``ref.hpwl_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_NETS = 256
SENTINEL = 1 << 20


def _hpwl_kernel(pins_ref, mask_ref, out_ref):
    """pins: (BN, K, 2) int32; mask: (BN, K) int32; out: (BN,) int32."""
    pins = pins_ref[...]
    mask = mask_ref[...] > 0
    big = jnp.int32(SENTINEL)
    x = pins[:, :, 0]
    y = pins[:, :, 1]
    xmax = jnp.max(jnp.where(mask, x, -big), axis=1)
    xmin = jnp.min(jnp.where(mask, x, big), axis=1)
    ymax = jnp.max(jnp.where(mask, y, -big), axis=1)
    ymin = jnp.min(jnp.where(mask, y, big), axis=1)
    any_pin = jnp.any(mask, axis=1)
    out_ref[...] = jnp.where(any_pin,
                             (xmax - xmin) + (ymax - ymin), 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hpwl(pins: jnp.ndarray, mask: jnp.ndarray,
         interpret: bool = True) -> jnp.ndarray:
    """pins: (n_nets, K, 2) int32 padded pin coords; mask: (n_nets, K).
    Returns per-net HPWL (n_nets,) int32."""
    n, k, _ = pins.shape
    n_pad = pl.cdiv(n, BLOCK_NETS) * BLOCK_NETS
    pins_p = jnp.pad(pins, ((0, n_pad - n), (0, 0), (0, 0)))
    mask_p = jnp.pad(mask.astype(jnp.int32), ((0, n_pad - n), (0, 0)))
    out = pl.pallas_call(
        _hpwl_kernel,
        grid=(n_pad // BLOCK_NETS,),
        in_specs=[
            pl.BlockSpec((BLOCK_NETS, k, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_NETS, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_NETS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(pins_p, mask_p)
    return out[:n]


def pack_nets(pin_net, pin_xy, n_nets: int, k_max: int):
    """Host-side helper: (pin_net, pin_xy) lists -> dense (n_nets, K, 2)."""
    import numpy as np
    pins = np.zeros((n_nets, k_max, 2), np.int32)
    mask = np.zeros((n_nets, k_max), np.int32)
    fill = np.zeros(n_nets, np.int32)
    for net, (x, y) in zip(pin_net, pin_xy):
        j = fill[net]
        if j >= k_max:
            raise ValueError(f"net {net} exceeds K={k_max} pins")
        pins[net, j] = (x, y)
        mask[net, j] = 1
        fill[net] += 1
    return pins, mask
