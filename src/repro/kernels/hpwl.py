"""Pallas kernels: per-net half-perimeter wirelength (HPWL) + bboxes.

The detailed-placement annealer (§3.4, Eq. 2) evaluates batches of
candidate moves; each evaluation reduces every net's pin bounding box. In
dense form the net pins are padded to (n_nets, K, 2) with +/- sentinel
coordinates, and the kernel is a pure VPU reduction, tiled over nets —
the ideal TPU shape for this workload (no scatter, no host sync).

Two entry points share the blocking scheme:

* ``hpwl`` — per-net half-perimeter wirelength, the Eq. 2 distance term.
* ``net_bboxes`` — the underlying per-net (xmin, xmax, ymin, ymax)
  boxes, which the device-resident annealer keeps as chain state (the
  overlap term gathers an occupancy integral image at box corners).

``interpret`` resolves per call from the active backend (compiled on
TPU, interpret elsewhere — CPU has no Mosaic backend), exactly like
``fabric_step`` / ``minplus``; pass an explicit bool to pin it.

Validated against ``ref.hpwl_ref`` / ``ref.net_bboxes_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fabric_step import _default_interpret

BLOCK_NETS = 256
SENTINEL = 1 << 20


def _bbox_block(pins, mask):
    """(BN, K, 2) pins + (BN, K) bool mask -> four (BN,) box edges."""
    big = jnp.int32(SENTINEL)
    x = pins[:, :, 0]
    y = pins[:, :, 1]
    xmax = jnp.max(jnp.where(mask, x, -big), axis=1)
    xmin = jnp.min(jnp.where(mask, x, big), axis=1)
    ymax = jnp.max(jnp.where(mask, y, -big), axis=1)
    ymin = jnp.min(jnp.where(mask, y, big), axis=1)
    return xmin, xmax, ymin, ymax


def _hpwl_kernel(pins_ref, mask_ref, out_ref):
    """pins: (BN, K, 2) int32; mask: (BN, K) int32; out: (BN,) int32."""
    mask = mask_ref[...] > 0
    xmin, xmax, ymin, ymax = _bbox_block(pins_ref[...], mask)
    any_pin = jnp.any(mask, axis=1)
    out_ref[...] = jnp.where(any_pin,
                             (xmax - xmin) + (ymax - ymin), 0)


def _bbox_kernel(pins_ref, mask_ref, out_ref):
    """Like ``_hpwl_kernel`` but emits the boxes: out (BN, 4) int32 as
    (xmin, xmax, ymin, ymax); empty nets collapse to the zero box."""
    mask = mask_ref[...] > 0
    xmin, xmax, ymin, ymax = _bbox_block(pins_ref[...], mask)
    any_pin = jnp.any(mask, axis=1)
    box = jnp.stack([xmin, xmax, ymin, ymax], axis=1)
    out_ref[...] = jnp.where(any_pin[:, None], box, 0)


def _pad_nets(pins, mask):
    n = pins.shape[0]
    n_pad = pl.cdiv(n, BLOCK_NETS) * BLOCK_NETS
    pins_p = jnp.pad(pins, ((0, n_pad - n), (0, 0), (0, 0)))
    mask_p = jnp.pad(mask.astype(jnp.int32), ((0, n_pad - n), (0, 0)))
    return pins_p, mask_p, n_pad


@functools.partial(jax.jit, static_argnames=("interpret",))
def _hpwl_jit(pins, mask, interpret: bool) -> jnp.ndarray:
    n, k, _ = pins.shape
    pins_p, mask_p, n_pad = _pad_nets(pins, mask)
    out = pl.pallas_call(
        _hpwl_kernel,
        grid=(n_pad // BLOCK_NETS,),
        in_specs=[
            pl.BlockSpec((BLOCK_NETS, k, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_NETS, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_NETS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(pins_p, mask_p)
    return out[:n]


def hpwl(pins: jnp.ndarray, mask: jnp.ndarray,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    """pins: (n_nets, K, 2) int32 padded pin coords; mask: (n_nets, K).
    Returns per-net HPWL (n_nets,) int32.

    ``interpret=None`` resolves from the backend *before* the jit
    boundary (the jit cache keys on the resolved bool): compiled on
    TPU, interpret mode everywhere else."""
    if interpret is None:
        interpret = _default_interpret()
    return _hpwl_jit(pins, mask, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _bbox_jit(pins, mask, interpret: bool) -> jnp.ndarray:
    n, k, _ = pins.shape
    pins_p, mask_p, n_pad = _pad_nets(pins, mask)
    out = pl.pallas_call(
        _bbox_kernel,
        grid=(n_pad // BLOCK_NETS,),
        in_specs=[
            pl.BlockSpec((BLOCK_NETS, k, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((BLOCK_NETS, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_NETS, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, 4), jnp.int32),
        interpret=interpret,
    )(pins_p, mask_p)
    return out[:n]


def net_bboxes(pins: jnp.ndarray, mask: jnp.ndarray,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """Per-net bounding boxes (n_nets, 4) int32 as (xmin, xmax, ymin,
    ymax); a net with no live pins is the zero box. Same backend-resolved
    ``interpret`` contract as :func:`hpwl`."""
    if interpret is None:
        interpret = _default_interpret()
    return _bbox_jit(pins, mask, interpret)


def pack_nets(pin_net, pin_xy, n_nets: int, k_max: int):
    """Host-side helper: (pin_net, pin_xy) lists -> dense (n_nets, K, 2)."""
    import numpy as np
    pins = np.zeros((n_nets, k_max, 2), np.int32)
    mask = np.zeros((n_nets, k_max), np.int32)
    fill = np.zeros(n_nets, np.int32)
    for net, (x, y) in zip(pin_net, pin_xy):
        j = fill[net]
        if j >= k_max:
            raise ValueError(f"net {net} exceeds K={k_max} pins")
        pins[net, j] = (x, y)
        mask[net, j] = 1
        fill[net] += 1
    return pins, mask
