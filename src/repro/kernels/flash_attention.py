"""Pallas kernel: flash attention (forward) for the LM substrate.

Streaming-softmax attention with VMEM-tiled Q/K/V blocks — the standard
TPU adaptation of FlashAttention: the (S x S) score matrix never
materializes in HBM; each Q block loops over KV blocks keeping running
max/denominator. MXU-aligned block sizes (128). Supports causal masking
and GQA (KV-head broadcast is resolved by the wrapper in ops.py).

Validated in interpret mode against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float,
                  causal: bool, block_k: int, kv_pad: int, kv_actual: int):
    """q: (1, BQ, D); k/v: (1, S_kv_pad, D) resident; o: (1, BQ, D)."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (BQ, D)
    bq, d = q.shape
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)

    n_kv = kv_pad // block_k
    if causal:
        # only KV blocks whose first key position <= this Q block's last
        # query position can contribute
        last_q = (qi + 1) * bq - 1
        n_kv_eff = jnp.minimum(n_kv, last_q // block_k + 1)
    else:
        n_kv_eff = n_kv

    def body(kj, carry):
        m_c, l_c, acc_c = carry
        k_blk = jax.lax.dynamic_slice_in_dim(
            k_ref[0], kj * block_k, block_k, axis=0).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(
            v_ref[0], kj * block_k, block_k, axis=0).astype(jnp.float32)
        s = jnp.dot(q, k_blk.T,
                    preferred_element_type=jnp.float32)  # (BQ, BK)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_k), 1)
        mask = k_pos < kv_actual                         # padding mask
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, block_k), 0)
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_c, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_c - m_new)
        l_new = l_c * alpha + jnp.sum(p, axis=1)
        acc_new = acc_c * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kv_eff, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "interpret", "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, interpret: bool = True,
                    block_k: int = BLOCK_K) -> jnp.ndarray:
    """q: (BH, Sq, D); k/v: (BH, Skv, D). Returns (BH, Sq, D).

    Head/batch dims must be pre-flattened (ops.py handles GQA broadcast).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    scale = 1.0 / (d ** 0.5)
    block_k = min(block_k, max(128, 1))
    sq_pad = pl.cdiv(sq, BLOCK_Q) * BLOCK_Q
    skv_pad = pl.cdiv(skv, block_k) * block_k
    q_p = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0)))

    grid = (bh, sq_pad // BLOCK_Q)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_k=block_k, kv_pad=skv_pad,
                               kv_actual=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, skv_pad, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, skv_pad, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_pad, d), q.dtype),
        interpret=interpret,
    )(q_p, k_p, v_p)
    return out[:, :sq, :]
