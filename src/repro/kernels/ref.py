"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fabric_sweep_ref(vals_ext: jnp.ndarray, src: jnp.ndarray,
                     sel: jnp.ndarray) -> jnp.ndarray:
    """out[i] = vals[src[i, sel[i]]]."""
    picked = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
    return vals_ext[picked]


def fabric_sweep_batch_ref(vals_ext: jnp.ndarray, src: jnp.ndarray,
                           sel: jnp.ndarray) -> jnp.ndarray:
    return jax.vmap(lambda v, s: fabric_sweep_ref(v, src, s))(vals_ext, sel)


def fabric_fused_batch_ref(vals0: jnp.ndarray, sel: jnp.ndarray,
                           pin_vals: jnp.ndarray, depths: jnp.ndarray,
                           op: jnp.ndarray, const: jnp.ndarray,
                           imm_mask: jnp.ndarray, imm_val: jnp.ndarray,
                           src: jnp.ndarray, keep: jnp.ndarray,
                           pin_mask: jnp.ndarray, pe_in: jnp.ndarray,
                           pe_out: jnp.ndarray, max_depth: int,
                           word: int = 0xFFFF) -> jnp.ndarray:
    """Scatter-based oracle for ``fabric_fused_batch``: a vmapped lane
    loop of gather -> hold-undriven -> re-pin -> PE-eval sweeps, each lane
    frozen once its own ``depths`` count is reached. Same contract as the
    kernel except PE outputs are named by ``pe_out`` (n_pe, n_cols) node
    ids instead of the kernel's flattened ``pe_res_idx`` map."""
    from .fabric_step import pe_alu_candidates

    n_pe = pe_out.shape[0]

    def lane(v0, s, pv, d, o, cst, im, iv):
        def sweep(t, v):
            v_ext = jnp.concatenate([v, jnp.zeros(1, jnp.int32)])
            picked = jnp.take_along_axis(src, s[:, None], axis=1)[:, 0]
            nv = v_ext[picked]
            nv = jnp.where(keep > 0, v, nv)
            nv = jnp.where(pin_mask > 0, pv, nv)
            nv_ext = jnp.concatenate([nv, jnp.zeros(1, jnp.int32)])
            ins = nv_ext[pe_in]
            ins = jnp.where(im > 0, iv, ins)
            a, b, c = ins[:, 0], ins[:, 1], ins[:, 2]
            cand = pe_alu_candidates(a, b, c, cst)
            res0 = jnp.take_along_axis(cand, o[None, :], axis=0)[0] & word
            res1 = a & word
            if n_pe:
                nv = nv.at[pe_out[:, 0]].set(res0[:n_pe])
                if pe_out.shape[1] > 1:
                    nv = nv.at[pe_out[:, 1]].set(res1[:n_pe])
            return jnp.where(t < d, nv, v)

        return jax.lax.fori_loop(0, max_depth, sweep, v0)

    return jax.vmap(lane)(vals0, sel, pin_vals, depths, op, const,
                          imm_mask, imm_val)


def hpwl_ref(pins: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    big = jnp.int32(1 << 20)
    m = mask > 0
    x, y = pins[:, :, 0], pins[:, :, 1]
    xmax = jnp.max(jnp.where(m, x, -big), axis=1)
    xmin = jnp.min(jnp.where(m, x, big), axis=1)
    ymax = jnp.max(jnp.where(m, y, -big), axis=1)
    ymin = jnp.min(jnp.where(m, y, big), axis=1)
    return jnp.where(m.any(axis=1), (xmax - xmin) + (ymax - ymin), 0)


def net_bboxes_ref(pins: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Per-net (xmin, xmax, ymin, ymax) boxes; empty nets -> zero box."""
    big = jnp.int32(1 << 20)
    m = mask > 0
    x, y = pins[:, :, 0], pins[:, :, 1]
    box = jnp.stack([
        jnp.min(jnp.where(m, x, big), axis=1),
        jnp.max(jnp.where(m, x, -big), axis=1),
        jnp.min(jnp.where(m, y, big), axis=1),
        jnp.max(jnp.where(m, y, -big), axis=1),
    ], axis=1)
    return jnp.where(m.any(axis=1)[:, None], box, 0)


def minplus_ref(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """min(d, min_i(d_i + w_ij)) batched over rows of d."""
    return jnp.minimum(d, jnp.min(d[:, :, None] + w[None], axis=1))


def minplus_fixpoint_ref(d0: jnp.ndarray, w: jnp.ndarray,
                         iters: int) -> jnp.ndarray:
    """``iters`` tropical relaxations — the contract of the blocked
    kernel's fixpoint loop (and of ``minplus_wavefront`` once ``iters``
    reaches the Bellman-Ford bound)."""
    d = d0
    for _ in range(iters):
        d = minplus_ref(d, w)
    return d


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention. q: (BH, Sq, D), k/v: (BH, Skv, D)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(qi >= ki, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
            b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Naive SSD recurrence (the semantics the chunked kernel must match).

    h_t = exp(dt_t a) h_{t-1} + dt_t x_t B_t^T ;  y_t = h_t C_t
    x: (BH, L, P), dt: (BH, L), a: (BH,), b/c: (BH, L, N) -> y (BH, L, P)
    """

    def one(xh, dth, ah, bh_, ch):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(dtt * ah) * h + dtt * jnp.outer(xt, bt)
            return h, h @ ct

        p, n = xh.shape[-1], bh_.shape[-1]
        h0 = jnp.zeros((p, n), jnp.float32)
        _, y = jax.lax.scan(step, h0,
                            (xh.astype(jnp.float32),
                             dth.astype(jnp.float32),
                             bh_.astype(jnp.float32),
                             ch.astype(jnp.float32)))
        return y

    return jax.vmap(one)(x, dt, a, b, c).astype(x.dtype)
