"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fabric_sweep_ref(vals_ext: jnp.ndarray, src: jnp.ndarray,
                     sel: jnp.ndarray) -> jnp.ndarray:
    """out[i] = vals[src[i, sel[i]]]."""
    picked = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
    return vals_ext[picked]


def fabric_sweep_batch_ref(vals_ext: jnp.ndarray, src: jnp.ndarray,
                           sel: jnp.ndarray) -> jnp.ndarray:
    return jax.vmap(lambda v, s: fabric_sweep_ref(v, src, s))(vals_ext, sel)


def hpwl_ref(pins: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    big = jnp.int32(1 << 20)
    m = mask > 0
    x, y = pins[:, :, 0], pins[:, :, 1]
    xmax = jnp.max(jnp.where(m, x, -big), axis=1)
    xmin = jnp.min(jnp.where(m, x, big), axis=1)
    ymax = jnp.max(jnp.where(m, y, -big), axis=1)
    ymin = jnp.min(jnp.where(m, y, big), axis=1)
    return jnp.where(m.any(axis=1), (xmax - xmin) + (ymax - ymin), 0)


def minplus_ref(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """min(d, min_i(d_i + w_ij)) batched over rows of d."""
    return jnp.minimum(d, jnp.min(d[:, :, None] + w[None], axis=1))


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention. q: (BH, Sq, D), k/v: (BH, Skv, D)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(qi >= ki, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
            b: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Naive SSD recurrence (the semantics the chunked kernel must match).

    h_t = exp(dt_t a) h_{t-1} + dt_t x_t B_t^T ;  y_t = h_t C_t
    x: (BH, L, P), dt: (BH, L), a: (BH,), b/c: (BH, L, N) -> y (BH, L, P)
    """

    def one(xh, dth, ah, bh_, ch):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(dtt * ah) * h + dtt * jnp.outer(xt, bt)
            return h, h @ ct

        p, n = xh.shape[-1], bh_.shape[-1]
        h0 = jnp.zeros((p, n), jnp.float32)
        _, y = jax.lax.scan(step, h0,
                            (xh.astype(jnp.float32),
                             dth.astype(jnp.float32),
                             bh_.astype(jnp.float32),
                             ch.astype(jnp.float32)))
        return y

    return jax.vmap(one)(x, dt, a, b, c).astype(x.dtype)
