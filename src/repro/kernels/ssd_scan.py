"""Pallas kernel: Mamba-2 SSD (state-space duality) chunked scan.

The SSD recurrence per head (scalar A decay, state (P, N)):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t ⊗ B_t
    y_t = C_t · h_t

TPU adaptation (the paper-family chunked algorithm): the sequence is
split into chunks of length C. Within a chunk the quadratic "attention
form" computes intra-chunk contributions on the MXU; a small carried
state (P x N) propagates across chunks through the sequential grid
dimension — Pallas guarantees sequential execution of the last grid axis,
so the state lives in a VMEM scratch accumulator.

Validated in interpret mode against ``ref.ssd_ref`` (naive recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_scr):
    """Blocks (one head, one chunk):
    x: (1, C, P); dt: (1, C); b/c: (1, C, N); a: (1,); y: (1, C, P)
    h_scr: (P, N) carried VMEM scratch (sequential chunk axis).
    """
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)         # (C, P)
    dt = dt_ref[0].astype(jnp.float32)       # (C,)
    b = b_ref[0].astype(jnp.float32)         # (C, N)
    c = c_ref[0].astype(jnp.float32)         # (C, N)
    a = a_ref[0].astype(jnp.float32)         # scalar

    # cumulative log-decay within the chunk: seg[t] = sum_{u<=t} dt_u * a
    da = dt * a                              # (C,) (a < 0)
    seg = jnp.cumsum(da)                     # (C,)
    # decay from chunk start to position t (inclusive of t's own decay)
    decay_in = jnp.exp(seg)                  # (C,)

    # inter-chunk: contribution of carried state h0
    #   y_t += C_t · (exp(seg_t) * h0)
    h0 = h_scr[...]                          # (P, N)
    y_inter = (c @ h0.T) * decay_in[:, None]                  # (C, P)

    # intra-chunk (attention form):
    #   y_t += sum_{u<=t} exp(seg_t - seg_u) * (C_t·B_u) * dt_u * x_u
    scores = c @ b.T                                           # (C, C) t,u
    t_idx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    l_mat = jnp.exp(seg[:, None] - seg[None, :])
    l_mat = jnp.where(t_idx >= u_idx, l_mat, 0.0)
    w = scores * l_mat * dt[None, :]                           # (C, C)
    y_intra = w @ x                                            # (C, P)

    y_ref[0] = (y_inter + y_intra).astype(y_ref.dtype)

    # carry state to next chunk:
    #   h_C = exp(seg_last) * h0 + sum_u exp(seg_last - seg_u) dt_u x_u⊗B_u
    seg_last = seg[-1]
    decay_tail = jnp.exp(seg_last - seg)                       # (C,)
    xb = (x * (dt * decay_tail)[:, None]).T @ b                # (P, N)
    h_scr[...] = jnp.exp(seg_last) * h0 + xb


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, chunk: int = 128,
             interpret: bool = True) -> jnp.ndarray:
    """SSD forward.

    x: (BH, L, P) inputs per flattened batch*head
    dt: (BH, L) positive step sizes
    a: (BH,) negative scalar decay per head
    b, c: (BH, L, N) input/output projections (already head-grouped)
    Returns y: (BH, L, P).
    """
    bh, l, p = x.shape
    n = b.shape[-1]
    if l % chunk != 0:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    lp = x.shape[1]
    grid = (bh, lp // chunk)                    # chunk axis sequential
    out = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk), lambda h, i: (h, i)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1,), lambda h, i: (h,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lp, p), x.dtype),
        scratch_shapes=[pltpu_scratch(p, n)],
        interpret=interpret,
    )(x, dt, b, c, a)
    return out[:, :l, :]


def pltpu_scratch(p: int, n: int):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM((p, n), jnp.float32)
