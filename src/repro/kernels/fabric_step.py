"""Pallas kernel: one fabric combinational sweep (the config-sweep /
emulation hot spot of the generated interconnect).

One sweep computes, for every IR node, the value of its selected mux input:

    out[i] = vals[src[i, sel[i]]]

TPU adaptation: the node-value vector lives wholly in VMEM (N ≤ ~64k nodes
⇒ ≤ 256 KiB int32, well under the ~16 MiB VMEM budget), while the fan-in
table is streamed block-by-block. The mux "select" is evaluated as a
take-along-axis inside the block, and the gather out of the resident value
vector is the only irregular access — exactly the structure a
statically-configured CGRA sweep has. The batched variant vectorizes over
configurations (bitstream-major layout) for the exhaustive connection
sweep (§3.3).

Validated in interpret mode against ``ref.fabric_sweep_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512          # nodes per block (multiple of 128 lanes x 4 sublanes)


@functools.lru_cache(maxsize=1)
def _default_interpret() -> bool:
    """Compiled on TPU, interpret elsewhere (CPU has no Mosaic backend)."""
    return jax.default_backend() != "tpu"


def _sweep_kernel(vals_ref, src_ref, sel_ref, out_ref):
    """vals: (Npad,) resident; src: (BLOCK_N, F); sel: (BLOCK_N,)."""
    src = src_ref[...]                        # (BN, F) int32
    sel = sel_ref[...]                        # (BN,) int32
    picked = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
    out_ref[...] = jnp.take(vals_ref[...], picked, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fabric_sweep(vals_ext: jnp.ndarray, src: jnp.ndarray, sel: jnp.ndarray,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """One sweep. vals_ext: (N+1,) with zero sentinel at N; src: (N, F)
    int32 (sentinel-padded); sel: (N,). Returns (N,).

    ``interpret=None`` resolves from the backend: compiled on TPU,
    interpret mode everywhere else."""
    if interpret is None:
        interpret = _default_interpret()
    n, f = src.shape
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    v_pad = pl.cdiv(vals_ext.shape[0], 128) * 128
    vals_p = jnp.pad(vals_ext, (0, v_pad - vals_ext.shape[0]))
    src_p = jnp.pad(src, ((0, n_pad - n), (0, 0)))
    sel_p = jnp.pad(sel, (0, n_pad - n))
    grid = (n_pad // BLOCK_N,)
    out = pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_pad,), lambda i: (0,)),          # resident vals
            pl.BlockSpec((BLOCK_N, f), lambda i: (i, 0)),    # streamed fan-in
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(vals_p, src_p, sel_p)
    return out[:n]


def _sweep_batch_kernel(vals_ref, src_ref, sel_ref, out_ref):
    """vals: (BB, Npad); src: (BLOCK_N, F); sel: (BB, BLOCK_N)."""
    src = src_ref[...]
    bb = vals_ref.shape[0]

    def body(b, _):
        sel = sel_ref[b]
        picked = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
        out_ref[b, :] = jnp.take(vals_ref[b], picked, axis=0)
        return 0

    jax.lax.fori_loop(0, bb, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fabric_sweep_batch(vals_ext: jnp.ndarray, src: jnp.ndarray,
                       sel: jnp.ndarray, interpret: Optional[bool] = None
                       ) -> jnp.ndarray:
    """Batched sweep over configurations. vals_ext: (B, N+1); sel: (B, N);
    src shared. Returns (B, N). ``interpret=None`` resolves from the
    backend (compiled on TPU, interpret elsewhere)."""
    if interpret is None:
        interpret = _default_interpret()
    b = vals_ext.shape[0]
    n, f = src.shape
    bb = 8                                     # configs per block
    b_pad = pl.cdiv(b, bb) * bb
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    v_pad = pl.cdiv(vals_ext.shape[1], 128) * 128
    vals_p = jnp.pad(vals_ext, ((0, b_pad - b), (0, v_pad - vals_ext.shape[1])))
    src_p = jnp.pad(src, ((0, n_pad - n), (0, 0)))
    sel_p = jnp.pad(sel, ((0, b_pad - b), (0, n_pad - n)))
    grid = (b_pad // bb, n_pad // BLOCK_N)
    out = pl.pallas_call(
        _sweep_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, f), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, BLOCK_N), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_pad), jnp.int32),
        interpret=interpret,
    )(vals_p, src_p, sel_p)
    return out[:b, :n]
