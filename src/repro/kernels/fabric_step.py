"""Pallas kernels: fabric combinational sweeps (the config-sweep /
emulation hot spot of the generated interconnect).

One sweep computes, for every IR node, the value of its selected mux input:

    out[i] = vals[src[i, sel[i]]]

Three kernels share that structure:

``fabric_sweep``
    One sweep, one configuration. The node-value vector lives wholly in
    VMEM (N <= ~64k nodes => <= 256 KiB int32, well under the ~16 MiB VMEM
    budget) while the fan-in table streams block-by-block.

``fabric_sweep_batch``
    One sweep, B configurations (bitstream-major layout): the value matrix
    is blocked over configs, the shared fan-in table over nodes.

``fabric_fused_run``
    The streamed multi-cycle engine: a whole *T-cycle emulation* runs in
    one kernel invocation, with the external IO stream gridded over
    ``chunk``-cycle blocks so only ``(FUSED_LANES, chunk, io)`` of the
    ``(B, T, io)`` stimulus ever sits in VMEM — the rest stays in HBM and
    streams in per grid step (long traces no longer materialize next to
    the value matrices). Register/memory state lives in a
    ``(FUSED_LANES, S)`` state-vector output that persists across the
    (sequential) T-chunk grid steps and re-initializes when a new lane
    block starts; per cycle the pinned sources are gathered scatter-free
    out of that state vector through a node→state index map (``pin_src``).

``fabric_fused_batch``
    The fused batched engine: the *entire* fixpoint (``max_depth`` sweeps)
    for a block of ``FUSED_LANES`` configurations runs inside a single
    kernel invocation. VMEM layout, per grid step ``i``:

    * ``vals``/``sel``/``pin_vals`` — (FUSED_LANES, NP) lane-major value,
      mux-select and pinned-source matrices, where NP rounds N+1 up to the
      128-lane boundary so index N doubles as the zero sentinel;
    * ``src`` (NP, F), ``keep``/``pin_mask``/``pe_res_idx`` (NP,) — the
      node tables, resident and shared by every lane of every block;
    * ``op``/``const`` (FUSED_LANES, P) and ``imm_mask``/``imm_val``
      (FUSED_LANES, P, 4) — the PE programs, resident next to the values
      so PE cores evaluate *in-kernel* (no Python-level round-trip between
      sweeps), applied scatter-free through ``pe_res_idx``: node i with
      ``pe_res_idx[i] < 2P`` reads its value out of the flattened
      (res0, res1) PE result vector;
    * ``depths`` (FUSED_LANES,) — per-configuration sweep counts.

    Masking scheme: every lane runs the static ``max_depth`` loop, but a
    lane whose own combinational depth ``depths[b]`` is reached keeps its
    value vector frozen (``where(t < depths[b], new, old)``). Each lane
    therefore performs exactly its configuration's fixpoint — bit-identical
    to a serial per-config run even when another lane in the batch needs
    more sweeps (and even for adversarial configs with combinational
    cycles, whose values depend on the sweep count).

Validated in interpret mode against ``ref.fabric_sweep_ref`` /
``ref.fabric_fused_batch_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512          # nodes per block (multiple of 128 lanes x 4 sublanes)
FUSED_LANES = 8        # configurations per fused-kernel block

# PE ALU candidate order; must match repro.core.tiles.PECore.OPS
# (repro.core.lowering asserts the correspondence at import time).
PE_OPS = ("add", "sub", "mul", "and", "or", "xor", "shl", "shr", "min",
          "max", "abs", "sel", "const", "pass")


def _default_interpret() -> bool:
    """Compiled on TPU, interpret elsewhere (CPU has no Mosaic backend).

    Resolved *per call*: tests and tools that swap ``jax.default_backend``
    (or force a platform mid-process) must not see a stale cached value.
    """
    return jax.default_backend() != "tpu"


def pe_alu_candidates(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
                      const: jnp.ndarray) -> jnp.ndarray:
    """All PE ALU results, stacked (n_ops, P) in ``PE_OPS`` order.

    Single source of truth for the PE datapath: the fused kernel, its
    pure-jnp oracle and the unfused ``FabricModule._eval_pes`` all select
    rows out of this stack with the configured opcode."""
    shift_b = jnp.clip(b, 0, 15)
    return jnp.stack([
        a + b, a - b, a * b, a & b, a | b, a ^ b,
        a << shift_b, a >> shift_b, jnp.minimum(a, b),
        jnp.maximum(a, b), jnp.abs(a - b),
        jnp.where((a & 1) == 1, b, c), const, a,
    ], axis=0)


def _sweep_kernel(vals_ref, src_ref, sel_ref, out_ref):
    """vals: (Npad,) resident; src: (BLOCK_N, F); sel: (BLOCK_N,)."""
    src = src_ref[...]                        # (BN, F) int32
    sel = sel_ref[...]                        # (BN,) int32
    picked = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
    out_ref[...] = jnp.take(vals_ref[...], picked, axis=0)


def fabric_sweep(vals_ext: jnp.ndarray, src: jnp.ndarray, sel: jnp.ndarray,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """One sweep. vals_ext: (N+1,) with zero sentinel at N; src: (N, F)
    int32 (sentinel-padded); sel: (N,). Returns (N,).

    ``interpret=None`` resolves from the backend *before* the jit
    boundary (the jit cache must key on the resolved bool, or a backend
    swap would replay a stale trace): compiled on TPU, interpret mode
    everywhere else."""
    if interpret is None:
        interpret = _default_interpret()
    return _fabric_sweep_jit(vals_ext, src, sel, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fabric_sweep_jit(vals_ext: jnp.ndarray, src: jnp.ndarray,
                      sel: jnp.ndarray, interpret: bool) -> jnp.ndarray:
    n, f = src.shape
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    v_pad = pl.cdiv(vals_ext.shape[0], 128) * 128
    vals_p = jnp.pad(vals_ext, (0, v_pad - vals_ext.shape[0]))
    src_p = jnp.pad(src, ((0, n_pad - n), (0, 0)))
    sel_p = jnp.pad(sel, (0, n_pad - n))
    grid = (n_pad // BLOCK_N,)
    out = pl.pallas_call(
        _sweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_pad,), lambda i: (0,)),          # resident vals
            pl.BlockSpec((BLOCK_N, f), lambda i: (i, 0)),    # streamed fan-in
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.int32),
        interpret=interpret,
    )(vals_p, src_p, sel_p)
    return out[:n]


def _sweep_batch_kernel(vals_ref, src_ref, sel_ref, out_ref):
    """vals: (BB, Npad); src: (BLOCK_N, F); sel: (BB, BLOCK_N)."""
    src = src_ref[...]
    bb = vals_ref.shape[0]

    def body(b, _):
        sel = sel_ref[b]
        picked = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
        out_ref[b, :] = jnp.take(vals_ref[b], picked, axis=0)
        return 0

    jax.lax.fori_loop(0, bb, body, 0)


def fabric_sweep_batch(vals_ext: jnp.ndarray, src: jnp.ndarray,
                       sel: jnp.ndarray, interpret: Optional[bool] = None
                       ) -> jnp.ndarray:
    """Batched sweep over configurations. vals_ext: (B, N+1); sel: (B, N);
    src shared. Returns (B, N). ``interpret=None`` resolves from the
    backend per call, before the jit boundary (compiled on TPU, interpret
    elsewhere)."""
    if interpret is None:
        interpret = _default_interpret()
    return _fabric_sweep_batch_jit(vals_ext, src, sel, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _fabric_sweep_batch_jit(vals_ext: jnp.ndarray, src: jnp.ndarray,
                            sel: jnp.ndarray, interpret: bool
                            ) -> jnp.ndarray:
    b = vals_ext.shape[0]
    n, f = src.shape
    bb = 8                                     # configs per block
    b_pad = pl.cdiv(b, bb) * bb
    n_pad = pl.cdiv(n, BLOCK_N) * BLOCK_N
    v_pad = pl.cdiv(vals_ext.shape[1], 128) * 128
    vals_p = jnp.pad(vals_ext,
                     ((0, b_pad - b), (0, v_pad - vals_ext.shape[1])))
    src_p = jnp.pad(src, ((0, n_pad - n), (0, 0)))
    sel_p = jnp.pad(sel, ((0, b_pad - b), (0, n_pad - n)))
    grid = (b_pad // bb, n_pad // BLOCK_N)
    out = pl.pallas_call(
        _sweep_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, v_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_N, f), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, BLOCK_N), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_pad), jnp.int32),
        interpret=interpret,
    )(vals_p, src_p, sel_p)
    return out[:b, :n]


def _fused_batch_kernel(depths_ref, vals_ref, sel_ref, pin_vals_ref,
                        op_ref, const_ref, imm_mask_ref, imm_val_ref,
                        src_ref, keep_ref, pin_mask_ref, pe_in_ref,
                        pe_res_idx_ref, out_ref, *, max_depth: int,
                        word: int):
    """One block: FUSED_LANES configurations, the whole fixpoint in VMEM.

    Per sweep and lane: gather the selected fan-in, hold undriven nodes,
    re-pin sources (registers / external IO / memory reads), evaluate the
    PE ALUs and place their results scatter-free via ``pe_res_idx`` — then
    freeze the lane once its own ``depths[b]`` sweeps have run."""
    src = src_ref[...]                              # (NP, F)
    keep = keep_ref[...]                            # (NP,)
    pin_mask = pin_mask_ref[...]                    # (NP,)
    pe_in = pe_in_ref[...]                          # (P, 4)
    pe_res_idx = pe_res_idx_ref[...]                # (NP,)
    np_, f = src.shape
    p = pe_in.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (np_, 1), 0)[:, 0] * f
    src_flat = src.reshape(-1)
    pe_in_flat = pe_in.reshape(-1)
    is_pe_out = pe_res_idx < 2 * p

    def lane(b, carry):
        sel = sel_ref[b, :]
        pin_vals = pin_vals_ref[b, :]
        op = op_ref[b, :]
        const = const_ref[b, :]
        imm_mask = imm_mask_ref[b, :, :]
        imm_val = imm_val_ref[b, :, :]
        d = depths_ref[b]
        # the selected source of every node is sweep-invariant
        picked = jnp.take(src_flat, rows + sel)

        def sweep(t, v):
            nv = jnp.take(v, picked)
            nv = jnp.where(keep > 0, v, nv)
            nv = jnp.where(pin_mask > 0, pin_vals, nv)
            ins = jnp.take(nv, pe_in_flat).reshape(p, 4)
            ins = jnp.where(imm_mask > 0, imm_val, ins)
            a_, b_, c_ = ins[:, 0], ins[:, 1], ins[:, 2]
            cand = pe_alu_candidates(a_, b_, c_, const)
            res0 = jnp.take_along_axis(cand, op[None, :], axis=0)[0] & word
            res1 = a_ & word
            res = jnp.concatenate(
                [jnp.stack([res0, res1], axis=1).reshape(-1),
                 jnp.zeros(1, jnp.int32)])
            nv = jnp.where(is_pe_out, jnp.take(res, pe_res_idx), nv)
            return jnp.where(t < d, nv, v)

        out_ref[b, :] = jax.lax.fori_loop(0, max_depth, sweep,
                                          vals_ref[b, :])
        return carry

    jax.lax.fori_loop(0, FUSED_LANES, lane, 0)


def fabric_fused_batch(vals0: jnp.ndarray, sel: jnp.ndarray,
                       pin_vals: jnp.ndarray, depths: jnp.ndarray,
                       op: jnp.ndarray, const: jnp.ndarray,
                       imm_mask: jnp.ndarray, imm_val: jnp.ndarray,
                       src: jnp.ndarray, keep: jnp.ndarray,
                       pin_mask: jnp.ndarray, pe_in: jnp.ndarray,
                       pe_res_idx: jnp.ndarray, max_depth: int,
                       word: int = 0xFFFF,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused batched fixpoint: ``max_depth`` masked sweeps with in-kernel
    PE evaluation, one kernel invocation per FUSED_LANES configurations.

    vals0/sel/pin_vals: (B, N); depths: (B,) per-lane sweep counts;
    op/const: (B, P); imm_mask/imm_val: (B, P, 4); src: (N, F) with
    sentinel N for absent fan-in; keep/pin_mask: (N,) int32 flags;
    pe_in: (P, 4) node indices (sentinel N); pe_res_idx: (N,) index into
    the flattened (res0, res1) PE result vector, 2P when the node is not a
    PE output. Returns the (B, N) value matrix after the fixpoint.
    ``interpret=None`` resolves from the backend per call, before the jit
    boundary."""
    if interpret is None:
        interpret = _default_interpret()
    return _fabric_fused_batch_jit(vals0, sel, pin_vals, depths, op,
                                   const, imm_mask, imm_val, src, keep,
                                   pin_mask, pe_in, pe_res_idx, max_depth,
                                   word, interpret)


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "word", "interpret"))
def _fabric_fused_batch_jit(vals0: jnp.ndarray, sel: jnp.ndarray,
                            pin_vals: jnp.ndarray, depths: jnp.ndarray,
                            op: jnp.ndarray, const: jnp.ndarray,
                            imm_mask: jnp.ndarray, imm_val: jnp.ndarray,
                            src: jnp.ndarray, keep: jnp.ndarray,
                            pin_mask: jnp.ndarray, pe_in: jnp.ndarray,
                            pe_res_idx: jnp.ndarray, max_depth: int,
                            word: int, interpret: bool) -> jnp.ndarray:
    b, n = vals0.shape
    f = src.shape[1]
    p = pe_in.shape[0]
    bb = FUSED_LANES
    b_pad = pl.cdiv(max(b, 1), bb) * bb
    # N+1 inside the padded region => index N is the zero sentinel
    n_pad = pl.cdiv(n + 1, 128) * 128
    db, dn = b_pad - b, n_pad - n
    vals_p = jnp.pad(vals0, ((0, db), (0, dn)))
    sel_p = jnp.pad(sel, ((0, db), (0, dn)))
    pin_vals_p = jnp.pad(pin_vals, ((0, db), (0, dn)))
    depths_p = jnp.pad(depths.astype(jnp.int32), (0, db))
    op_p = jnp.pad(op, ((0, db), (0, 0)))
    const_p = jnp.pad(const, ((0, db), (0, 0)))
    imm_mask_p = jnp.pad(imm_mask, ((0, db), (0, 0), (0, 0)))
    imm_val_p = jnp.pad(imm_val, ((0, db), (0, 0), (0, 0)))
    # padded nodes hold their (zero) value: src points at the sentinel,
    # keep=1, unpinned, not a PE output
    src_p = jnp.pad(src, ((0, dn), (0, 0)), constant_values=n)
    keep_p = jnp.pad(keep, (0, dn), constant_values=1)
    pin_mask_p = jnp.pad(pin_mask, (0, dn))
    pe_res_idx_p = jnp.pad(pe_res_idx, (0, dn), constant_values=2 * p)
    grid = (b_pad // bb,)
    out = pl.pallas_call(
        functools.partial(_fused_batch_kernel, max_depth=max_depth,
                          word=word),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), lambda i: (i,)),             # depths
            pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),     # vals
            pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),     # sel
            pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),     # pin_vals
            pl.BlockSpec((bb, p), lambda i: (i, 0)),         # op
            pl.BlockSpec((bb, p), lambda i: (i, 0)),         # const
            pl.BlockSpec((bb, p, 4), lambda i: (i, 0, 0)),   # imm_mask
            pl.BlockSpec((bb, p, 4), lambda i: (i, 0, 0)),   # imm_val
            pl.BlockSpec((n_pad, f), lambda i: (0, 0)),      # src (shared)
            pl.BlockSpec((n_pad,), lambda i: (0,)),          # keep
            pl.BlockSpec((n_pad,), lambda i: (0,)),          # pin_mask
            pl.BlockSpec((p, 4), lambda i: (0, 0)),          # pe_in
            pl.BlockSpec((n_pad,), lambda i: (0,)),          # pe_res_idx
        ],
        out_specs=pl.BlockSpec((bb, n_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_pad), jnp.int32),
        interpret=interpret,
    )(depths_p, vals_p, sel_p, pin_vals_p, op_p, const_p, imm_mask_p,
      imm_val_p, src_p, keep_p, pin_mask_p, jnp.asarray(pe_in),
      pe_res_idx_p)
    return out[:b, :n]


def _fused_run_kernel(depths_ref, sel_ref, op_ref, const_ref, imm_mask_ref,
                      imm_val_ref, ext_ref, src_ref, keep_ref, pin_mask_ref,
                      pin_src_ref, pe_in_ref, pe_res_idx_ref, reg_src_ref,
                      mem_in_ref, io_out_ref, obs_ref, state_ref, *,
                      max_depth: int, word: int, chunk: int, n_reg: int,
                      n_io: int, n_mem: int):
    """One grid step: FUSED_LANES configurations x ``chunk`` fabric cycles.

    The state vector (per lane) is laid out ``[regs | ext io | mem | 0]``;
    ``pin_src`` maps every pinned node into it, so per-cycle re-pinning is
    a gather (scatter-free, like the PE result placement). The state
    output block is pinned to t-block 0, so it survives the sequential
    walk over T chunks and is zeroed whenever a new lane block begins."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _reset_state():
        state_ref[...] = jnp.zeros_like(state_ref[...])

    src = src_ref[...]                              # (NP, F)
    keep = keep_ref[...]                            # (NP,)
    pin_mask = pin_mask_ref[...]                    # (NP,)
    pin_src = pin_src_ref[...]                      # (NP,)
    pe_in = pe_in_ref[...]                          # (P, 4)
    pe_res_idx = pe_res_idx_ref[...]                # (NP,)
    reg_src = reg_src_ref[...]                      # (Rp,)
    mem_in = mem_in_ref[...]                        # (Mp,)
    io_out = io_out_ref[...]                        # (IOp,)
    np_, f = src.shape
    p = pe_in.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (np_, 1), 0)[:, 0] * f
    src_flat = src.reshape(-1)
    pe_in_flat = pe_in.reshape(-1)
    is_pe_out = pe_res_idx < 2 * p

    def lane(b, carry):
        sel = sel_ref[b, :]
        op = op_ref[b, :]
        const = const_ref[b, :]
        imm_mask = imm_mask_ref[b, :, :]
        imm_val = imm_val_ref[b, :, :]
        d = depths_ref[b]
        ext = ext_ref[b, :, :]                      # (chunk, IOp)
        picked = jnp.take(src_flat, rows + sel)

        def cycle(c, st):
            if n_io:
                ext_c = jax.lax.dynamic_index_in_dim(ext, c, axis=0,
                                                     keepdims=False)
                st = st.at[n_reg:n_reg + n_io].set(ext_c[:n_io])
            pinned = jnp.take(st, pin_src)          # (NP,)
            v0 = jnp.where(pin_mask > 0, pinned, 0)

            def sweep(s, v):
                nv = jnp.take(v, picked)
                nv = jnp.where(keep > 0, v, nv)
                nv = jnp.where(pin_mask > 0, pinned, nv)
                ins = jnp.take(nv, pe_in_flat).reshape(p, 4)
                ins = jnp.where(imm_mask > 0, imm_val, ins)
                a_, b_, c_ = ins[:, 0], ins[:, 1], ins[:, 2]
                cand = pe_alu_candidates(a_, b_, c_, const)
                res0 = jnp.take_along_axis(cand, op[None, :],
                                           axis=0)[0] & word
                res1 = a_ & word
                res = jnp.concatenate(
                    [jnp.stack([res0, res1], axis=1).reshape(-1),
                     jnp.zeros(1, jnp.int32)])
                nv = jnp.where(is_pe_out, jnp.take(res, pe_res_idx), nv)
                return jnp.where(s < d, nv, v)

            v = jax.lax.fori_loop(0, max_depth, sweep, v0)
            obs_ref[b, c, :] = jnp.take(v, io_out)
            if n_reg:
                st = st.at[0:n_reg].set(jnp.take(v, reg_src)[:n_reg])
            if n_mem:
                st = st.at[n_reg + n_io:n_reg + n_io + n_mem].set(
                    jnp.take(v, mem_in)[:n_mem])
            return st

        state_ref[b, :] = jax.lax.fori_loop(0, chunk, cycle,
                                            state_ref[b, :])
        return carry

    jax.lax.fori_loop(0, FUSED_LANES, lane, 0)


def fabric_fused_run(sel: jnp.ndarray, ext: jnp.ndarray,
                     depths: jnp.ndarray, op: jnp.ndarray,
                     const: jnp.ndarray, imm_mask: jnp.ndarray,
                     imm_val: jnp.ndarray, src: jnp.ndarray,
                     keep: jnp.ndarray, pin_mask: jnp.ndarray,
                     pin_src: jnp.ndarray, pe_in: jnp.ndarray,
                     pe_res_idx: jnp.ndarray, reg_src: jnp.ndarray,
                     mem_in: jnp.ndarray, io_out: jnp.ndarray,
                     n_reg: int, n_io: int, n_mem: int, max_depth: int,
                     chunk: int = 8, word: int = 0xFFFF,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Streamed fused emulation: T cycles in one kernel, ext-IO from HBM.

    sel: (B, N) mux selects; ext: (B, T, n_io) stimulus (streamed in
    ``chunk``-cycle grid blocks); depths: (B,) per-lane sweep counts;
    op/const: (B, P); imm_mask/imm_val: (B, P, 4); src/keep/pin_mask/
    pe_res_idx as in ``fabric_fused_batch``; pin_src: (N,) node → state
    slot ([regs | io | mem | zero] layout); reg_src: (R,) node feeding
    each register; mem_in: (M,); io_out: (n_io,) observed port nodes.
    Returns (B, T, n_io) observations, bit-identical to scanning
    ``fabric_fused_batch`` cycle by cycle. ``interpret=None`` resolves
    from the backend per call."""
    if interpret is None:
        interpret = _default_interpret()
    return _fabric_fused_run_jit(sel, ext, depths, op, const, imm_mask,
                                 imm_val, src, keep, pin_mask, pin_src,
                                 pe_in, pe_res_idx, reg_src, mem_in,
                                 io_out, n_reg, n_io, n_mem, max_depth,
                                 chunk, word, interpret)


@functools.partial(jax.jit,
                   static_argnames=("n_reg", "n_io", "n_mem", "max_depth",
                                    "chunk", "word", "interpret"))
def _fabric_fused_run_jit(sel: jnp.ndarray, ext: jnp.ndarray,
                          depths: jnp.ndarray, op: jnp.ndarray,
                          const: jnp.ndarray, imm_mask: jnp.ndarray,
                          imm_val: jnp.ndarray, src: jnp.ndarray,
                          keep: jnp.ndarray, pin_mask: jnp.ndarray,
                          pin_src: jnp.ndarray, pe_in: jnp.ndarray,
                          pe_res_idx: jnp.ndarray, reg_src: jnp.ndarray,
                          mem_in: jnp.ndarray, io_out: jnp.ndarray,
                          n_reg: int, n_io: int, n_mem: int,
                          max_depth: int, chunk: int, word: int,
                          interpret: bool) -> jnp.ndarray:
    b, n = sel.shape
    t_len = ext.shape[1]
    f = src.shape[1]
    p = pe_in.shape[0]
    bb = FUSED_LANES
    tc = max(1, chunk)
    b_pad = pl.cdiv(max(b, 1), bb) * bb
    t_pad = pl.cdiv(max(t_len, 1), tc) * tc
    n_pad = pl.cdiv(n + 1, 128) * 128               # index N = zero sentinel
    io_p = pl.cdiv(max(n_io, 1), 128) * 128
    s_len = n_reg + n_io + n_mem + 1                # trailing zero slot
    s_pad = pl.cdiv(s_len, 128) * 128
    r_p = pl.cdiv(max(n_reg, 1), 128) * 128
    m_p = pl.cdiv(max(n_mem, 1), 128) * 128
    db, dn = b_pad - b, n_pad - n
    sel_p = jnp.pad(sel, ((0, db), (0, dn)))
    ext_p = jnp.pad(ext.astype(jnp.int32),
                    ((0, db), (0, t_pad - t_len), (0, io_p - n_io)))
    depths_p = jnp.pad(depths.astype(jnp.int32), (0, db))
    op_p = jnp.pad(op, ((0, db), (0, 0)))
    const_p = jnp.pad(const, ((0, db), (0, 0)))
    imm_mask_p = jnp.pad(imm_mask, ((0, db), (0, 0), (0, 0)))
    imm_val_p = jnp.pad(imm_val, ((0, db), (0, 0), (0, 0)))
    src_p = jnp.pad(src, ((0, dn), (0, 0)), constant_values=n)
    keep_p = jnp.pad(keep, (0, dn), constant_values=1)
    pin_mask_p = jnp.pad(pin_mask, (0, dn))
    pin_src_p = jnp.pad(pin_src, (0, dn), constant_values=s_len - 1)
    pe_res_idx_p = jnp.pad(pe_res_idx, (0, dn), constant_values=2 * p)
    # node-space sentinel n: vals[n] is 0 (padded region holds zeros)
    reg_src_p = jnp.pad(reg_src, (0, r_p - reg_src.shape[0]),
                        constant_values=n)
    mem_in_p = jnp.pad(mem_in, (0, m_p - mem_in.shape[0]),
                       constant_values=n)
    io_out_p = jnp.pad(io_out, (0, io_p - io_out.shape[0]),
                       constant_values=n)
    grid = (b_pad // bb, t_pad // tc)
    obs, _state = pl.pallas_call(
        functools.partial(_fused_run_kernel, max_depth=max_depth,
                          word=word, chunk=tc, n_reg=n_reg, n_io=n_io,
                          n_mem=n_mem),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb,), lambda i, j: (i,)),            # depths
            pl.BlockSpec((bb, n_pad), lambda i, j: (i, 0)),    # sel
            pl.BlockSpec((bb, p), lambda i, j: (i, 0)),        # op
            pl.BlockSpec((bb, p), lambda i, j: (i, 0)),        # const
            pl.BlockSpec((bb, p, 4), lambda i, j: (i, 0, 0)),  # imm_mask
            pl.BlockSpec((bb, p, 4), lambda i, j: (i, 0, 0)),  # imm_val
            pl.BlockSpec((bb, tc, io_p), lambda i, j: (i, j, 0)),  # ext
            pl.BlockSpec((n_pad, f), lambda i, j: (0, 0)),     # src
            pl.BlockSpec((n_pad,), lambda i, j: (0,)),         # keep
            pl.BlockSpec((n_pad,), lambda i, j: (0,)),         # pin_mask
            pl.BlockSpec((n_pad,), lambda i, j: (0,)),         # pin_src
            pl.BlockSpec((p, 4), lambda i, j: (0, 0)),         # pe_in
            pl.BlockSpec((n_pad,), lambda i, j: (0,)),         # pe_res_idx
            pl.BlockSpec((r_p,), lambda i, j: (0,)),           # reg_src
            pl.BlockSpec((m_p,), lambda i, j: (0,)),           # mem_in
            pl.BlockSpec((io_p,), lambda i, j: (0,)),          # io_out
        ],
        out_specs=[
            pl.BlockSpec((bb, tc, io_p), lambda i, j: (i, j, 0)),  # obs
            pl.BlockSpec((bb, s_pad), lambda i, j: (i, 0)),    # state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, t_pad, io_p), jnp.int32),
            jax.ShapeDtypeStruct((b_pad, s_pad), jnp.int32),
        ],
        interpret=interpret,
    )(depths_p, sel_p, op_p, const_p, imm_mask_p, imm_val_p, ext_p,
      src_p, keep_p, pin_mask_p, pin_src_p, jnp.asarray(pe_in),
      pe_res_idx_p, reg_src_p, mem_in_p, io_out_p)
    return obs[:b, :t_len, :n_io]
