"""Pallas kernel: blocked min-plus (tropical) relaxation for routing
wavefronts.

Hardware adaptation (DESIGN.md §2): per-net A* is pointer-chasing and has
no TPU analogue, so the wavefront-cost computation is reformulated as
iterated tropical matrix-vector products over the (tile-level) routing
graph:

    d'[b, j] = min(d[b, j], min_i (d[b, i] + w[i, j]))

for a *batch* of nets b at once. ``w`` is the dense inf-padded adjacency
of the coarse routing graph (tiles, not IR nodes: N = W*H <= 4096, so the
dense tile fits VMEM in 128x128 blocks). Iterating to fixpoint yields all
shortest path costs (Bellman-Ford over the tropical semiring); the
PathFinder outer loop then uses these costs as its A* lower bounds /
batched wavefronts.

Validated in interpret mode against ``ref.minplus_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128
INF = jnp.float32(3.0e38) / 4


def _minplus_kernel(d_ref, w_ref, out_ref):
    """d: (B, BLOCK_i) costs; w: (BLOCK_i, BLOCK_j); out: (B, BLOCK_j).

    Accumulates the running minimum across the i-grid dimension.
    """
    i = pl.program_id(1)
    d = d_ref[...]                              # (B, bi)
    w = w_ref[...]                              # (bi, bj)
    cand = jnp.min(d[:, :, None] + w[None, :, :], axis=1)   # (B, bj)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = cand

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = jnp.minimum(out_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_step(d: jnp.ndarray, w: jnp.ndarray,
                 interpret: bool = True) -> jnp.ndarray:
    """One relaxation: returns min(d, d ⊗ w) for batched cost vectors.

    d: (B, N) float32; w: (N, N) float32 inf-padded adjacency (w[i,i]=0).
    """
    b, n = d.shape
    n_pad = pl.cdiv(n, BLOCK) * BLOCK
    d_p = jnp.pad(d, ((0, 0), (0, n_pad - n)), constant_values=INF)
    w_p = jnp.pad(w, ((0, n_pad - n), (0, n_pad - n)), constant_values=INF)
    grid = (n_pad // BLOCK, n_pad // BLOCK)     # (j, i): i inner accumulates
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, BLOCK), lambda j, i: (0, i)),
            pl.BlockSpec((BLOCK, BLOCK), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((b, BLOCK), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
        interpret=interpret,
    )(d_p, w_p)
    return jnp.minimum(d, out[:, :n])


def minplus_fixpoint(d0: jnp.ndarray, w: jnp.ndarray, iters: int,
                     interpret: bool = True) -> jnp.ndarray:
    """Iterate to (bounded) fixpoint: all-sources shortest path costs."""

    def body(_, d):
        return minplus_step(d, w, interpret=interpret)

    return jax.lax.fori_loop(0, iters, body, d0)
