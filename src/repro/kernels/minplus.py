"""Pallas kernel: blocked min-plus (tropical) relaxation for routing
wavefronts.

Hardware adaptation (DESIGN.md §2): per-net A* is pointer-chasing and has
no TPU analogue, so the wavefront-cost computation is reformulated as
iterated tropical matrix-vector products over the (tile-level) routing
graph:

    d'[b, j] = min(d[b, j], min_i (d[b, i] + w[i, j]))

for a *batch* of nets b at once. ``w`` is the dense inf-padded adjacency
of the coarse routing graph (tiles, not IR nodes: N = W*H <= 4096, so the
dense tile fits VMEM in 128x128 blocks). Iterating to fixpoint yields all
shortest path costs (Bellman-Ford over the tropical semiring); the
PathFinder outer loop (``repro.core.pnr.route``, ``strategy="minplus"``)
uses these cost fields as its batched A* lower bounds.

``minplus_wavefront`` is the router-facing entry point: it relaxes in
device-side blocks and stops as soon as the field stops changing, so the
iteration count adapts to the graph diameter instead of paying the full
Bellman-Ford ``N - 1`` bound. ``engine="auto"`` runs the Pallas kernel
where it compiles (TPU) and the jitted dense reference elsewhere — the
same dispatch convention as the fabric kernels.

Validated in interpret mode against ``ref.minplus_ref`` /
``ref.minplus_fixpoint_ref`` and against host Dijkstra in
``tests/test_route_minplus.py``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128
INF = jnp.float32(3.0e38) / 4


def _default_interpret() -> bool:
    """Compiled on TPU, interpret elsewhere — resolved per call (mirrors
    ``fabric_step._default_interpret``; a mid-process backend swap must
    not see a stale value)."""
    return jax.default_backend() != "tpu"


def _minplus_kernel(d_ref, w_ref, out_ref):
    """d: (B, BLOCK_i) costs; w: (BLOCK_i, BLOCK_j); out: (B, BLOCK_j).

    Accumulates the running minimum across the i-grid dimension.
    """
    i = pl.program_id(1)
    d = d_ref[...]                              # (B, bi)
    w = w_ref[...]                              # (bi, bj)
    cand = jnp.min(d[:, :, None] + w[None, :, :], axis=1)   # (B, bj)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = cand

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = jnp.minimum(out_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("interpret",))
def minplus_step(d: jnp.ndarray, w: jnp.ndarray,
                 interpret: bool = True) -> jnp.ndarray:
    """One relaxation: returns min(d, d ⊗ w) for batched cost vectors.

    d: (B, N) float32; w: (N, N) float32 inf-padded adjacency (w[i,i]=0).
    """
    b, n = d.shape
    n_pad = pl.cdiv(n, BLOCK) * BLOCK
    d_p = jnp.pad(d, ((0, 0), (0, n_pad - n)), constant_values=INF)
    w_p = jnp.pad(w, ((0, n_pad - n), (0, n_pad - n)), constant_values=INF)
    grid = (n_pad // BLOCK, n_pad // BLOCK)     # (j, i): i inner accumulates
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, BLOCK), lambda j, i: (0, i)),
            pl.BlockSpec((BLOCK, BLOCK), lambda j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((b, BLOCK), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
        interpret=interpret,
    )(d_p, w_p)
    return jnp.minimum(d, out[:, :n])


def minplus_fixpoint(d0: jnp.ndarray, w: jnp.ndarray, iters: int,
                     interpret: bool = True) -> jnp.ndarray:
    """Iterate to (bounded) fixpoint: all-sources shortest path costs."""

    def body(_, d):
        return minplus_step(d, w, interpret=interpret)

    return jax.lax.fori_loop(0, iters, body, d0)


@functools.partial(jax.jit, static_argnames=("iters",))
def _ref_block(d: jnp.ndarray, w: jnp.ndarray, iters: int) -> jnp.ndarray:
    """``iters`` dense relaxations of the pure-jnp oracle under one jit."""

    def body(_, dd):
        return jnp.minimum(dd, jnp.min(dd[:, :, None] + w[None], axis=1))

    return jax.lax.fori_loop(0, iters, body, d)


def minplus_wavefront(d0: jnp.ndarray, w: jnp.ndarray,
                      block_iters: int = 8,
                      engine: str = "auto",
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Relax ``d0`` to the true shortest-path fixpoint, adaptively.

    Runs ``block_iters`` relaxations per device dispatch and stops when a
    block leaves the field unchanged (a min-plus fixpoint is stable, so
    one unchanged block proves convergence); a cap of ``N - 1`` total
    relaxations preserves the Bellman-Ford bound on adversarial graphs.

    engine: ``"pallas"`` forces the blocked kernel, ``"ref"`` the jitted
    dense reference, ``"auto"`` picks the kernel only where it compiles
    (TPU) — on interpret-mode hosts the reference is the faster exact
    implementation of the same contract.
    """
    if interpret is None:
        interpret = _default_interpret()
    if engine not in ("auto", "pallas", "ref"):
        raise ValueError(f"unknown minplus engine {engine!r}")
    use_kernel = engine == "pallas" or (engine == "auto" and not interpret)
    d = jnp.asarray(d0, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    n = w.shape[0]
    max_blocks = max(1, -(-max(n - 1, 1) // block_iters))
    for _ in range(max_blocks):
        if use_kernel:
            nd = minplus_fixpoint(d, w, block_iters, interpret=interpret)
        else:
            nd = _ref_block(d, w, block_iters)
        if bool(jnp.array_equal(nd, d)):
            return nd
        d = nd
    return d
