from .step import TrainState, make_train_step, loss_fn  # noqa: F401
