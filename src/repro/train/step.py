"""Training step: loss, grads, optimizer update, with microbatch gradient
accumulation, mixed precision (bf16 params/activations, f32 loss and
optimizer math) and optional int8 error-feedback gradient compression on
the cross-pod reduction (runtime/compression.py)."""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import Optimizer


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jnp.ndarray


def init_train_state(model, optimizer: Optimizer, rng) -> TrainState:
    params = model.init_params(rng)
    return TrainState(params=params, opt=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def train_state_specs(model, optimizer: Optimizer):
    from jax.sharding import PartitionSpec as P
    p_specs = model.param_specs()
    return TrainState(params=p_specs,
                      opt=optimizer.state_specs(p_specs), step=P())


def loss_fn(model, params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    """Sequence-chunked cross entropy: the (B, S, V) logits tensor never
    materializes (at 150k vocab x 1M tokens it would be hundreds of GiB
    per device). Hidden states are unembedded chunk-by-chunk under remat.
    """
    cfg = model.cfg
    hidden = model.hidden(params, batch)           # (B, S, D)
    w = model.unembed(params).astype(cfg.adtype)   # (D, V)
    labels = batch["labels"]
    b, s, d = hidden.shape
    chunk = min(cfg.ce_seq_chunk or s, s)
    if s % chunk:
        chunk = s                                  # fallback: one chunk

    n_chunks = s // chunk
    hc = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def chunk_ce(h, y):
        logits = (h @ w).astype(jnp.float32)       # (B, chunk, Vpad)
        if cfg.padded_vocab > cfg.vocab_size:      # mask pad logits
            v_ids = jnp.arange(cfg.padded_vocab)
            logits = jnp.where(v_ids[None, None] < cfg.vocab_size,
                               logits, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        hits = ((jnp.argmax(logits, -1) == y) * mask).sum()
        return -(ll * mask).sum(), mask.sum(), hits

    def body(carry, xs):
        h, y = xs
        nll, n, hits = jax.checkpoint(chunk_ce)(h, y)
        return (carry[0] + nll, carry[1] + n, carry[2] + hits), None

    (nll, n, hits), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
               jnp.zeros((), jnp.float32)), (hc, lc))
    loss = nll / jnp.maximum(n, 1.0)
    acc = hits / jnp.maximum(n, 1.0)
    return loss, {"loss": loss, "accuracy": acc}


def make_train_step(model, optimizer: Optimizer,
                    microbatches: int = 0,
                    grad_compression: Optional[str] = None,
                    pod_axis: Optional[str] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 splits the batch and accumulates grads via scan
    (memory/perf knob); grad_compression="int8_ef" compresses the
    cross-pod gradient reduction with error feedback.
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)
        return grads, metrics

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState,
                                                            Dict]:
        params = state.params
        if microbatches and microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mb_batch):
                g_acc, m_acc = carry
                g, m = grads_of(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b_: a + b_, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "accuracy": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_fn, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        else:
            grads, metrics = grads_of(params, batch)

        if grad_compression == "int8_ef" and pod_axis is not None:
            from repro.runtime.compression import compressed_grad_sync
            grads = compressed_grad_sync(grads, pod_axis)

        updates, new_opt = optimizer.update(grads, state.opt, params,
                                            state.step)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step
