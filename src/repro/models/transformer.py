"""Decoder-only transformer LM covering the dense / MoE / VLM assigned
architectures (tinyllama, phi3, deepseek-coder, qwen3, kimi-k2, granite,
internvl2 backbone).

Design: pre-norm blocks, GQA attention (+optional qk-norm), SwiGLU MLP or
top-k MoE, RoPE, stacked-layer scan, optional leading dense layers before
the MoE stack (Kimi-style), optional vision-patch prefix (InternVL stub
frontend: ``input_specs`` feeds precomputed patch embeddings).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from .config import ModelConfig
from .stacking import (scan_layers, scan_layers_with_cache, stacked_init,
                       stacked_specs)


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        m = cfg.moe
        self.n_dense = (cfg.num_layers if m is None or m.num_experts == 0
                        else m.first_k_dense)
        self.n_moe = cfg.num_layers - self.n_dense

    # ------------------------------------------------------------ params
    def _init_dense_layer(self, rng):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(rng, 4)
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
            "mlp": L.init_mlp(k2, cfg),
        }

    def _init_moe_layer(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {
            "ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
            "moe": L.init_moe(k2, cfg),
        }

    def init_params(self, rng) -> Dict:
        cfg = self.cfg
        keys = jax.random.split(rng, 6)
        p = {
            "embed": L._init(keys[0], (cfg.padded_vocab, cfg.d_model),
                             1.0, cfg.pdtype),
            "ln_f": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
        }
        if not cfg.tie_embeddings:
            p["unembed"] = L._init(keys[1], (cfg.d_model, cfg.padded_vocab),
                                   1.0 / math.sqrt(cfg.d_model), cfg.pdtype)
        if self.n_dense:
            p["dense_layers"] = stacked_init(self._init_dense_layer,
                                             keys[2], self.n_dense)
        if self.n_moe:
            p["moe_layers"] = stacked_init(self._init_moe_layer, keys[3],
                                           self.n_moe)
        if cfg.vlm is not None:
            p["patch_proj"] = L._init(keys[4],
                                      (cfg.vlm.d_patch, cfg.d_model),
                                      1.0 / math.sqrt(cfg.vlm.d_patch),
                                      cfg.pdtype)
        return p

    def param_specs(self) -> Dict:
        cfg = self.cfg
        dense_spec = {
            "ln1": L.spec_rmsnorm(), "attn": L.spec_attention(cfg),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg),
        }
        sp = {
            "embed": P("model", None),
            "ln_f": L.spec_rmsnorm(),
        }
        if not cfg.tie_embeddings:
            sp["unembed"] = P(None, "model")
        if self.n_dense:
            sp["dense_layers"] = stacked_specs(dense_spec, self.n_dense)
        if self.n_moe:
            moe_spec = {
                "ln1": L.spec_rmsnorm(), "attn": L.spec_attention(cfg),
                "ln2": L.spec_rmsnorm(), "moe": L.spec_moe(cfg),
            }
            sp["moe_layers"] = stacked_specs(moe_spec, self.n_moe)
        if cfg.vlm is not None:
            sp["patch_proj"] = P(None, None)
        return sp

    # ------------------------------------------------------------ forward
    def _block(self, lp, x, extra, kind: str):
        cfg = self.cfg
        positions = extra
        x = L.shard_batch(x, cfg)
        h, _ = L.attention(lp["attn"], L.rms_norm(x, lp["ln1"],
                                                  cfg.norm_eps),
                           cfg, positions)
        x = x + h
        z = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if kind == "dense":
            x = x + L.mlp(lp["mlp"], z, cfg)
        else:
            x = x + L.moe(lp["moe"], z, cfg)
        return L.shard_batch(x, cfg)

    def _embed(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.adtype)
        if cfg.vlm is not None and "patches" in batch:
            vis = (batch["patches"].astype(cfg.adtype)
                   @ params["patch_proj"].astype(cfg.adtype))
            x = jnp.concatenate([vis, x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        return x, positions

    def hidden(self, params: Dict, batch: Dict) -> jnp.ndarray:
        """Final-norm hidden states (B, S_tokens, D)."""
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        x = L.shard_batch(x, cfg)
        if self.n_dense:
            x = scan_layers(lambda lp, h, e: self._block(lp, h, e, "dense"),
                            params["dense_layers"], x, remat=cfg.remat,
                            carry_extra=positions)
        if self.n_moe:
            x = scan_layers(lambda lp, h, e: self._block(lp, h, e, "moe"),
                            params["moe_layers"], x, remat=cfg.remat,
                            carry_extra=positions)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.vlm is not None and "patches" in batch:
            x = x[:, -batch["tokens"].shape[1]:]
        return x

    def unembed(self, params: Dict) -> jnp.ndarray:
        return (params["embed"].T if self.cfg.tie_embeddings
                else params["unembed"])

    def logits(self, params: Dict, batch: Dict) -> jnp.ndarray:
        return (self.hidden(params, batch)
                @ self.unembed(params).astype(self.cfg.adtype)) \
            .astype(jnp.float32)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        shape = (batch, cfg.kv_heads, max_seq, cfg.hd)

        def mk(n):
            return {
                "k": jnp.zeros((n,) + shape, cfg.adtype),
                "v": jnp.zeros((n,) + shape, cfg.adtype),
            }

        cache = {"index": jnp.zeros((), jnp.int32)}
        if self.n_dense:
            cache["dense"] = mk(self.n_dense)
        if self.n_moe:
            cache["moe"] = mk(self.n_moe)
        return cache

    def cache_specs(self) -> Dict:
        # "seq": batch on data, SEQUENCE on model — kv-head counts (4/8)
        # don't divide the 16-way model axis, but the cache length always
        # does; decode attention becomes sequence-parallel with a small
        # psum. "batch": replicate over model (more HBM, no reshard) —
        # the §Perf decode experiment compares the two.
        if self.cfg.kv_cache_shard == "seq":
            kv = {"k": P(None, "data", None, "model", None),
                  "v": P(None, "data", None, "model", None)}
        else:
            kv = {"k": P(None, "data", None, None, None),
                  "v": P(None, "data", None, None, None)}
        sp = {"index": P()}
        if self.n_dense:
            sp["dense"] = dict(kv)
        if self.n_moe:
            sp["moe"] = dict(kv)
        return sp

    def _block_cached(self, lp, x, layer_cache, extra, kind: str):
        cfg = self.cfg
        positions, idx = extra
        h, new_kv = L.attention(
            lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps), cfg,
            positions, cache=(layer_cache["k"], layer_cache["v"], idx))
        x = x + h
        z = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if kind == "dense":
            x = x + L.mlp(lp["mlp"], z, cfg)
        else:
            x = x + L.moe(lp["moe"], z, cfg)
        return x, {"k": new_kv[0], "v": new_kv[1]}

    def forward_cached(self, params: Dict, cache: Dict,
                       batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """Shared prefill/decode: consumes tokens, appends to cache."""
        cfg = self.cfg
        tokens = batch["tokens"]
        idx = cache["index"]
        x = params["embed"][tokens].astype(cfg.adtype)
        if cfg.vlm is not None and "patches" in batch:
            vis = (batch["patches"].astype(cfg.adtype)
                   @ params["patch_proj"].astype(cfg.adtype))
            x = jnp.concatenate([vis, x], axis=1)
        b, s, _ = x.shape
        positions = idx + jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        new_cache = {"index": idx + s}
        for kind, key in (("dense", "dense"), ("moe", "moe")):
            if key == "dense" and not self.n_dense:
                continue
            if key == "moe" and not self.n_moe:
                continue
            x, nc = scan_layers_with_cache(
                lambda lp, h, c, e, _k=kind: self._block_cached(
                    lp, h, c, e, _k),
                params[f"{key}_layers"], x, cache[key],
                carry_extra=(positions, idx))
            new_cache[key] = nc
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        x_last = x[:, -1:]
        w = (params["embed"].T if cfg.tie_embeddings
             else params["unembed"])
        logits = (x_last @ w.astype(cfg.adtype)).astype(jnp.float32)
        return logits, new_cache

    prefill = forward_cached
    decode_step = forward_cached
