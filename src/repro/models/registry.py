"""Model registry: family -> model class."""
from __future__ import annotations

from .config import ModelConfig
from .mamba2 import Mamba2LM
from .recurrentgemma import RecurrentGemmaLM
from .transformer import TransformerLM
from .whisper import WhisperEncDec

ARCH_FAMILIES = {
    "dense": TransformerLM,
    "moe": TransformerLM,
    "vlm": TransformerLM,
    "hybrid": RecurrentGemmaLM,
    "audio": WhisperEncDec,
    "ssm": Mamba2LM,
}


def build_model(cfg: ModelConfig):
    try:
        cls = ARCH_FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None
    return cls(cfg)
