"""Whisper-medium encoder–decoder (arXiv:2212.04356). The conv frontend is
a STUB per the assignment: ``input_specs()`` feeds precomputed frame
embeddings (B, 1500, d_frame); a linear projection stands in for the two
conv layers. Pre-LN LayerNorm (with bias), GELU MLPs, MHA (kv=16).

"seq_len" for the decode/prefill shapes is the *decoder* self-attention
length; the encoder length is fixed at 1500 frames.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from .config import ModelConfig
from .stacking import scan_layers, stacked_init, stacked_specs


class WhisperEncDec:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ params
    def _init_enc_layer(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {"ln1": L.init_layernorm(cfg.d_model, cfg.pdtype),
                "attn": L.init_attention(k1, cfg),
                "ln2": L.init_layernorm(cfg.d_model, cfg.pdtype),
                "mlp": L.init_mlp(k2, cfg)}

    def _init_dec_layer(self, rng):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"ln1": L.init_layernorm(cfg.d_model, cfg.pdtype),
                "self_attn": L.init_attention(k1, cfg),
                "ln_x": L.init_layernorm(cfg.d_model, cfg.pdtype),
                "cross_attn": L.init_attention(k2, cfg),
                "ln2": L.init_layernorm(cfg.d_model, cfg.pdtype),
                "mlp": L.init_mlp(k3, cfg)}

    def init_params(self, rng) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        e = cfg.encdec
        return {
            "frame_proj": L._init(ks[0], (e.d_frame, cfg.d_model),
                                  1.0 / math.sqrt(e.d_frame), cfg.pdtype),
            "enc_pos": L._init(ks[1], (e.encoder_seq, cfg.d_model), 0.02,
                               cfg.pdtype),
            "enc_layers": stacked_init(self._init_enc_layer, ks[2],
                                       e.encoder_layers),
            "ln_enc": L.init_layernorm(cfg.d_model, cfg.pdtype),
            "embed": L._init(ks[3], (cfg.padded_vocab, cfg.d_model), 1.0,
                             cfg.pdtype),
            "dec_pos": L._init(ks[4], (cfg.max_seq, cfg.d_model), 0.02,
                               cfg.pdtype),
            "dec_layers": stacked_init(self._init_dec_layer, ks[5],
                                       cfg.num_layers),
            "ln_f": L.init_layernorm(cfg.d_model, cfg.pdtype),
        }

    def param_specs(self) -> Dict:
        cfg = self.cfg
        enc_spec = {"ln1": L.spec_layernorm(),
                    "attn": L.spec_attention(cfg),
                    "ln2": L.spec_layernorm(), "mlp": L.spec_mlp(cfg)}
        dec_spec = {"ln1": L.spec_layernorm(),
                    "self_attn": L.spec_attention(cfg),
                    "ln_x": L.spec_layernorm(),
                    "cross_attn": L.spec_attention(cfg),
                    "ln2": L.spec_layernorm(), "mlp": L.spec_mlp(cfg)}
        return {
            "frame_proj": P(None, "model"),
            "enc_pos": P(None, None),
            "enc_layers": stacked_specs(enc_spec, cfg.encdec.encoder_layers),
            "ln_enc": L.spec_layernorm(),
            "embed": P("model", None),
            "dec_pos": P(None, None),
            "dec_layers": stacked_specs(dec_spec, cfg.num_layers),
            "ln_f": L.spec_layernorm(),
        }

    # ------------------------------------------------------------ encoder
    def encode(self, params: Dict, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = frames.astype(cfg.adtype) @ params["frame_proj"]
        x = x + params["enc_pos"][None, :x.shape[1]].astype(cfg.adtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def block(lp, h, e):
            h = L.shard_batch(h, cfg)
            z = L.layer_norm(h, lp["ln1"])
            a, _ = self._bidir_attn(lp["attn"], z)
            h = h + a
            h = h + L.mlp(lp["mlp"], L.layer_norm(h, lp["ln2"]), cfg)
            return L.shard_batch(h, cfg)

        x = scan_layers(block, params["enc_layers"], x, remat=cfg.remat,
                        carry_extra=positions)
        return L.layer_norm(x, params["ln_enc"])

    def _bidir_attn(self, p, x, kv: jnp.ndarray = None):
        """Bidirectional (or cross) attention, no RoPE (whisper style)."""
        cfg = self.cfg
        hq, hkv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
        src = x if kv is None else kv
        q = L._split_heads(x @ p["wq"], hq, hd)
        k = L._split_heads(src @ p["wk"], hkv, hd)
        v = L._split_heads(src @ p["wv"], hkv, hd)
        out = L._sdpa(q, k, v, causal=False, window=0, q_offset=0)
        return L._merge_heads(out) @ p["wo"], None

    # ------------------------------------------------------------ training
    def hidden(self, params: Dict, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(cfg.adtype)
        x = x + params["dec_pos"][None, :x.shape[1]].astype(cfg.adtype)
        x = L.shard_batch(x, cfg)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def block(lp, h, e):
            enc_out, pos = e
            h = L.shard_batch(h, cfg)
            z = L.layer_norm(h, lp["ln1"])
            hq, hkv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
            q = L._split_heads(z @ lp["self_attn"]["wq"], hq, hd)
            k = L._split_heads(z @ lp["self_attn"]["wk"], hkv, hd)
            v = L._split_heads(z @ lp["self_attn"]["wv"], hkv, hd)
            a = L._sdpa(q, k, v, causal=True, window=0, q_offset=0)
            h = h + L._merge_heads(a) @ lp["self_attn"]["wo"]
            zx = L.layer_norm(h, lp["ln_x"])
            cx, _ = self._bidir_attn(lp["cross_attn"], zx, kv=enc_out)
            h = h + cx
            h = h + L.mlp(lp["mlp"], L.layer_norm(h, lp["ln2"]), cfg)
            return L.shard_batch(h, cfg)

        x = scan_layers(block, params["dec_layers"], x, remat=cfg.remat,
                        carry_extra=(enc, positions))
        return L.layer_norm(x, params["ln_f"])

    def unembed(self, params: Dict) -> jnp.ndarray:
        return params["embed"].T

    def logits(self, params: Dict, batch: Dict) -> jnp.ndarray:
        return (self.hidden(params, batch)
                @ self.unembed(params).astype(self.cfg.adtype)) \
            .astype(jnp.float32)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        l = cfg.num_layers
        e = cfg.encdec
        kv = (batch, cfg.kv_heads, max_seq, cfg.hd)
        xkv = (batch, cfg.kv_heads, e.encoder_seq, cfg.hd)
        return {
            "index": jnp.zeros((), jnp.int32),
            "k": jnp.zeros((l,) + kv, cfg.adtype),
            "v": jnp.zeros((l,) + kv, cfg.adtype),
            # cross-attention K/V are computed once from the encoder
            "xk": jnp.zeros((l,) + xkv, cfg.adtype),
            "xv": jnp.zeros((l,) + xkv, cfg.adtype),
        }

    def cache_specs(self) -> Dict:
        kv = P(None, "data", "model", None, None)
        return {"index": P(), "k": kv, "v": kv, "xk": kv, "xv": kv}

    def prefill(self, params: Dict, cache: Dict,
                batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """Encode audio, precompute cross K/V, then run decoder tokens."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])

        def xkv_fn(lp):
            hkv, hd = cfg.kv_heads, cfg.hd
            xk = L._split_heads(enc @ lp["cross_attn"]["wk"], hkv, hd)
            xv = L._split_heads(enc @ lp["cross_attn"]["wv"], hkv, hd)
            return xk, xv

        xk, xv = jax.vmap(xkv_fn)(params["dec_layers"])
        cache = dict(cache)
        cache["xk"], cache["xv"] = xk, xv
        return self.decode_step(params, cache, batch)

    def decode_step(self, params: Dict, cache: Dict,
                    batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        tokens = batch["tokens"]
        idx = cache["index"]
        x = params["embed"][tokens].astype(cfg.adtype)
        b, s, _ = x.shape
        pos_ids = idx + jnp.arange(s)
        x = x + jnp.take(params["dec_pos"], pos_ids, axis=0)[None] \
            .astype(cfg.adtype)

        def block(h, inp):
            lp, kc, vc, xk, xv = inp
            hq, hkv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
            z = L.layer_norm(h, lp["ln1"])
            q = L._split_heads(z @ lp["self_attn"]["wq"], hq, hd)
            k = L._split_heads(z @ lp["self_attn"]["wk"], hkv, hd)
            v = L._split_heads(z @ lp["self_attn"]["wv"], hkv, hd)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, idx, axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, idx, axis=2)
            a = L._sdpa(q, kc, vc, causal=True, window=0, q_offset=idx)
            h = h + L._merge_heads(a) @ lp["self_attn"]["wo"]
            zx = L.layer_norm(h, lp["ln_x"])
            qx = L._split_heads(zx @ lp["cross_attn"]["wq"], hq, hd)
            ax = L._sdpa(qx, xk, xv, causal=False, window=0, q_offset=0)
            h = h + L._merge_heads(ax) @ lp["cross_attn"]["wo"]
            h = h + L.mlp(lp["mlp"], L.layer_norm(h, lp["ln2"]), cfg)
            return h, (kc, vc)

        x, (new_k, new_v) = jax.lax.scan(
            block, x, (params["dec_layers"], cache["k"], cache["v"],
                       cache["xk"], cache["xv"]))
        x = L.layer_norm(x, params["ln_f"])
        logits = (x[:, -1:] @ params["embed"].T.astype(cfg.adtype)) \
            .astype(jnp.float32)
        new_cache = dict(cache)
        new_cache.update({"index": idx + s, "k": new_k, "v": new_v})
        return logits, new_cache
