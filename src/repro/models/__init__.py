from .registry import build_model, ARCH_FAMILIES  # noqa: F401
