"""Mamba-2 (SSD, state-space duality, arXiv:2405.21060): attention-free
LM. Decode is O(1) in sequence length (carried (NH, P, N) state), so the
long_500k cell runs for this arch. Training/prefill uses the chunked SSD
algorithm (Pallas kernel or the jnp chunked path)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from .config import ModelConfig
from .stacking import scan_layers, stacked_init, stacked_specs


class Mamba2LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _init_layer(self, rng):
        cfg = self.cfg
        return {"ln": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
                "mixer": L.init_mamba2(rng, cfg)}

    def init_params(self, rng) -> Dict:
        cfg = self.cfg
        k0, k1 = jax.random.split(rng)
        return {
            "embed": L._init(k0, (cfg.padded_vocab, cfg.d_model), 1.0,
                             cfg.pdtype),
            "ln_f": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
            "layers": stacked_init(self._init_layer, k1, cfg.num_layers),
        }

    def param_specs(self) -> Dict:
        cfg = self.cfg
        lspec = {"ln": L.spec_rmsnorm(), "mixer": L.spec_mamba2(cfg)}
        return {"embed": P("model", None), "ln_f": L.spec_rmsnorm(),
                "layers": stacked_specs(lspec, cfg.num_layers)}

    def hidden(self, params: Dict, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.adtype)
        x = L.shard_batch(x, cfg)

        def block(lp, h, _):
            h = L.shard_batch(h, cfg)
            y, _st = L.mamba2(lp["mixer"],
                              L.rms_norm(h, lp["ln"], cfg.norm_eps), cfg)
            return L.shard_batch(h + y, cfg)

        x = scan_layers(block, params["layers"], x, remat=cfg.remat)
        return L.rms_norm(x, params["ln_f"], cfg.norm_eps)

    def unembed(self, params: Dict) -> jnp.ndarray:
        return params["embed"].T

    def logits(self, params: Dict, batch: Dict) -> jnp.ndarray:
        return (self.hidden(params, batch)
                @ self.unembed(params).astype(self.cfg.adtype)) \
            .astype(jnp.float32)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        s_cfg = cfg.ssm
        d_in = s_cfg.expand * cfg.d_model
        nh = s_cfg.num_heads or d_in // s_cfg.head_dim
        ph = d_in // nh
        n = s_cfg.state_dim
        conv_c = d_in + 2 * n
        l = cfg.num_layers
        return {
            "index": jnp.zeros((), jnp.int32),
            "h": jnp.zeros((l, batch, nh, ph, n), jnp.float32),
            "conv": jnp.zeros((l, batch, s_cfg.conv_width - 1, conv_c),
                              cfg.adtype),
        }

    def cache_specs(self) -> Dict:
        return {"index": P(),
                "h": P(None, "data", "model", None, None),
                "conv": P(None, "data", None, "model")}

    def forward_cached(self, params: Dict, cache: Dict,
                       batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.adtype)
        idx = cache["index"]

        def block(h, inp):
            lp, st_h, st_conv = inp
            y, new_st = L.mamba2(lp["mixer"],
                                 L.rms_norm(h, lp["ln"], cfg.norm_eps),
                                 cfg, state=(st_h, st_conv))
            return h + y, new_st

        x, (new_h, new_conv) = jax.lax.scan(
            block, x, (params["layers"], cache["h"], cache["conv"]))
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, -1:] @ params["embed"].T.astype(cfg.adtype)) \
            .astype(jnp.float32)
        return logits, {"index": idx + batch["tokens"].shape[1],
                        "h": new_h, "conv": new_conv}

    prefill = forward_cached
    decode_step = forward_cached
