"""Model configuration shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0          # leading dense layers (Kimi-K2 style)
    d_ff_shared: int = 0            # shared-expert FFN width (0 = none)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128            # N
    head_dim: int = 64              # P
    num_heads: int = 0              # derived if 0: d_inner / head_dim
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma temporal-mixing pattern."""
    pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    window: int = 2048
    lru_width: int = 0              # defaults to d_model


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder."""
    encoder_layers: int = 24
    encoder_seq: int = 1500         # audio frames after the conv stub
    d_frame: int = 128              # stub frontend frame feature size


@dataclass(frozen=True)
class VLMConfig:
    """InternVL-style stub vision frontend."""
    num_patches: int = 256
    d_patch: int = 1024             # stub ViT feature size


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense|moe|vlm|hybrid|audio|ssm
    num_layers: int = 4
    d_model: int = 512
    num_heads: int = 8
    kv_heads: int = 8
    head_dim: int = 0               # derived d_model // num_heads if 0
    d_ff: int = 2048
    vocab_size: int = 32000
    max_seq: int = 4096
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    activation: str = "swiglu"      # swiglu|gelu
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    remat: str = "none"             # none|full|dots (activation ckpt policy)
    attn_impl: str = "xla"          # xla | pallas
    #: mesh axes the batch dim is sharded over (dryrun sets ('pod','data'))
    batch_axes: tuple = ("data",)
    #: chunk size for memory-efficient attention (0 = never chunk)
    attn_chunk: int = 2048
    #: keep attention scores in f32 (False halves score HBM traffic)
    attn_scores_f32: bool = True
    #: GQA K/V expansion: "repeat" (shard-friendly) | "grouped" (fewer
    #: K/V bytes, misaligns when kv_heads < model axis — see §Perf)
    gqa_mode: str = "repeat"
    #: re-shard q/k/v head-wise before attention (Megatron pattern):
    #: kills the score partial-sum all-reduce from contraction-sharded
    #: head_dim (§Perf hillclimb)
    attn_head_shard: bool = False
    #: KV-cache layout: "seq" shards cache length on the model axis
    #: (sequence-parallel decode attention); "batch" replicates it over
    #: model and shards batch only (§Perf decode hillclimb)
    kv_cache_shard: str = "seq"
    #: MoE dispatch groups (GShard-style grouped capacity; = data axis so
    #: each group's dispatch stays shard-local)
    moe_groups: int = 16
    #: vocab-chunked cross entropy: tokens per chunk (avoids (B,S,V) logits)
    ce_seq_chunk: int = 1024
    # attention family: "full" is O(S^2) ⇒ long_500k is skipped (DESIGN.md)
    sub_quadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 256 so the embedding shards on any mesh axis
        (16x16); logits beyond vocab_size are masked in the loss."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activation_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                        # train_4k | prefill_32k | ...
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode
    microbatch: int = 0              # grad-accum microbatch (0 = off)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
