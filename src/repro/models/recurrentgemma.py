"""RecurrentGemma / Griffin hybrid: RG-LRU + local sliding-window attention
in a 2:1 pattern (arXiv:2402.19427). Sub-quadratic: decode state is O(1)
(LRU state + a fixed window), so the long_500k cell runs for this arch.

Layer = temporal-mixing block (RG-LRU or local attention) + MLP block,
pre-norm residuals. 26 layers = 8 scanned (rglru, rglru, local_attn)
groups + 2 tail rglru layers.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L
from .config import ModelConfig
from .stacking import scan_layers, stacked_init, stacked_specs


class RecurrentGemmaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        pat = len(cfg.hybrid.pattern)           # 3
        self.n_groups = cfg.num_layers // pat
        self.n_tail = cfg.num_layers - self.n_groups * pat

    # ------------------------------------------------------------ params
    def _init_rglru_layer(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
                "mix": L.init_rglru(k1, cfg),
                "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
                "mlp": L.init_mlp(k2, cfg)}

    def _init_attn_layer(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
                "attn": L.init_attention(k1, cfg),
                "ln2": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
                "mlp": L.init_mlp(k2, cfg)}

    def _init_group(self, rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"r1": self._init_rglru_layer(k1),
                "r2": self._init_rglru_layer(k2),
                "a": self._init_attn_layer(k3)}

    def init_params(self, rng) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        p = {"embed": L._init(ks[0], (cfg.padded_vocab, cfg.d_model), 1.0,
                              cfg.pdtype),
             "ln_f": L.init_rmsnorm(cfg.d_model, cfg.pdtype),
             "groups": stacked_init(self._init_group, ks[1], self.n_groups)}
        if self.n_tail:
            p["tail"] = stacked_init(self._init_rglru_layer, ks[2],
                                     self.n_tail)
        return p

    def param_specs(self) -> Dict:
        cfg = self.cfg
        r_spec = {"ln1": L.spec_rmsnorm(), "mix": L.spec_rglru(cfg),
                  "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg)}
        a_spec = {"ln1": L.spec_rmsnorm(), "attn": L.spec_attention(cfg),
                  "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg)}
        g_spec = {"r1": r_spec, "r2": r_spec, "a": a_spec}
        sp = {"embed": P("model", None), "ln_f": L.spec_rmsnorm(),
              "groups": stacked_specs(g_spec, self.n_groups)}
        if self.n_tail:
            sp["tail"] = stacked_specs(r_spec, self.n_tail)
        return sp

    # ------------------------------------------------------------ blocks
    def _rglru_layer(self, lp, x, state=None):
        cfg = self.cfg
        h, new_state = L.rglru(lp["mix"],
                               L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                               cfg, state)
        x = x + h
        x = x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                      cfg)
        return x, new_state

    def _attn_layer(self, lp, x, positions, cache=None, idx=None):
        cfg = self.cfg
        z = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        if cache is None:
            h, new_kv = L.attention(lp["attn"], z, cfg, positions,
                                    window=cfg.hybrid.window)
        else:
            h, new_kv = L.attention(lp["attn"], z, cfg, positions,
                                    cache=(cache["k"], cache["v"], idx),
                                    window=cfg.hybrid.window)
        x = x + h
        x = x + L.mlp(lp["mlp"], L.rms_norm(x, lp["ln2"], cfg.norm_eps),
                      cfg)
        return x, new_kv

    # ------------------------------------------------------------ training
    def hidden(self, params: Dict, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens].astype(cfg.adtype)
        x = L.shard_batch(x, cfg)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def group_fn(lp, h, e):
            h = L.shard_batch(h, cfg)
            h, _ = self._rglru_layer(lp["r1"], h)
            h, _ = self._rglru_layer(lp["r2"], h)
            h, _ = self._attn_layer(lp["a"], h, e)
            return L.shard_batch(h, cfg)

        x = scan_layers(group_fn, params["groups"], x, remat=cfg.remat,
                        carry_extra=positions)
        if self.n_tail:
            def tail_fn(lp, h, e):
                h, _ = self._rglru_layer(lp, h)
                return L.shard_batch(h, cfg)
            x = scan_layers(tail_fn, params["tail"], x, remat=cfg.remat,
                            carry_extra=positions)
        return L.rms_norm(x, params["ln_f"], cfg.norm_eps)

    def unembed(self, params: Dict) -> jnp.ndarray:
        return params["embed"].T

    def logits(self, params: Dict, batch: Dict) -> jnp.ndarray:
        return (self.hidden(params, batch)
                @ self.unembed(params).astype(self.cfg.adtype)) \
            .astype(jnp.float32)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        w = min(cfg.hybrid.window, max_seq)
        lru_w = cfg.hybrid.lru_width or cfg.d_model
        kv = (batch, cfg.kv_heads, w, cfg.hd)
        g = self.n_groups
        cache = {
            "index": jnp.zeros((), jnp.int32),
            "groups": {
                "s1": jnp.zeros((g, batch, lru_w), jnp.float32),
                "s2": jnp.zeros((g, batch, lru_w), jnp.float32),
                "k": jnp.zeros((g,) + kv, cfg.adtype),
                "v": jnp.zeros((g,) + kv, cfg.adtype),
            },
        }
        if self.n_tail:
            cache["tail"] = jnp.zeros((self.n_tail, batch, lru_w),
                                      jnp.float32)
        return cache

    def cache_specs(self) -> Dict:
        sp = {"index": P(),
              "groups": {"s1": P(None, "data", "model"),
                         "s2": P(None, "data", "model"),
                         "k": P(None, "data", None, "model", None),
                         "v": P(None, "data", None, "model", None)}}
        if self.n_tail:
            sp["tail"] = P(None, "data", "model")
        return sp

    def forward_cached(self, params: Dict, cache: Dict,
                       batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """Decode/short-prefill with rolling window cache.

        The KV cache keeps the last ``window`` positions; slot = pos %
        window, masking handles wrap-around (O(window) per step).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        idx = cache["index"]
        x = params["embed"][tokens].astype(cfg.adtype)
        b, s, _ = x.shape
        positions = idx + jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        w = cache["groups"]["k"].shape[3]

        def group_fn(h, inp):
            lp, c = inp
            h, s1 = self._rglru_layer(lp["r1"], h, c["s1"])
            h, s2 = self._rglru_layer(lp["r2"], h, c["s2"])
            # windowed attention against rolled cache
            z = L.rms_norm(h, lp["a"]["ln1"], cfg.norm_eps)
            hq, hkv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
            q = L._split_heads(z @ lp["a"]["attn"]["wq"], hq, hd)
            k = L._split_heads(z @ lp["a"]["attn"]["wk"], hkv, hd)
            v = L._split_heads(z @ lp["a"]["attn"]["wv"], hkv, hd)
            if cfg.qk_norm:
                q = L.rms_norm(q, lp["a"]["attn"]["q_norm"], cfg.norm_eps)
                k = L.rms_norm(k, lp["a"]["attn"]["k_norm"], cfg.norm_eps)
            q = L.rope(q.transpose(0, 2, 1, 3), positions,
                       cfg.rope_theta).transpose(0, 2, 1, 3)
            k = L.rope(k.transpose(0, 2, 1, 3), positions,
                       cfg.rope_theta).transpose(0, 2, 1, 3)
            slot = idx % w
            k_c = jax.lax.dynamic_update_slice_in_dim(c["k"], k, slot,
                                                      axis=2)
            v_c = jax.lax.dynamic_update_slice_in_dim(c["v"], v, slot,
                                                      axis=2)
            # key absolute positions per slot
            slots = jnp.arange(w)
            key_pos = jnp.where(slots <= slot, idx - slot + slots,
                                idx - slot + slots - w)
            scores = jnp.einsum("bhqd,bhkd->bhqk",
                                q, jnp.repeat(k_c, hq // hkv, 1),
                                preferred_element_type=jnp.float32) \
                / math.sqrt(hd)
            valid = (key_pos[None, None, None] >= 0) & \
                    (key_pos[None, None, None] <= positions[:, None, :,
                                                            None])
            scores = jnp.where(valid, scores, -1e30)
            probs = jax.nn.softmax(scores, -1).astype(cfg.adtype)
            att = jnp.einsum("bhqk,bhkd->bhqd", probs,
                             jnp.repeat(v_c, hq // hkv, 1))
            h = h + L._merge_heads(att) @ lp["a"]["attn"]["wo"]
            h = h + L.mlp(lp["a"]["mlp"],
                          L.rms_norm(h, lp["a"]["ln2"], cfg.norm_eps), cfg)
            return h, {"s1": s1, "s2": s2, "k": k_c, "v": v_c}

        x, new_groups = jax.lax.scan(group_fn, x,
                                     (params["groups"], cache["groups"]))
        new_cache = {"index": idx + s, "groups": new_groups}
        if self.n_tail:
            def tail_fn(h, inp):
                lp, st = inp
                h, ns = self._rglru_layer(lp, h, st)
                return h, ns
            x, new_tail = jax.lax.scan(tail_fn, x,
                                       (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, -1:] @ params["embed"].T.astype(cfg.adtype)) \
            .astype(jnp.float32)
        return logits, new_cache

    def prefill(self, params: Dict, cache: Dict,
                batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        """Long prefill: full-sequence processing (associative-scan LRU +
        windowed attention), then the rolling cache is seeded with the
        final LRU states and the last ``window`` keys/values."""
        cfg = self.cfg
        tokens = batch["tokens"]
        s = tokens.shape[1]
        w = cache["groups"]["k"].shape[3]
        if s <= 1:
            return self.forward_cached(params, cache, batch)
        x = params["embed"][tokens].astype(cfg.adtype)
        x = L.shard_batch(x, cfg)
        b = x.shape[0]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        hq, hkv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd

        def seed_cache(k, v, kc, vc):
            """Place the last min(s, w) keys at slot = pos %% w."""
            if s >= w:
                kw = jnp.roll(k[:, :, -w:], s % w, axis=2)
                vw = jnp.roll(v[:, :, -w:], s % w, axis=2)
                return kw.astype(kc.dtype), vw.astype(vc.dtype)
            kc = jax.lax.dynamic_update_slice_in_dim(
                kc, k.astype(kc.dtype), 0, axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(
                vc, v.astype(vc.dtype), 0, axis=2)
            return kc, vc

        def group_fn(h, inp):
            lp, c = inp
            h, s1 = self._rglru_layer(lp["r1"], h)
            h, s2 = self._rglru_layer(lp["r2"], h)
            z = L.rms_norm(h, lp["a"]["ln1"], cfg.norm_eps)
            q = L._split_heads(z @ lp["a"]["attn"]["wq"], hq, hd)
            k = L._split_heads(z @ lp["a"]["attn"]["wk"], hkv, hd)
            v = L._split_heads(z @ lp["a"]["attn"]["wv"], hkv, hd)
            if cfg.qk_norm:
                q = L.rms_norm(q, lp["a"]["attn"]["q_norm"], cfg.norm_eps)
                k = L.rms_norm(k, lp["a"]["attn"]["k_norm"], cfg.norm_eps)
            q = L.rope(q.transpose(0, 2, 1, 3), positions,
                       cfg.rope_theta).transpose(0, 2, 1, 3)
            k = L.rope(k.transpose(0, 2, 1, 3), positions,
                       cfg.rope_theta).transpose(0, 2, 1, 3)
            att = L._sdpa(q, k, v, causal=True, window=cfg.hybrid.window,
                          q_offset=0, chunk=cfg.attn_chunk)
            h = h + L._merge_heads(att) @ lp["a"]["attn"]["wo"]
            h = h + L.mlp(lp["a"]["mlp"],
                          L.rms_norm(h, lp["a"]["ln2"], cfg.norm_eps),
                          cfg)
            kc, vc = seed_cache(k, v, c["k"], c["v"])
            return L.shard_batch(h, cfg), {"s1": s1, "s2": s2,
                                           "k": kc, "v": vc}

        x, new_groups = jax.lax.scan(group_fn, x,
                                     (params["groups"], cache["groups"]))
        new_cache = {"index": cache["index"] + s, "groups": new_groups}
        if self.n_tail:
            def tail_fn(h, inp):
                lp, _ = inp
                h, ns = self._rglru_layer(lp, h)
                return h, ns
            x, new_tail = jax.lax.scan(tail_fn, x,
                                       (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, -1:] @ params["embed"].T.astype(cfg.adtype)) \
            .astype(jnp.float32)
        return logits, new_cache

    decode_step = forward_cached
