"""Shared neural blocks for the assigned architectures.

Every block provides ``init_*`` (parameters), ``spec_*`` (PartitionSpec
tree, same structure) and an apply function. Parameters never carry the
layer dimension here — the decoder stacks them and scans (constant compile
time in depth). Sharding axes:

* ``model`` — tensor parallel (heads / ffn / experts / vocab)
* ``data``  — FSDP for weights, batch for activations (+ ``pod`` when the
  multi-pod mesh is active)
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig


def _init(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * scale) \
        .astype(dtype)


def shard_batch(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Pin the batch dim to the data-parallel mesh axes. Without this the
    GSPMD propagator is free to replicate activations inside the layer
    scan (observed: 900 GiB/device stashes). No-op off-mesh."""
    try:
        axes = cfg.batch_axes
        spec = P(tuple(axes) if len(axes) > 1 else axes[0],
                 *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x                    # no mesh context (CPU tests)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def spec_rmsnorm() -> Dict:
    return {"scale": P(None)}


def rms_norm(x: jnp.ndarray, p: Dict, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * p["scale"].astype(x.dtype)


def init_layernorm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def spec_layernorm() -> Dict:
    return {"scale": P(None), "bias": P(None)}


def layer_norm(x: jnp.ndarray, p: Dict, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y.astype(x.dtype) * p["scale"].astype(x.dtype)
            + p["bias"].astype(x.dtype))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D). positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                   / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                          # (..., S, 1, h)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
        axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm, optional sliding window)
# ---------------------------------------------------------------------------

def init_attention(rng, cfg: ModelConfig) -> Dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": _init(ks[0], (d, hq * hd), s, cfg.pdtype),
        "wk": _init(ks[1], (d, hkv * hd), s, cfg.pdtype),
        "wv": _init(ks[2], (d, hkv * hd), s, cfg.pdtype),
        "wo": _init(ks[3], (hq * hd, d), 1.0 / math.sqrt(hq * hd),
                    cfg.pdtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, cfg.pdtype)
        p["k_norm"] = init_rmsnorm(hd, cfg.pdtype)
    return p


def spec_attention(cfg: ModelConfig) -> Dict:
    sp = {
        "wq": P("data", "model"),
        "wk": P("data", "model"),
        "wv": P("data", "model"),
        "wo": P("model", "data"),
    }
    if cfg.qk_norm:
        sp["q_norm"] = spec_rmsnorm()
        sp["k_norm"] = spec_rmsnorm()
    return sp


def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)  # (B,H,S,D)


def _merge_heads(x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _sdpa(q, k, v, causal: bool, window: int, q_offset,
          impl: str = "xla", chunk: int = 2048,
          scores_f32: bool = True, gqa_mode: str = "repeat") -> jnp.ndarray:
    """q: (B,Hq,Sq,D); k,v: (B,Hkv,Skv,D).

    GQA modes (§Perf): "repeat" expands K/V to Hq heads (extra HBM copies
    but head dim stays 16-way shardable); "grouped" reshapes queries to
    (B, Hkv, G, Sq, D) against unexpanded K/V — fewer K/V bytes but the
    (Hkv, G) split misaligns with the model axis when Hkv < 16 and
    *regresses* (measured: +6% memory term on deepseek train — refuted
    hypothesis, kept as a knob). Long sequences scan over query chunks
    (memory-efficient attention): the (Sq, Skv) score matrix never fully
    materializes. ``scores_f32=False`` keeps scores in bf16 (halves their
    HBM traffic; ~2 digit logit precision loss).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if impl == "pallas" and window <= 0:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal)
    grouped = (gqa_mode == "grouped" and hkv != hq)
    if not grouped and hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    g = hq // hkv
    skv = k.shape[2]
    acc_t = jnp.float32 if scores_f32 else q.dtype

    def attend(qc, qpos):
        cq = qc.shape[2]
        if grouped:
            qg = qc.reshape(b, hkv, g, cq, d)
            scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                                preferred_element_type=acc_t)
        else:
            scores = jnp.einsum("bhqd,bhkd->bhqk", qc, k,
                                preferred_element_type=acc_t)
        scores = scores / math.sqrt(d)
        ki = jnp.arange(skv)[None, :]
        qi = qpos[:, None]
        mask = jnp.ones((cq, skv), bool)
        if causal:
            mask &= qi >= ki
        if window > 0:
            mask &= ki > qi - window
        big_neg = -1e30 if scores_f32 else -3e38
        mask_b = (mask[None, None, None] if grouped
                  else mask[None, None])
        scores = jnp.where(mask_b, scores, big_neg)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(qc.dtype)
        if grouped:
            out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v)
            return out.reshape(b, hq, cq, d)
        return jnp.einsum("bhqk,bhkd->bhqd", probs, v)

    if chunk <= 0 or sq <= chunk:
        return attend(q, jnp.arange(sq) + q_offset)

    if sq % chunk:
        # largest divisor of sq no bigger than the requested chunk
        # (e.g. the VLM's 4352-token patch+text sequence with chunk 2048)
        chunk = math.gcd(sq, chunk)
        if chunk < 128:
            return attend(q, jnp.arange(sq) + q_offset)
    n_chunks = sq // chunk
    qs = q.reshape(b, hq, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    def body(_, i):
        qpos = i * chunk + jnp.arange(chunk) + q_offset
        return None, attend(qs[i], qpos)

    _, out = jax.lax.scan(jax.checkpoint(body), None,
                          jnp.arange(n_chunks))
    return out.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq, d)


def attention(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
              positions: jnp.ndarray,
              cache: Optional[Tuple] = None,
              window: int = 0) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """Full-sequence (cache=None) or cached decode/prefill attention.

    cache = (k_cache, v_cache, index): k/v (B, Hkv, S_max, D). Returns
    (out, new_cache).
    """
    hq, hkv, hd = cfg.num_heads, cfg.kv_heads, cfg.hd
    q = _split_heads(x @ p["wq"], hq, hd)
    k = _split_heads(x @ p["wk"], hkv, hd)
    v = _split_heads(x @ p["wv"], hkv, hd)
    if cfg.attn_head_shard and cache is None:
        # Megatron-style: heads on the model axis, head_dim whole — the
        # qk/pv contractions become shard-local (no score all-reduce)
        ba = (tuple(cfg.batch_axes) if len(cfg.batch_axes) > 1
              else cfg.batch_axes[0])
        q = _constrain(q, P(ba, "model", None, None))
        k = _constrain(k, P(ba, "model", None, None))
        v = _constrain(v, P(ba, "model", None, None))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta) \
        .transpose(0, 2, 1, 3)
    k = rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta) \
        .transpose(0, 2, 1, 3)

    if cache is None:
        out = _sdpa(q, k, v, causal=True, window=window, q_offset=0,
                    impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                    scores_f32=cfg.attn_scores_f32,
                    gqa_mode=cfg.gqa_mode)
        new_cache = None
    else:
        k_c, v_c, idx = cache
        k_c = jax.lax.dynamic_update_slice_in_dim(k_c, k, idx, axis=2)
        v_c = jax.lax.dynamic_update_slice_in_dim(v_c, v, idx, axis=2)
        s_max = k_c.shape[2]
        # mask out beyond current index via positions
        # decode path: grouped GQA by default — heads are unsharded here,
        # so there is no alignment penalty, and K/V repeat would multiply
        # the whole cache by Hq/Hkv (measured 9.7x on the decode bound,
        # §Perf C3)
        decode_gqa = ("grouped" if cfg.gqa_mode == "repeat"
                      else cfg.gqa_mode)
        out = _sdpa(q, k_c, v_c, causal=True, window=window, q_offset=idx,
                    impl="xla", chunk=cfg.attn_chunk,
                    scores_f32=cfg.attn_scores_f32,
                    gqa_mode=decode_gqa)
        new_cache = (k_c, v_c, idx + q.shape[2])
    return _merge_heads(out) @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    s = 1.0 / math.sqrt(d)
    p = {"w_up": _init(ks[0], (d, f), s, cfg.pdtype),
         "w_down": _init(ks[1], (f, d), 1.0 / math.sqrt(f), cfg.pdtype)}
    if cfg.activation == "swiglu":
        p["w_gate"] = _init(ks[2], (d, f), s, cfg.pdtype)
    return p


def spec_mlp(cfg: ModelConfig) -> Dict:
    sp = {"w_up": P("data", "model"), "w_down": P("model", "data")}
    if cfg.activation == "swiglu":
        sp["w_gate"] = P("data", "model")
    return sp


def mlp(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    up = x @ p["w_up"]
    if cfg.activation == "swiglu":
        act = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        act = jax.nn.gelu(up)
    return act @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based capacity dispatch, expert-parallel)
# ---------------------------------------------------------------------------

def init_moe(rng, cfg: ModelConfig) -> Dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(rng, 5)
    s = 1.0 / math.sqrt(d)
    p = {
        "router": _init(ks[0], (d, e), s, jnp.float32),
        "w_up": _init(ks[1], (e, d, f), s, cfg.pdtype),
        "w_gate": _init(ks[2], (e, d, f), s, cfg.pdtype),
        "w_down": _init(ks[3], (e, f, d), 1.0 / math.sqrt(f), cfg.pdtype),
    }
    if m.d_ff_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=m.d_ff_shared)
    return p


def spec_moe(cfg: ModelConfig) -> Dict:
    # expert parallelism when the expert count divides the model axis;
    # otherwise fall back to tensor-sharding each expert's matrices
    # (e.g. granite's 40 experts on a 16-wide axis)
    if cfg.moe.num_experts % 16 == 0:
        sp = {
            "router": P(None, None),
            "w_up": P("model", "data", None),
            "w_gate": P("model", "data", None),
            "w_down": P("model", None, "data"),
        }
    else:
        sp = {
            "router": P(None, None),
            "w_up": P(None, "data", "model"),
            "w_gate": P(None, "data", "model"),
            "w_down": P(None, "model", "data"),
        }
    if cfg.moe.d_ff_shared:
        sp["shared"] = spec_mlp(cfg)
    return sp


def moe(p: Dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Top-k token-choice MoE with GShard-style *grouped* dispatch.

    Tokens are split into G groups (G = data-parallel axis size, so each
    group's sort/scatter stays shard-local and never induces a global
    buffer all-reduce); within a group, entries are sorted by expert and
    scattered into a (G, E, C_g, D) buffer whose expert dim is
    model-sharded — the group→expert reshard is the MoE all-to-all. The
    expert matmuls are uniform batched GEMMs; overflow beyond the per-
    group capacity C_g is dropped (Switch-style).
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.num_experts
    g = max(1, math.gcd(cfg.moe_groups, t))
    tl = t // g                                  # tokens per group
    cap = int(math.ceil(tl * k / e * m.capacity_factor))
    cap = max(4, min(cap, tl))

    xf = x.reshape(g, tl, d)
    xf = _constrain(xf, P("data", None, None))
    logits = (xf.astype(jnp.float32) @ p["router"])          # (G, TL, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, k)                   # (G, TL, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    def dispatch_group(xg, eg, gg):
        """xg: (TL, D); eg/gg: (TL, k) -> buffer (E, C, D), slot info."""
        flat_e = eg.reshape(-1)                              # (TL*k,)
        order = jnp.argsort(flat_e)
        se = flat_e[order]
        tok = order // k
        starts = jnp.searchsorted(se, jnp.arange(e))
        pos = jnp.arange(tl * k) - starts[se]
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, e * cap)
        buf = jnp.zeros((e * cap + 1, d), cfg.adtype)
        buf = buf.at[slot].set(xg[tok].astype(cfg.adtype), mode="drop")
        return buf[:e * cap].reshape(e, cap, d), (order, tok, slot, keep)

    h, (order, tok, slot, keep) = jax.vmap(dispatch_group)(xf, top_e,
                                                           top_g)
    h = _constrain(h, P("data", "model", None, None))        # all-to-all

    up = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    gate = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
    out_e = jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up,
                       p["w_down"])
    out_e = _constrain(out_e, P("data", "model", None, None))

    def combine_group(oe, og, info):
        order_g, tok_g, slot_g, keep_g = info
        flat = oe.reshape(e * cap, d)
        flat = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)], 0)
        gathered = flat[slot_g] * og.reshape(-1)[order_g][:, None] \
            .astype(cfg.adtype)
        return jnp.zeros((tl, d), cfg.adtype).at[tok_g].add(
            jnp.where(keep_g[:, None], gathered, 0))

    y = jax.vmap(combine_group)(out_e, top_g, (order, tok, slot, keep))
    y = _constrain(y, P("data", None, None)).reshape(b, s, d)
    if m.d_ff_shared:
        y = y + mlp(p["shared"], x, cfg)
    return y


def _constrain(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x                      # no mesh context (CPU tests)


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) — diagonal linear recurrence via associative scan
# ---------------------------------------------------------------------------

def init_rglru(rng, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    ks = jax.random.split(rng, 5)
    s = 1.0 / math.sqrt(d)
    return {
        "w_x": _init(ks[0], (d, w), s, cfg.pdtype),
        "w_gate_a": _init(ks[1], (d, w), s, cfg.pdtype),
        "w_gate_x": _init(ks[2], (d, w), s, cfg.pdtype),
        "w_out": _init(ks[3], (w, d), 1.0 / math.sqrt(w), cfg.pdtype),
        # Λ parametrized via softplus -> decay in (0, 1)
        "lam": _init(ks[4], (w,), 1.0, jnp.float32) * 0.5 + 4.0,
    }


def spec_rglru(cfg: ModelConfig) -> Dict:
    return {"w_x": P("data", "model"), "w_gate_a": P("data", "model"),
            "w_gate_x": P("data", "model"), "w_out": P("model", "data"),
            "lam": P("model")}


def rglru(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
          state: Optional[jnp.ndarray] = None
          ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """x: (B, S, D). Real-Gated LRU:
    h_t = a_t ⊙ h_{t-1} + sqrt(1-a²)⊙i_t."""
    xb = x @ p["w_x"]                                   # (B, S, W)
    ga = jax.nn.sigmoid((x @ p["w_gate_a"]).astype(jnp.float32))
    gx = jax.nn.sigmoid((x @ p["w_gate_x"]).astype(jnp.float32))
    c = -8.0 * jax.nn.softplus(-p["lam"])               # log a_base < 0
    log_a = c[None, None, :] * ga                       # (B, S, W)
    a = jnp.exp(log_a)
    gated_x = (xb.astype(jnp.float32) * gx) * jnp.sqrt(
        jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))

    if state is None and x.shape[1] > 1:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        a_s, h = jax.lax.associative_scan(combine, (a, gated_x), axis=1)
        new_state = h[:, -1]
    else:
        st = state if state is not None else jnp.zeros(
            (x.shape[0], a.shape[-1]), jnp.float32)
        h = a * st[:, None, :] + gated_x                # S == 1 decode
        new_state = h[:, -1]
    return h.astype(x.dtype) @ p["w_out"], new_state


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------

def init_mamba2(rng, cfg: ModelConfig) -> Dict:
    s_cfg = cfg.ssm
    d = cfg.d_model
    d_in = s_cfg.expand * d
    nh = s_cfg.num_heads or d_in // s_cfg.head_dim
    n = s_cfg.state_dim
    ks = jax.random.split(rng, 6)
    sc = 1.0 / math.sqrt(d)
    return {
        # projections for x, z (gate), B, C, dt
        "w_in": _init(ks[0], (d, 2 * d_in + 2 * n + nh), sc, cfg.pdtype),
        "conv": _init(ks[1], (s_cfg.conv_width, d_in + 2 * n), 0.3,
                      cfg.pdtype),
        "a_log": jnp.zeros((nh,), jnp.float32) - 0.5,
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": init_rmsnorm(d_in, cfg.pdtype),
        "w_out": _init(ks[2], (d_in, d), 1.0 / math.sqrt(d_in), cfg.pdtype),
    }


def spec_mamba2(cfg: ModelConfig) -> Dict:
    return {"w_in": P("data", "model"), "conv": P(None, "model"),
            "a_log": P(None), "dt_bias": P(None), "d_skip": P(None),
            "norm": spec_rmsnorm(), "w_out": P("model", "data")}


def _causal_conv(seq: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Depthwise causal conv. seq: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((seq.shape[0], k - 1, seq.shape[2]), seq.dtype)
    else:
        pad = state.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i][None, None]
              for i in range(k))
    return out, full[:, -(k - 1):]


def mamba2(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
           state: Optional[Tuple] = None
           ) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """SSD mixer. state = (h (B, NH, P, N), conv_state)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_in = s_cfg.expand * d
    nh = s_cfg.num_heads or d_in // s_cfg.head_dim
    ph = d_in // nh
    n = s_cfg.state_dim

    zxbcdt = x @ p["w_in"]
    z, xc, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xc, bmat, cmat], axis=-1)
    conv_state = None if state is None else state[1]
    conv_out, new_conv = _causal_conv(conv_in, p["conv"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :d_in]
    bmat = conv_out[..., d_in:d_in + n]
    cmat = conv_out[..., d_in + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,NH)
    a = -jnp.exp(p["a_log"])                                     # (NH,)
    xh = xc.reshape(b, s, nh, ph)

    if state is None or s > 1:
        # chunked SSD: Pallas kernel when requested, else jnp path
        xf = xh.transpose(0, 2, 1, 3).reshape(b * nh, s, ph)
        dtf = dt.transpose(0, 2, 1).reshape(b * nh, s)
        af = jnp.tile(a, (b,))
        bf = jnp.repeat(bmat[:, None], nh, 1).reshape(b * nh, s, n)
        cf = jnp.repeat(cmat[:, None], nh, 1).reshape(b * nh, s, n)
        if cfg.attn_impl == "pallas" and state is None:
            from repro.kernels import ops as kops
            y = kops.ssd_scan(xf.astype(jnp.float32), dtf, af,
                              bf.astype(jnp.float32),
                              cf.astype(jnp.float32), chunk=s_cfg.chunk)
            new_h = None
        else:
            y, h_last = _ssd_xla(xf.astype(jnp.float32), dtf, af,
                                 bf.astype(jnp.float32),
                                 cf.astype(jnp.float32), s_cfg.chunk,
                                 return_state=True)
            new_h = (None if state is None
                     else h_last.reshape(b, nh, ph, n))
        y = y.reshape(b, nh, s, ph).transpose(0, 2, 1, 3)
    else:
        h = state[0]                                     # (B, NH, P, N)
        dtb = dt[:, 0]                                   # (B, NH)
        decay = jnp.exp(dtb * a[None])[:, :, None, None]
        upd = (dtb[:, :, None] * xh[:, 0].astype(jnp.float32)
               )[..., None] * bmat[:, 0].astype(jnp.float32)[:, None, None, :]
        h = h * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", h, cmat[:, 0].astype(jnp.float32))
        y = y.reshape(b, 1, nh, ph)
        new_h = h

    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ p["w_out"]
    new_state = None if state is None else (new_h, new_conv)
    return out, new_state


def _ssd_xla(x, dt, a, bmat, cmat, chunk: int, return_state: bool = False):
    """Chunked SSD in plain jnp (same math as kernels/ssd_scan).
    return_state=True also returns the final (BH, P, N) state (prefill)."""
    bh, l, p = x.shape
    n = bmat.shape[-1]
    if l % chunk:
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    lp = x.shape[1]
    nc = lp // chunk
    xc = x.reshape(bh, nc, chunk, p)
    dtc = dt.reshape(bh, nc, chunk)
    bc = bmat.reshape(bh, nc, chunk, n)
    cc = cmat.reshape(bh, nc, chunk, n)
    da = dtc * a[:, None, None]
    seg = jnp.cumsum(da, axis=-1)                         # (BH,NC,C)
    scores = jnp.einsum("bntk,bnuk->bntu", cc, bc)
    lmat = jnp.exp(seg[..., :, None] - seg[..., None, :])
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.where(tri[None, None], scores * lmat, 0.0) * dtc[..., None, :]
    y_intra = jnp.einsum("bntu,bnup->bntp", w, xc)

    # inter-chunk state carry (scan over chunks)
    decay_tail = jnp.exp(seg[..., -1:] - seg)             # (BH,NC,C)
    xb = jnp.einsum("bnc,bncp,bncq->bnpq", dtc * decay_tail, xc, bc)
    chunk_decay = jnp.exp(seg[..., -1])                   # (BH,NC)

    def scan_fn(h, inp):
        xb_c, dec_c = inp
        h_new = h * dec_c[:, None, None] + xb_c
        return h_new, h
    (h_final, h_prev) = jax.lax.scan(
        scan_fn, jnp.zeros((bh, p, n), jnp.float32),
        (xb.transpose(1, 0, 2, 3), chunk_decay.T))
    h_prev = h_prev.transpose(1, 0, 2, 3)                 # state BEFORE chunk
    y_inter = jnp.einsum("bntk,bnpk,bnt->bntp", cc, h_prev,
                         jnp.exp(seg))
    y = (y_intra + y_inter).reshape(bh, lp, p)[:, :l]
    if return_state:
        return y, h_final
    return y
