"""Layer stacking utilities: init a layer L times (stacked leading dim),
scan over the stack, remat policies. Constant compile time in depth."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def stacked_init(layer_init: Callable, rng, n_layers: int) -> Dict:
    """vmap the per-layer init over layer rngs -> params with leading L."""
    rngs = jax.random.split(rng, n_layers)
    return jax.vmap(layer_init)(rngs)


def stacked_specs(layer_spec: Dict, n_layers: int) -> Dict:
    """Prepend None (layer) axis to every PartitionSpec in the tree."""

    def add_axis(s):
        if isinstance(s, P):
            return P(None, *s)
        return s

    return jax.tree.map(add_axis, layer_spec,
                        is_leaf=lambda x: isinstance(x, P))


def remat_wrap(fn: Callable, policy: str) -> Callable:
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if policy == "dots_no_batch":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat policy {policy}")


def scan_layers(block_fn: Callable, stacked_params: Dict, x: jnp.ndarray,
                remat: str = "none", carry_extra=None,
                unroll: int = 1):
    """x flows through L layers; block_fn(layer_params, x, extra) -> x."""
    fn = remat_wrap(block_fn, remat)

    def body(carry, layer_params):
        y = fn(layer_params, carry, carry_extra)
        return y, None

    out, _ = jax.lax.scan(body, x, stacked_params, unroll=unroll)
    return out


def scan_layers_with_cache(block_fn: Callable, stacked_params: Dict,
                           x: jnp.ndarray, cache, carry_extra=None):
    """Serve path: scans layers while threading per-layer cache slices.

    cache: pytree with leading L dim on every leaf.
    block_fn(layer_params, x, layer_cache, extra) -> (x, new_layer_cache)
    """

    def body(carry, inp):
        layer_params, layer_cache = inp
        y, new_cache = block_fn(layer_params, carry, layer_cache,
                                carry_extra)
        return y, new_cache

    out, new_cache = jax.lax.scan(body, x, (stacked_params, cache))
    return out, new_cache
