"""The Canal eDSL (§3.2): Python helpers that build the interconnect IR.

Two levels, as in the paper:

* low level — instantiate ``Node`` subclasses and ``add_edge`` them together
  (Fig. 4 top);
* high level — ``create_uniform_interconnect(...)`` (Fig. 4 bottom), a
  helper that produces uniform interconnect topologies by varying array
  size, switch-box topology, track count/width, pipeline register density
  and core-port connectivity.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import (IO, Interconnect, InterconnectGraph, Node, NodeKind,
                    PortNode, RegisterMuxNode, RegisterNode, SBConnection,
                    Side, SwitchBox, SwitchBoxNode, Tile)
from .tiles import Core, IOCore, MemCore, PECore, default_core_assigner


class SwitchBoxType(enum.Enum):
    DISJOINT = "disjoint"
    WILTON = "wilton"
    IMRAN = "imran"


# ---------------------------------------------------------------------------
# Switch-box topologies (§4.2.1, Fig. 9)
# ---------------------------------------------------------------------------

def disjoint_connections(num_tracks: int) -> List[SBConnection]:
    """Track i connects only to track i on the other three sides."""
    conns: List[SBConnection] = []
    for t in range(num_tracks):
        for s_from in Side:
            for s_to in Side:
                if s_from == s_to:
                    continue
                conns.append((t, s_from, t, s_to))
    return conns


def wilton_connections(num_tracks: int) -> List[SBConnection]:
    """Classic Wilton switch block: straight tracks pass through, turns are
    track permutations — same mux sizes as disjoint (each input reaches each
    other side exactly once) but far better routability."""
    w = num_tracks
    conns: List[SBConnection] = []
    for t in range(w):
        # straight through
        conns.append((t, Side.WEST, t, Side.EAST))
        conns.append((t, Side.EAST, t, Side.WEST))
        conns.append((t, Side.NORTH, t, Side.SOUTH))
        conns.append((t, Side.SOUTH, t, Side.NORTH))
        # turns (Wilton permutations)
        conns.append((t, Side.WEST, (w - t) % w, Side.NORTH))
        conns.append(((w - t) % w, Side.NORTH, t, Side.WEST))
        conns.append((t, Side.NORTH, (t + 1) % w, Side.EAST))
        conns.append(((t + 1) % w, Side.EAST, t, Side.NORTH))
        conns.append((t, Side.EAST, (2 * w - 2 - t) % w, Side.SOUTH))
        conns.append(((2 * w - 2 - t) % w, Side.SOUTH, t, Side.EAST))
        conns.append((t, Side.SOUTH, (t + 1) % w, Side.WEST))
        conns.append(((t + 1) % w, Side.WEST, t, Side.SOUTH))
    return conns


def imran_connections(num_tracks: int) -> List[SBConnection]:
    """Imran-style universal block: straight passes plus reflected turns."""
    w = num_tracks
    conns: List[SBConnection] = []
    for t in range(w):
        conns.append((t, Side.WEST, t, Side.EAST))
        conns.append((t, Side.EAST, t, Side.WEST))
        conns.append((t, Side.NORTH, t, Side.SOUTH))
        conns.append((t, Side.SOUTH, t, Side.NORTH))
        conns.append((t, Side.WEST, (w - 1 - t) % w, Side.NORTH))
        conns.append(((w - 1 - t) % w, Side.NORTH, t, Side.WEST))
        conns.append((t, Side.NORTH, (t + 1) % w, Side.EAST))
        conns.append(((t + 1) % w, Side.EAST, t, Side.NORTH))
        conns.append((t, Side.EAST, (w - 1 - t) % w, Side.SOUTH))
        conns.append(((w - 1 - t) % w, Side.SOUTH, t, Side.EAST))
        conns.append((t, Side.SOUTH, (t + 1) % w, Side.WEST))
        conns.append(((t + 1) % w, Side.WEST, t, Side.SOUTH))
    return conns


SB_TOPOLOGIES: Dict[SwitchBoxType, Callable[[int], List[SBConnection]]] = {
    SwitchBoxType.DISJOINT: disjoint_connections,
    SwitchBoxType.WILTON: wilton_connections,
    SwitchBoxType.IMRAN: imran_connections,
}


# ---------------------------------------------------------------------------
# High-level generator
# ---------------------------------------------------------------------------

ALL_SIDES: Tuple[Side, ...] = (Side.NORTH, Side.SOUTH, Side.EAST, Side.WEST)

# Reduction order for the port-connection DSE (Fig. 12): 4 sides, then drop
# EAST, then drop SOUTH.
SIDE_REDUCTION_ORDER: Tuple[Side, ...] = (Side.NORTH, Side.WEST, Side.SOUTH,
                                          Side.EAST)


def sides_for(n: int) -> Tuple[Side, ...]:
    """First n sides in the paper's reduction order (Fig. 12)."""
    if not 1 <= n <= 4:
        raise ValueError("side count must be in 1..4")
    return SIDE_REDUCTION_ORDER[:n]


@dataclass
class InterconnectSpec:
    """Everything `create_uniform_interconnect` can vary (the DSE axes)."""

    width: int = 8                  # array width in tiles
    height: int = 8                 # array height in tiles
    track_width: int = 16           # routing track bit width
    num_tracks: int = 5             # tracks per side
    sb_type: SwitchBoxType = SwitchBoxType.WILTON
    reg_density: float = 1.0        # fraction of tracks with pipeline regs
    cb_sides: int = 4               # sides feeding CBs (core inputs)
    sb_sides: int = 4               # sides fed by core outputs
    cb_track_fc: float = 1.0        # fraction of tracks a CB connects to
    sb_track_fc: float = 1.0        # fraction of tracks a core output drives
    mem_columns: Tuple[int, ...] = ()
    io_ring: bool = False
    pe_inputs: int = 4
    pe_outputs: int = 2
    wire_delay: float = 0.12        # ns per inter-tile hop
    mux_delay: float = 0.06         # ns per SB mux
    cb_delay: float = 0.05          # ns through CB mux
    extra_layers: Dict[int, int] = field(default_factory=dict)
    # ready-valid support (hybrid interconnect, §3.3)
    ready_valid: bool = False
    fifo_depth: int = 2
    split_fifo: bool = False

    def sb_connection_sides(self) -> Tuple[Side, ...]:
        return sides_for(self.sb_sides)

    def cb_connection_sides(self) -> Tuple[Side, ...]:
        return sides_for(self.cb_sides)


def _reg_pattern(spec: InterconnectSpec, x: int, y: int, track: int) -> bool:
    """Deterministic register placement at the requested density."""
    if spec.reg_density <= 0.0:
        return False
    if spec.reg_density >= 1.0:
        return True
    period = max(1, round(1.0 / spec.reg_density))
    return (x + y + track) % period == 0


def create_uniform_interconnect(
        width: int = 8,
        height: int = 8,
        sb_type: "SwitchBoxType | str" = SwitchBoxType.WILTON,
        num_tracks: int = 5,
        track_width: int = 16,
        reg_density: float = 1.0,
        core_fn: Optional[Callable[[int, int, int, int], Optional[Core]]]
        = None,
        spec: Optional[InterconnectSpec] = None,
        **kwargs) -> Interconnect:
    """Create a uniform interconnect (all SBs share one topology, no diagonal
    connections). Mirrors the paper's helper (Fig. 4, bottom)."""
    if spec is None:
        if isinstance(sb_type, str):
            sb_type = SwitchBoxType(sb_type)
        spec = InterconnectSpec(width=width, height=height, sb_type=sb_type,
                                num_tracks=num_tracks,
                                track_width=track_width,
                                reg_density=reg_density, **kwargs)
    if core_fn is None:
        core_fn = default_core_assigner(
            mem_columns=spec.mem_columns, io_ring=spec.io_ring,
            pe_inputs=spec.pe_inputs, pe_outputs=spec.pe_outputs,
            width=spec.track_width)

    layers = {spec.track_width: spec.num_tracks}
    layers.update(spec.extra_layers)

    graphs: Dict[int, InterconnectGraph] = {}
    for bit_width, n_tracks in layers.items():
        graphs[bit_width] = _build_layer(spec, bit_width, n_tracks, core_fn)

    ic = Interconnect(graphs)
    ic.params.update(dict(
        width=spec.width, height=spec.height, sb_type=spec.sb_type.value,
        num_tracks=spec.num_tracks, track_width=spec.track_width,
        reg_density=spec.reg_density, cb_sides=spec.cb_sides,
        sb_sides=spec.sb_sides, ready_valid=spec.ready_valid,
        fifo_depth=spec.fifo_depth, split_fifo=spec.split_fifo,
        wire_delay=spec.wire_delay, mux_delay=spec.mux_delay,
    ))
    ic.spec = spec  # type: ignore[attr-defined]
    return ic


def _build_layer(spec: InterconnectSpec, bit_width: int, n_tracks: int,
                 core_fn: Callable[[int, int, int, int], Optional[Core]]
                 ) -> InterconnectGraph:
    g = InterconnectGraph(bit_width)
    topo_fn = SB_TOPOLOGIES[spec.sb_type]
    conns = topo_fn(n_tracks)

    # 1. tiles + switch boxes (+ internal topology)
    for y in range(spec.height):
        for x in range(spec.width):
            sb = SwitchBox(x, y, n_tracks, bit_width, conns,
                           mux_delay=spec.mux_delay)
            core = core_fn(x, y, spec.width, spec.height)
            tile = Tile(x, y, sb, core)
            g.add_tile(tile)

    # 2. core <-> interconnect (CB in, SB out), honouring side reduction and
    # track population fraction Fc (staggered per port, VPR-style)
    cb_sides = spec.cb_connection_sides()
    sb_sides = spec.sb_connection_sides()
    cb_stride = max(1, round(1.0 / max(spec.cb_track_fc, 1e-6)))
    sb_stride = max(1, round(1.0 / max(spec.sb_track_fc, 1e-6)))
    for tile in g.tiles.values():
        if tile.core is None:
            continue
        for pi, p in enumerate(tile.core.inputs()):
            if p.width != bit_width:
                continue
            port = tile.get_port(p.name)
            for side in cb_sides:
                for t in range(n_tracks):
                    if (t + pi) % cb_stride != 0:
                        continue
                    sb_in = tile.switchbox.get_sb(side, t, IO.SB_IN)
                    sb_in.add_edge(port, delay=spec.cb_delay)
        for pi, p in enumerate(tile.core.outputs()):
            if p.width != bit_width:
                continue
            port = tile.get_port(p.name)
            for side in sb_sides:
                for t in range(n_tracks):
                    if (t + pi) % sb_stride != 0:
                        continue
                    sb_out = tile.switchbox.get_sb(side, t, IO.SB_OUT)
                    port.add_edge(sb_out)

    # 3. inter-tile wiring (+ pipeline registers per density pattern)
    for (x, y), tile in g.tiles.items():
        for side in ALL_SIDES:
            dx, dy = side.delta()
            nbr = g.get_tile(x + dx, y + dy)
            if nbr is None:
                continue
            for t in range(n_tracks):
                src = tile.switchbox.get_sb(side, t, IO.SB_OUT)
                dst = nbr.switchbox.get_sb(side.opposite(), t, IO.SB_IN)
                if _reg_pattern(spec, x, y, t):
                    _insert_register(g, src, dst, side, t, spec)
                else:
                    src.add_edge(dst, delay=spec.wire_delay)
    return g


def _insert_register(g: InterconnectGraph, src: SwitchBoxNode,
                     dst: SwitchBoxNode, side: Side, track: int,
                     spec: InterconnectSpec) -> None:
    """src -> REG -> RMUX -> dst, with src -> RMUX bypass (canal pattern)."""
    name = f"{side.name}_{track}"
    reg = RegisterNode(name, src.x, src.y, track, src.width, delay=0.0)
    rmux = RegisterMuxNode(name, src.x, src.y, track, src.width,
                           delay=spec.mux_delay)
    src.add_edge(reg)
    reg.add_edge(rmux)
    src.add_edge(rmux)                      # bypass path
    rmux.add_edge(dst, delay=spec.wire_delay)
    g.add_register(reg)
    g.add_reg_mux(rmux)


# ---------------------------------------------------------------------------
# Low-level helpers (paper Fig. 4, top)
# ---------------------------------------------------------------------------

def make_sb_node(x: int, y: int, side: "Side | str", track: int,
                 width: int = 16, io: IO = IO.SB_OUT) -> SwitchBoxNode:
    if isinstance(side, str):
        side = Side[side.upper()]
    return SwitchBoxNode(x, y, track, width, side, io)


def connect_all(node: Node, targets: Sequence[Node], delay: float = 0.0
                ) -> None:
    for t in targets:
        node.add_edge(t, delay=delay)
