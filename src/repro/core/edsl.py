"""The Canal eDSL (§3.2): Python helpers that build the interconnect IR.

Two levels, as in the paper:

* low level — instantiate ``Node`` subclasses and ``add_edge`` them together
  (Fig. 4 top);
* high level — a declarative :class:`repro.core.spec.InterconnectSpec`
  compiled through the pass pipeline (:mod:`repro.core.passes`) via
  ``canal.compile`` / ``PassManager.compile``.

This module keeps the switch-box topology generators (the reusable
"connection pattern" half of the eDSL) and the low-level node helpers.
The old monolithic generator ``create_uniform_interconnect(...)`` (Fig. 4
bottom) survives as a thin **deprecated** shim that builds a spec and runs
the exact same pass pipeline — it produces IR isomorphic to
``PassManager().run(InterconnectSpec(...))`` by construction.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, List, Optional, Sequence

from .graph import IO, Interconnect, Node, SBConnection, Side, SwitchBoxNode
from .spec import InterconnectSpec, SwitchBoxType
from .tiles import Core


# ---------------------------------------------------------------------------
# Switch-box topologies (§4.2.1, Fig. 9)
# ---------------------------------------------------------------------------

def disjoint_connections(num_tracks: int) -> List[SBConnection]:
    """Track i connects only to track i on the other three sides."""
    conns: List[SBConnection] = []
    for t in range(num_tracks):
        for s_from in Side:
            for s_to in Side:
                if s_from == s_to:
                    continue
                conns.append((t, s_from, t, s_to))
    return conns


def wilton_connections(num_tracks: int) -> List[SBConnection]:
    """Classic Wilton switch block: straight tracks pass through, turns are
    track permutations — same mux sizes as disjoint (each input reaches each
    other side exactly once) but far better routability."""
    w = num_tracks
    conns: List[SBConnection] = []
    for t in range(w):
        # straight through
        conns.append((t, Side.WEST, t, Side.EAST))
        conns.append((t, Side.EAST, t, Side.WEST))
        conns.append((t, Side.NORTH, t, Side.SOUTH))
        conns.append((t, Side.SOUTH, t, Side.NORTH))
        # turns (Wilton permutations)
        conns.append((t, Side.WEST, (w - t) % w, Side.NORTH))
        conns.append(((w - t) % w, Side.NORTH, t, Side.WEST))
        conns.append((t, Side.NORTH, (t + 1) % w, Side.EAST))
        conns.append(((t + 1) % w, Side.EAST, t, Side.NORTH))
        conns.append((t, Side.EAST, (2 * w - 2 - t) % w, Side.SOUTH))
        conns.append(((2 * w - 2 - t) % w, Side.SOUTH, t, Side.EAST))
        conns.append((t, Side.SOUTH, (t + 1) % w, Side.WEST))
        conns.append(((t + 1) % w, Side.WEST, t, Side.SOUTH))
    return conns


def imran_connections(num_tracks: int) -> List[SBConnection]:
    """Imran-style universal block: straight passes plus reflected turns."""
    w = num_tracks
    conns: List[SBConnection] = []
    for t in range(w):
        conns.append((t, Side.WEST, t, Side.EAST))
        conns.append((t, Side.EAST, t, Side.WEST))
        conns.append((t, Side.NORTH, t, Side.SOUTH))
        conns.append((t, Side.SOUTH, t, Side.NORTH))
        conns.append((t, Side.WEST, (w - 1 - t) % w, Side.NORTH))
        conns.append(((w - 1 - t) % w, Side.NORTH, t, Side.WEST))
        conns.append((t, Side.NORTH, (t + 1) % w, Side.EAST))
        conns.append(((t + 1) % w, Side.EAST, t, Side.NORTH))
        conns.append((t, Side.EAST, (w - 1 - t) % w, Side.SOUTH))
        conns.append(((w - 1 - t) % w, Side.SOUTH, t, Side.EAST))
        conns.append((t, Side.SOUTH, (t + 1) % w, Side.WEST))
        conns.append(((t + 1) % w, Side.WEST, t, Side.SOUTH))
    return conns


SB_TOPOLOGIES: Dict[SwitchBoxType, Callable[[int], List[SBConnection]]] = {
    SwitchBoxType.DISJOINT: disjoint_connections,
    SwitchBoxType.WILTON: wilton_connections,
    SwitchBoxType.IMRAN: imran_connections,
}


# ---------------------------------------------------------------------------
# Deprecated high-level generator (now a shim over the pass pipeline)
# ---------------------------------------------------------------------------

def create_uniform_interconnect(
        width: int = 8,
        height: int = 8,
        sb_type: "SwitchBoxType | str" = SwitchBoxType.WILTON,
        num_tracks: int = 5,
        track_width: int = 16,
        reg_density: float = 1.0,
        core_fn: Optional[Callable[[int, int, int, int], Optional[Core]]]
        = None,
        spec: Optional[InterconnectSpec] = None,
        **kwargs) -> Interconnect:
    """Create a uniform interconnect (all SBs share one topology, no diagonal
    connections). Mirrors the paper's helper (Fig. 4, bottom).

    .. deprecated::
        Use the front door instead: ``canal.compile(InterconnectSpec(...))``
        (or ``PassManager().run(spec)`` for the bare IR). This shim builds
        the same spec and runs the same pass pipeline, so the result is
        isomorphic; it only exists so existing call sites keep working.
    """
    warnings.warn(
        "create_uniform_interconnect is deprecated; use "
        "canal.compile(InterconnectSpec(...)) — the pass-pipeline front "
        "door — instead", DeprecationWarning, stacklevel=2)
    from .passes import PassManager
    if spec is None:
        spec = InterconnectSpec(width=width, height=height, sb_type=sb_type,
                                num_tracks=num_tracks,
                                track_width=track_width,
                                reg_density=reg_density, **kwargs)
    return PassManager().run(spec, core_fn=core_fn)


# ---------------------------------------------------------------------------
# Low-level helpers (paper Fig. 4, top)
# ---------------------------------------------------------------------------

def make_sb_node(x: int, y: int, side: "Side | str", track: int,
                 width: int = 16, io: IO = IO.SB_OUT) -> SwitchBoxNode:
    if isinstance(side, str):
        side = Side[side.upper()]
    return SwitchBoxNode(x, y, track, width, side, io)


def connect_all(node: Node, targets: Sequence[Node], delay: float = 0.0
                ) -> None:
    for t in targets:
        node.add_edge(t, delay=delay)
