"""Graph-based intermediate representation for CGRA interconnects
(Canal §3.1).

The IR primitives are *nodes* — anything that can be connected in the
underlying hardware — and directed *edges* — wires connecting nodes. A node
with multiple incoming edges lowers to a configurable multiplexer; node
attributes (kind, x, y, side, track, width, delay) drive type checking,
hardware generation and PnR.

This module is pure Python data structures (no JAX): the IR must stay cheap
to build and mutate during design-space exploration. Lowering to the JAX
functional fabric lives in ``repro.core.lowering``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple


class Side(enum.IntEnum):
    """Tile side. Values match the bitstream encoding order."""

    NORTH = 0
    SOUTH = 1
    EAST = 2
    WEST = 3

    def opposite(self) -> "Side":
        return _OPPOSITE[self]

    def delta(self) -> Tuple[int, int]:
        """(dx, dy) of the neighbouring tile on this side (y grows south)."""
        return _DELTA[self]


_OPPOSITE = {
    Side.NORTH: Side.SOUTH,
    Side.SOUTH: Side.NORTH,
    Side.EAST: Side.WEST,
    Side.WEST: Side.EAST,
}
_DELTA = {
    Side.NORTH: (0, -1),
    Side.SOUTH: (0, 1),
    Side.EAST: (1, 0),
    Side.WEST: (-1, 0),
}


class IO(enum.IntEnum):
    SB_IN = 0
    SB_OUT = 1


class NodeKind(enum.IntEnum):
    SWITCH_BOX = 0
    PORT = 1       # core port behind a connection box (fan-in ⇒ CB mux)
    REGISTER = 2   # pipeline register on a track
    REG_MUX = 3    # selects register output vs. combinational bypass
    GENERIC = 4    # user-defined node (low-level eDSL escape hatch)


_node_uid = 0


def _next_uid() -> int:
    global _node_uid
    _node_uid += 1
    return _node_uid


class Node:
    """A connectable point in the interconnect.

    ``fan_in`` order is semantically meaningful: it is the multiplexer input
    order, and therefore fixes the meaning of the configuration select bits.
    """

    kind: NodeKind = NodeKind.GENERIC

    __slots__ = (
        "uid", "x", "y", "track", "width", "fan_in", "fan_out",
        "edge_delay_in", "delay", "attributes",
    )

    def __init__(self, x: int, y: int, track: int, width: int,
                 delay: float = 0.0):
        self.uid = _next_uid()
        self.x = x
        self.y = y
        self.track = track
        self.width = width
        self.fan_in: List["Node"] = []
        self.fan_out: List["Node"] = []
        self.edge_delay_in: List[float] = []
        self.delay = delay            # intrinsic node delay (mux/reg), ns
        self.attributes: Dict[str, object] = {}

    # -- connectivity -------------------------------------------------------
    def add_edge(self, other: "Node", delay: float = 0.0) -> None:
        """Wire ``self -> other``. Widths must match (type check)."""
        if self.width != other.width:
            raise ValueError(
                f"width mismatch on edge {self} -> {other}: "
                f"{self.width} != {other.width}")
        if other in self.fan_out:
            return  # idempotent
        self.fan_out.append(other)
        other.fan_in.append(self)
        other.edge_delay_in.append(delay)

    def remove_edge(self, other: "Node") -> None:
        if other not in self.fan_out:
            raise ValueError(f"no edge {self} -> {other}")
        self.fan_out.remove(other)
        idx = other.fan_in.index(self)
        other.fan_in.pop(idx)
        other.edge_delay_in.pop(idx)

    def get_conn_in(self) -> List["Node"]:
        """Ordered mux inputs (the order defines select-bit semantics)."""
        return list(self.fan_in)

    # -- identity ------------------------------------------------------------
    def node_key(self) -> Tuple:
        """Stable, structural identity used for serialization & bitstreams."""
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"{type(self).__name__}{self.node_key()}"

    def __hash__(self) -> int:
        return self.uid

    def __eq__(self, other: object) -> bool:
        return self is other


class SwitchBoxNode(Node):
    kind = NodeKind.SWITCH_BOX
    __slots__ = ("side", "io")

    def __init__(self, x: int, y: int, track: int, width: int, side: Side,
                 io: IO, delay: float = 0.0):
        super().__init__(x, y, track, width, delay)
        self.side = side
        self.io = io

    def node_key(self) -> Tuple:
        return ("SB", self.x, self.y, int(self.side), int(self.io),
                self.track, self.width)


class PortNode(Node):
    kind = NodeKind.PORT
    __slots__ = ("port_name",)

    def __init__(self, port_name: str, x: int, y: int, width: int,
                 delay: float = 0.0):
        super().__init__(x, y, 0, width, delay)
        self.port_name = port_name

    def node_key(self) -> Tuple:
        return ("PORT", self.x, self.y, self.port_name, self.width)


class RegisterNode(Node):
    kind = NodeKind.REGISTER
    __slots__ = ("reg_name",)

    def __init__(self, reg_name: str, x: int, y: int, track: int, width: int,
                 delay: float = 0.0):
        super().__init__(x, y, track, width, delay)
        self.reg_name = reg_name

    def node_key(self) -> Tuple:
        return ("REG", self.x, self.y, self.reg_name, self.track, self.width)


class RegisterMuxNode(Node):
    kind = NodeKind.REG_MUX
    __slots__ = ("mux_name",)

    def __init__(self, mux_name: str, x: int, y: int, track: int, width: int,
                 delay: float = 0.0):
        super().__init__(x, y, track, width, delay)
        self.mux_name = mux_name

    def node_key(self) -> Tuple:
        return ("RMUX", self.x, self.y, self.mux_name, self.track, self.width)


# ---------------------------------------------------------------------------
# Switch box
# ---------------------------------------------------------------------------

# An internal SB connection: (track_from, side_from, track_to, side_to).
SBConnection = Tuple[int, Side, int, Side]


class SwitchBox:
    """A tile's switch box: 4 sides × num_tracks × {in, out} nodes plus the
    internal topology edges between them."""

    def __init__(self, x: int, y: int, num_tracks: int, width: int,
                 internal_connections: Sequence[SBConnection],
                 mux_delay: float = 0.06):
        self.x = x
        self.y = y
        self.num_tracks = num_tracks
        self.width = width
        self.internal_connections: List[SBConnection] = []
        # sbs[side][io][track]
        self.sbs: Dict[Side, Dict[IO, List[SwitchBoxNode]]] = {}
        for side in Side:
            self.sbs[side] = {}
            for io in IO:
                self.sbs[side][io] = [
                    SwitchBoxNode(x, y, t, width, side, io,
                                  delay=mux_delay if io == IO.SB_OUT else 0.0)
                    for t in range(num_tracks)
                ]
        self.add_internal_connections(internal_connections)

    def add_internal_connections(
            self, connections: Sequence[SBConnection]) -> None:
        """Wire internal topology edges (in -> out). Split out of the
        constructor so the pass pipeline can materialize bare switch boxes
        first (``materialize_tiles``) and apply the topology as its own
        pass (``apply_sb_topology``)."""
        connections = list(connections)   # survive one-shot iterators
        for (t_from, s_from, t_to, s_to) in connections:
            src = self.get_sb(s_from, t_from, IO.SB_IN)
            dst = self.get_sb(s_to, t_to, IO.SB_OUT)
            src.add_edge(dst)
        self.internal_connections.extend(connections)

    def get_sb(self, side: Side, track: int, io: IO) -> SwitchBoxNode:
        return self.sbs[side][io][track]

    def nodes(self) -> Iterator[SwitchBoxNode]:
        for side in Side:
            for io in IO:
                yield from self.sbs[side][io]

    def remove_side_connections(self, side: Side, io: IO) -> None:
        """Depopulate one side (used by the port-connection DSE, Fig. 12)."""
        for node in self.sbs[side][io]:
            for other in list(node.fan_out):
                node.remove_edge(other)
            for src in list(node.fan_in):
                src.remove_edge(node)


# ---------------------------------------------------------------------------
# Tiles & cores
# ---------------------------------------------------------------------------


@dataclass
class PortSpec:
    name: str
    width: int
    is_input: bool
    delay: float = 0.0


class Core:
    """A compute/memory core dropped into a tile. Pure port bundle at the IR
    level; the functional behaviour is attached at lowering time."""

    core_type = "core"
    #: combinational delay through the core, ns (used by STA)
    delay: float = 0.8

    def __init__(self, ports: Sequence[PortSpec]):
        self.ports = list(ports)

    def inputs(self) -> List[PortSpec]:
        return [p for p in self.ports if p.is_input]

    def outputs(self) -> List[PortSpec]:
        return [p for p in self.ports if not p.is_input]


class Tile:
    """One interconnect tile: a switch box, connection boxes (port nodes) and
    an optional core."""

    def __init__(self, x: int, y: int, switchbox: SwitchBox,
                 core: Optional[Core] = None):
        self.x = x
        self.y = y
        self.switchbox = switchbox
        self.core = core
        self.ports: Dict[str, PortNode] = {}
        if core is not None:
            for p in core.ports:
                self.ports[p.name] = PortNode(p.name, x, y, p.width,
                                              delay=p.delay)

    @property
    def core_type(self) -> str:
        return self.core.core_type if self.core is not None else "empty"

    def get_port(self, name: str) -> PortNode:
        return self.ports[name]

    def nodes(self) -> Iterator[Node]:
        yield from self.switchbox.nodes()
        yield from self.ports.values()


class InterconnectGraph:
    """The IR for one routing bit-width: a grid of tiles plus registers."""

    def __init__(self, width: int):
        self.width = width               # data bit width of this layer
        self.tiles: Dict[Tuple[int, int], Tile] = {}
        self.registers: List[RegisterNode] = []
        self.reg_muxes: List[RegisterMuxNode] = []
        #: nodes removed by ``prune`` — excluded from ``nodes()`` (and so
        #: from lowering, routing, area and connectivity)
        self._pruned: set = set()

    # -- construction --------------------------------------------------------
    def add_tile(self, tile: Tile) -> None:
        self.tiles[(tile.x, tile.y)] = tile

    def get_tile(self, x: int, y: int) -> Optional[Tile]:
        return self.tiles.get((x, y))

    def get_sb(self, x: int, y: int, side: Side, track: int,
               io: IO) -> Optional[SwitchBoxNode]:
        tile = self.get_tile(x, y)
        if tile is None:
            return None
        if track >= tile.switchbox.num_tracks:
            return None
        return tile.switchbox.get_sb(side, track, io)

    def get_port(self, x: int, y: int, name: str) -> PortNode:
        return self.tiles[(x, y)].get_port(name)

    def add_register(self, reg: RegisterNode) -> None:
        self.registers.append(reg)

    def add_reg_mux(self, mux: RegisterMuxNode) -> None:
        self.reg_muxes.append(mux)

    def prune(self, nodes: Iterable[Node]) -> None:
        """Remove observer-free nodes (no fan-out) from the graph's node
        set, detaching their incoming edges. A node with fan-out cannot
        be pruned: removing it would shrink its consumers' fan-in lists,
        renumbering surviving mux inputs and silently changing config
        semantics. Detaching *incoming* edges is safe — it only shrinks
        the drivers' fan-out lists, which carry no select-bit meaning
        (and may expose those drivers as newly observer-free: callers
        such as ``prune_dead_muxes`` iterate to a fixpoint)."""
        nodes = list(nodes)       # a generator must not drain on validation
        for n in nodes:
            if n.fan_out:
                raise ValueError(
                    f"cannot prune node still connected downstream: {n}")
        dead = set(nodes)
        if not dead:
            return
        for n in dead:
            for src in list(n.fan_in):
                src.remove_edge(n)
        self.registers = [r for r in self.registers if r not in dead]
        self.reg_muxes = [m for m in self.reg_muxes if m not in dead]
        self._pruned.update(dead)

    # -- queries --------------------------------------------------------------
    def nodes(self) -> Iterator[Node]:
        if self._pruned:
            for tile in self.tiles.values():
                for n in tile.nodes():
                    if n not in self._pruned:
                        yield n
        else:
            for tile in self.tiles.values():
                yield from tile.nodes()
        yield from self.registers
        yield from self.reg_muxes

    def num_nodes(self) -> int:
        return sum(1 for _ in self.nodes())

    def edges(self) -> Iterator[Tuple[Node, Node, float]]:
        for node in self.nodes():
            for dst, d in zip(node.fan_out,
                              _delays_for(node)):
                yield node, dst, d

    def dims(self) -> Tuple[int, int]:
        xs = [x for x, _ in self.tiles]
        ys = [y for _, y in self.tiles]
        return max(xs) + 1, max(ys) + 1

    # -- structural serialization (used for verification round-trips) --------
    def connectivity(self) -> Dict[Tuple, List[Tuple]]:
        """Structural map node_key -> sorted fan-in node_keys."""
        out: Dict[Tuple, List[Tuple]] = {}
        for node in self.nodes():
            out[node.node_key()] = [n.node_key() for n in node.fan_in]
        return out


def _delays_for(node: Node) -> List[float]:
    """Edge delays, aligned with node.fan_out (looked up on the dst side)."""
    ds = []
    for dst in node.fan_out:
        idx = dst.fan_in.index(node)
        ds.append(dst.edge_delay_in[idx])
    return ds


class Interconnect:
    """Top level: one InterconnectGraph per routing bit-width, plus global
    metadata. This is what the eDSL emits and every backend consumes."""

    def __init__(self, graphs: Dict[int, InterconnectGraph],
                 config_addr_width: int = 8, config_data_width: int = 32):
        self.graphs = graphs
        self.config_addr_width = config_addr_width
        self.config_data_width = config_data_width
        self.params: Dict[str, object] = {}

    def graph(self, width: int) -> InterconnectGraph:
        return self.graphs[width]

    @property
    def widths(self) -> List[int]:
        return sorted(self.graphs)

    def dims(self) -> Tuple[int, int]:
        return next(iter(self.graphs.values())).dims()

    def nodes(self) -> Iterator[Node]:
        for g in self.graphs.values():
            yield from g.nodes()

    def num_nodes(self) -> int:
        return sum(g.num_nodes() for g in self.graphs.values())

    def num_edges(self) -> int:
        return sum(sum(1 for _ in g.edges()) for g in self.graphs.values())

    def connectivity(self) -> Dict[Tuple, List[Tuple]]:
        out: Dict[Tuple, List[Tuple]] = {}
        for g in self.graphs.values():
            out.update(g.connectivity())
        return out


# ---------------------------------------------------------------------------
# Topological utilities shared by lowering & PnR
# ---------------------------------------------------------------------------


def levelize(nodes: Iterable[Node]) -> List[List[Node]]:
    """Group nodes into combinational levels. REGISTER nodes are sequential
    boundaries: their outputs are level-0 sources (state), so cycles through
    registers are legal; a purely combinational cycle raises."""
    nodes = list(nodes)
    level: Dict[Node, int] = {}
    indeg: Dict[Node, int] = {}
    for n in nodes:
        if n.kind == NodeKind.REGISTER:
            indeg[n] = 0        # state: breaks the cycle
        else:
            indeg[n] = len(n.fan_in)
    frontier = [n for n in nodes if indeg[n] == 0]
    for n in frontier:
        level[n] = 0
    seen = 0
    order: List[Node] = []
    while frontier:
        n = frontier.pop()
        order.append(n)
        seen += 1
        for dst in n.fan_out:
            if dst.kind == NodeKind.REGISTER:
                continue
            indeg[dst] -= 1
            level[dst] = max(level.get(dst, 0), level[n] + 1)
            if indeg[dst] == 0:
                frontier.append(dst)
    if seen != len(nodes):
        stuck = [n for n in nodes if n not in level]
        raise ValueError(
            f"combinational cycle through {len(stuck)} nodes, e.g. "
            f"{stuck[:4]}")
    # registers live at level 0 (as sources); also appear as sinks implicitly
    n_levels = max(level.values()) + 1 if level else 0
    buckets: List[List[Node]] = [[] for _ in range(n_levels)]
    for n in order:
        buckets[level[n]].append(n)
    return buckets
