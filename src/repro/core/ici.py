"""Canal-for-collectives: the pod ICI fabric as a Canal interconnect.

The beyond-paper integration (DESIGN.md §2): the same graph IR + router
that generates CGRA interconnects models the TPU pod's 2-D torus. Chips
are GENERIC nodes, ICI links are edges; a compiled step's collectives
become *nets* (per-hop transfers of their ring schedules), and either

* a fast dimension-ordered accounting (`link_loads`) or
* Canal's own negotiated-congestion router (`route_traffic_canal`)

assigns them to physical links. The congestion-aware collective time
(max-link bytes / link bw) refines the naive ``bytes/(links x bw)``
roofline term, and lets us DSE the mesh the way the paper DSEs switch
boxes (axis order, torus vs mesh, per-axis ring schedules).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .graph import Node, NodeKind
from repro.roofline.hw import TPU_V5E, ChipSpec


@dataclass
class PodFabric:
    """2-D torus of chips; link_bytes[(src, dst)] accumulates traffic."""

    nx: int
    ny: int
    torus: bool = True

    def __post_init__(self):
        self.link_bytes: Dict[Tuple[int, int], float] = {}
        for x in range(self.nx):
            for y in range(self.ny):
                i = self.chip(x, y)
                for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                    xx, yy = x + dx, y + dy
                    if self.torus:
                        xx %= self.nx
                        yy %= self.ny
                    elif not (0 <= xx < self.nx and 0 <= yy < self.ny):
                        continue
                    j = self.chip(xx, yy)
                    if i != j:
                        self.link_bytes[(i, j)] = 0.0

    def chip(self, x: int, y: int) -> int:
        return y * self.nx + x

    def coords(self, i: int) -> Tuple[int, int]:
        return i % self.nx, i // self.nx

    def add(self, src: int, dst: int, nbytes: float) -> None:
        self.link_bytes[(src, dst)] += nbytes

    # ------------------------------------------------- collective schedules
    def ring_neighbors(self, axis: str) -> List[Tuple[int, int]]:
        """Unidirectional ring hops along one torus axis, all rows/cols."""
        hops = []
        if axis == "x":
            for y in range(self.ny):
                for x in range(self.nx):
                    hops.append((self.chip(x, y),
                                 self.chip((x + 1) % self.nx, y)))
        else:
            for x in range(self.nx):
                for y in range(self.ny):
                    hops.append((self.chip(x, y),
                                 self.chip(x, (y + 1) % self.ny)))
        return hops

    def apply_all_reduce(self, nbytes: float, axis: str,
                         bidirectional: bool = True) -> None:
        """Ring all-reduce on one axis: reduce-scatter + all-gather, each
        moving (N-1)/N of the tensor over every ring hop."""
        n = self.nx if axis == "x" else self.ny
        per_hop = 2.0 * nbytes * (n - 1) / n / n
        hops = self.ring_neighbors(axis)
        share = 0.5 if bidirectional else 1.0
        for s, d in hops:
            self.add(s, d, per_hop * share)
            if bidirectional:
                self.add(d, s, per_hop * share)

    def apply_all_gather(self, nbytes: float, axis: str) -> None:
        n = self.nx if axis == "x" else self.ny
        per_hop = nbytes * (n - 1) / n / n
        for s, d in self.ring_neighbors(axis):
            self.add(s, d, per_hop)

    def apply_all_to_all(self, nbytes: float, axis: str) -> None:
        """Pairwise exchange along the axis, dimension-ordered."""
        n = self.nx if axis == "x" else self.ny
        # each chip sends nbytes/n to each of n-1 peers; average hop
        # distance on a ring is n/4 (bidirectional shortest path)
        avg_hops = max(n / 4.0, 1.0)
        per_link = nbytes / n * (n - 1) * avg_hops / n
        for s, d in self.ring_neighbors(axis):
            self.add(s, d, per_link / 2)
            self.add(d, s, per_link / 2)

    # ---------------------------------------------------------- summaries
    def max_link_bytes(self) -> float:
        return max(self.link_bytes.values(), default=0.0)

    def total_bytes(self) -> float:
        return sum(self.link_bytes.values())

    def congestion_factor(self) -> float:
        """max link load / mean link load (1.0 = perfectly balanced)."""
        loads = np.array(list(self.link_bytes.values()))
        mean = loads.mean() if loads.size else 0.0
        return float(loads.max() / mean) if mean > 0 else 1.0

    def collective_time(self, chip: ChipSpec = TPU_V5E) -> float:
        return self.max_link_bytes() / chip.ici_link_bw


AXIS_OF_GROUP = {16: None}      # resolved against the mesh shape


def pod_collective_model(collectives_by_kind: Dict[str, float],
                         mesh_axes: Dict[str, int],
                         chip: ChipSpec = TPU_V5E,
                         axis_order: Tuple[str, str] = ("data", "model")
                         ) -> Dict[str, float]:
    """Schedule a dry-run cell's collective traffic onto the pod torus.

    collectives_by_kind: per-chip link traffic by op kind (from the HLO
    parse). Model-axis collectives ride the x rings, data-axis the y
    rings (axis_order swaps this — a DSE knob).
    """
    nx = mesh_axes.get("model", 16)
    ny = mesh_axes.get("data", 16)
    # per_chip values are already *link traffic* (ring factors applied by
    # hlo_parse). The naive roofline spreads them over all 4 links; the
    # pod model recognizes that each collective's ring only uses the 2
    # links of ITS axis: tensor-parallel collectives (all-gather /
    # reduce-scatter / all-to-all) ride the model axis, gradient
    # all-reduce rides the data axis, so per-axis hot-link load is
    # traffic/2, not traffic/4.
    model_kinds = ("all-gather", "reduce-scatter", "all-to-all",
                   "collective-permute")
    t_model = sum(v for k, v in collectives_by_kind.items()
                  if k in model_kinds)
    t_data = sum(v for k, v in collectives_by_kind.items()
                 if k == "all-reduce")
    if axis_order != ("data", "model"):
        t_model, t_data = t_data, t_model
    x_load = t_model / 2.0               # 2 links per axis per chip
    y_load = t_data / 2.0
    max_link = max(x_load, y_load)
    total = sum(collectives_by_kind.values())
    naive = total / chip.ici_links
    return {
        "max_link_bytes": max_link,
        "congestion_factor": (max_link / (total / chip.ici_links)
                              if total > 0 else 1.0),
        "collective_time_s": max_link / chip.ici_link_bw,
        "naive_time_s": naive / chip.ici_link_bw,
    }


# ---------------------------------------------------------------------------
# Canal-router variant: the pod as a Canal IR graph, nets routed with the
# paper's negotiated-congestion router (demonstrates IR reuse; small pods)
# ---------------------------------------------------------------------------


class _ChipNode(Node):
    kind = NodeKind.GENERIC

    def __init__(self, x: int, y: int, port: int):
        super().__init__(x, y, track=port, width=32)
        self.port = port

    def node_key(self):
        return ("CHIP", self.x, self.y, self.port)


class _FlowPort(Node):
    kind = NodeKind.PORT

    def __init__(self, name: str, x: int, y: int):
        super().__init__(x, y, track=0, width=32)
        self.name = name

    def node_key(self):
        return ("FLOWPORT", self.name, self.x, self.y)


def route_traffic_canal(nx: int, ny: int,
                        flows: Sequence[Tuple[Tuple[int, int],
                                              Tuple[int, int]]],
                        lanes: int = 2):
    """Route point-to-point flows over the pod with Canal's PathFinder.

    Chips provide ``lanes`` capacity-1 transit nodes per location; every
    flow gets its own inject/eject PORT nodes (NIC model) so endpoints
    never block transit. Returns (RoutingResult, transit usage histogram).
    Used by the ICI DSE benchmark/tests on small pods.
    """
    from repro.core.pnr.route import RoutingResources, route_nets

    class _FakeIC:
        def __init__(self, all_nodes):
            self._nodes = all_nodes
            self.widths = [32]

        def nodes(self):
            return iter(self._nodes)

    nodes: List[Node] = []
    grid: Dict[Tuple[int, int], List[_ChipNode]] = {}
    for y in range(ny):
        for x in range(nx):
            ports = [_ChipNode(x, y, p) for p in range(lanes)]
            grid[(x, y)] = ports
            nodes.extend(ports)
    for (x, y), ports in grid.items():
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            xx, yy = (x + dx) % nx, (y + dy) % ny
            for p_src in ports:
                for p_dst in grid[(xx, yy)]:   # lane change allowed at hop
                    p_src.add_edge(p_dst, delay=1.0)

    flow_ports: List[Tuple[_FlowPort, _FlowPort]] = []
    for i, (src, dst) in enumerate(flows):
        inj = _FlowPort(f"inj{i}", *src)
        ej = _FlowPort(f"ej{i}", *dst)
        for lane_node in grid[src]:
            inj.add_edge(lane_node)
        for lane_node in grid[dst]:
            lane_node.add_edge(ej)
        nodes += [inj, ej]
        flow_ports.append((inj, ej))

    res = RoutingResources(_FakeIC(nodes), reg_penalty=0.0)
    nets = [(f"flow{i}", res.node_id[inj], [res.node_id[ej]])
            for i, (inj, ej) in enumerate(flow_ports)
            if inj.x != ej.x or inj.y != ej.y]
    # transit nodes carry 2 virtual channels; flow ports are exclusive
    cap = np.where(res.kind == int(NodeKind.PORT), 1, 2).astype(np.int32)
    result = route_nets(res, nets, max_iters=80,
                        pres_fac0=1.0, pres_growth=1.7,
                        node_capacity=cap)
    usage = np.zeros(len(res.nodes), np.int32)
    for net in result.nets:
        for nid in net.nodes_used():
            if res.kind[nid] != int(NodeKind.PORT):
                usage[nid] += 1
    return result, usage
