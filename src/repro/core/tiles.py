"""Core definitions dropped into interconnect tiles.

Cores are port bundles at the IR level (Canal is agnostic to the core's
internals); each core also carries a *functional model* — a pure function on
int32 words — used by the JAX fabric backend, and PnR metadata (op names it
can implement, intrinsic delay).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence


from .graph import Core, PortSpec

WORD = 0xFFFF  # 16-bit datapath mask


class PECore(Core):
    """Processing element: 4 data inputs, 2 outputs (paper §4.1 baseline).

    The functional model implements a small ALU chosen by the PE opcode
    (part of the core config, not the interconnect bitstream).
    """

    core_type = "pe"
    delay = 0.8  # ns through the ALU, GF12-ish

    OPS = ("add", "sub", "mul", "and", "or", "xor", "shl", "shr", "min",
           "max", "abs", "sel", "const", "pass")

    def __init__(self, width: int = 16, num_inputs: int = 4,
                 num_outputs: int = 2):
        self.width = width
        self.num_inputs = num_inputs
        self.num_outputs = num_outputs
        ports = [PortSpec(f"data{i}", width, True) for i in range(num_inputs)]
        ports += [PortSpec(f"res{i}", width, False)
                  for i in range(num_outputs)]
        super().__init__(ports)

    @staticmethod
    def evaluate(op: str, operands: Sequence[int], const: int = 0) -> int:
        a = operands[0] if len(operands) > 0 else 0
        b = operands[1] if len(operands) > 1 else 0
        c = operands[2] if len(operands) > 2 else 0
        if op == "add":
            r = a + b
        elif op == "sub":
            r = a - b
        elif op == "mul":
            r = a * b
        elif op == "and":
            r = a & b
        elif op == "or":
            r = a | b
        elif op == "xor":
            r = a ^ b
        elif op == "shl":
            r = a << (b & 0xF)
        elif op == "shr":
            r = a >> (b & 0xF)
        elif op == "min":
            r = min(a, b)
        elif op == "max":
            r = max(a, b)
        elif op == "abs":
            r = abs(a - b)
        elif op == "sel":
            r = b if (a & 1) else c
        elif op == "const":
            r = const
        elif op == "pass":
            r = a
        else:
            raise ValueError(f"unknown PE op {op}")
        return int(r) & WORD


class MemCore(Core):
    """Memory core: behaves as a configurable delay line / ROM for the
    functional tests (the real MEM has many modes; line-buffer semantics are
    what image pipelines use)."""

    core_type = "mem"
    delay = 1.0

    def __init__(self, width: int = 16, depth: int = 512):
        self.width = width
        self.depth = depth
        ports = [
            PortSpec("wdata", width, True),
            PortSpec("waddr", width, True),
            PortSpec("raddr", width, True),
            PortSpec("flush", width, True),
            PortSpec("rdata", width, False),
            PortSpec("valid", width, False),
        ]
        super().__init__(ports)


class IOCore(Core):
    """Array-edge IO: one input stream in, one output stream out."""

    core_type = "io"
    delay = 0.1

    def __init__(self, width: int = 16):
        self.width = width
        ports = [
            PortSpec("io_in", width, True),   # from array to pad
            PortSpec("io_out", width, False),  # from pad into array
        ]
        super().__init__(ports)


CORE_FACTORIES: Dict[str, Callable[..., Core]] = {
    "pe": PECore,
    "mem": MemCore,
    "io": IOCore,
}


def default_core_assigner(mem_columns: Sequence[int] = (),
                          io_ring: bool = False,
                          pe_inputs: int = 4, pe_outputs: int = 2,
                          width: int = 16) -> Callable[[int, int, int, int],
                                                       Optional[Core]]:
    """Returns core_fn(x, y, W, H) -> Core placing MEM cores on the given
    columns and PEs elsewhere; optionally an IO ring on the array border."""

    def core_fn(x: int, y: int, w: int, h: int) -> Optional[Core]:
        if io_ring and (x in (0, w - 1) or y in (0, h - 1)):
            return IOCore(width)
        if x in mem_columns:
            return MemCore(width)
        return PECore(width, pe_inputs, pe_outputs)

    return core_fn
