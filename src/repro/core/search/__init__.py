"""Search-driven design-space exploration (the optimizer front end).

The grid sweeps of :mod:`repro.core.dse` enumerate; this package
*searches*: a :class:`SearchSpace` of axes over
:class:`~repro.core.spec.InterconnectSpec`, pluggable
:class:`~.selectors.Selector` policies (random / greedy local mutation
/ evolutionary), and a :func:`search` driver that batches candidate
evaluation through one store-memoized
:meth:`~repro.core.dse.SweepExecutor.run_points` call per round while
maintaining a Pareto frontier over (area, critical-path delay,
routability).

Entry points: ``canal.search(...)`` (this :func:`search`),
``DSEService.recommend(...)`` (the serving verb), and
``python -m canal.search`` (the CLI, :mod:`.cli`).
"""
from .driver import search
from .pareto import (Evaluated, SearchResult, best_point, dominates,
                     pareto_frontier, point_metrics)
from .selectors import (EvolutionarySelector, GreedySelector,
                        RandomSelector, Selector, SelectorKind,
                        make_selector)
from .space import SearchSpace

__all__ = [
    "search", "SearchSpace", "SearchResult", "Evaluated",
    "dominates", "pareto_frontier", "best_point", "point_metrics",
    "Selector", "SelectorKind", "make_selector",
    "RandomSelector", "GreedySelector", "EvolutionarySelector",
]
