"""The search driver: selector proposes, executor evaluates, frontier
accumulates.

``search()`` turns the grid-sweep substrate into an optimizer: each
round the selector proposes a candidate batch, the batch is evaluated
through a single :meth:`repro.core.dse.SweepExecutor.run_points` call —
store-memoized (repeat searches are pure store hits, zero PnR),
statically-invalid candidates pruned for free by the analyzer verdict
already on the record — and the evaluated points feed the selector and
the Pareto frontier over (area, critical-path delay, routability).
"""
from __future__ import annotations

import random
from typing import Any, Dict, List, Optional

from ..spec import InterconnectSpec
from .pareto import Evaluated, SearchResult, pareto_frontier, \
    point_metrics
from .selectors import make_selector
from .space import SearchSpace


def _point_valid(rec: Dict) -> bool:
    """Statically valid and not skipped: the analyzer said ``clean`` (or
    predates the analysis field) and no app was skipped pre-PnR."""
    analysis = rec.get("analysis")
    if isinstance(analysis, dict) and not analysis.get("clean", True):
        return False
    apps = rec.get("apps") or {}
    return not any(isinstance(a, dict) and a.get("skipped")
                   for a in apps.values())


def search(base: Optional[InterconnectSpec] = None,
           axes: Optional[Dict] = None, *,
           space: Optional[SearchSpace] = None,
           selector: str = "greedy",
           objective: str = "area",
           constraints: Optional[Dict[str, float]] = None,
           budget: int = 32, batch_size: int = 4, seed: int = 0,
           executor: Any = None, store: Any = None,
           apps: Optional[Dict] = None, emulate_cycles: int = 0,
           selector_options: Optional[Dict] = None,
           use_pallas: bool = True,
           max_workers: Optional[int] = None,
           **executor_kwargs) -> SearchResult:
    """Search-driven design-space exploration over ``InterconnectSpec``
    space (exported as ``canal.search``).

    Pass ``base`` + ``axes`` (the ``spec_grid`` shape) or a prebuilt
    :class:`SearchSpace`. ``selector`` is ``"random"``, ``"greedy"`` or
    ``"evolutionary"`` (:mod:`.selectors`); ``objective`` one of
    ``area`` / ``critical_path_ns`` / ``routability``; ``constraints``
    e.g. ``{"max_critical_path_ns": 5.0, "min_routability": 1.0}``.
    ``budget`` caps evaluated candidates, proposed ``batch_size`` at a
    time (one batched executor pass each — shared caches, concurrent
    points, batched emulation).

    An existing ``executor`` (e.g. a :class:`DSEService`'s) is reused
    as configured; otherwise one is built from ``store`` / ``apps`` /
    ``emulate_cycles`` / ``use_pallas`` and the remaining kwargs.
    Returns a :class:`SearchResult` — ``frontier`` (non-dominated valid
    points), ``evaluated`` (everything), ``stats`` (round counts plus
    the executor counter deltas, so "zero new PnR on the re-run" is one
    assertion away)."""
    if space is None:
        if base is None or axes is None:
            raise TypeError("pass base + axes, or space=SearchSpace(...)")
        space = SearchSpace(base, axes)
    elif base is not None or axes is not None:
        raise TypeError("pass base + axes or space, not both")
    if budget < 1:
        raise ValueError("budget must be >= 1")
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    if executor is not None and (store is not None or apps is not None
                                 or executor_kwargs):
        raise TypeError("pass executor kwargs or a prebuilt executor, "
                        "not both")
    if executor is None:
        from ..dse import SweepExecutor
        executor = SweepExecutor(apps=apps, store=store,
                                 emulate_cycles=emulate_cycles,
                                 use_pallas=use_pallas,
                                 max_workers=max_workers,
                                 **executor_kwargs)

    rng = random.Random(seed)
    sel = make_selector(selector, space, rng, objective=objective,
                        constraints=constraints,
                        **(selector_options or {}))
    before = executor.stats()
    evaluated: List[Evaluated] = []
    evaluated_specs: set = set()
    rounds = proposed = invalid = stalls = 0
    while len(evaluated) < budget:
        n = min(batch_size, budget - len(evaluated))
        cands = sel.propose(n)
        if not cands:
            break  # selector exhausted the space
        rounds += 1
        proposed += len(cands)
        # driver-side dedup: a selector re-proposing an evaluated spec
        # must not burn budget on it (the executor would just serve the
        # store record again)
        cands = [s for s in cands if s not in evaluated_specs][:n]
        if not cands:
            # the bundled selectors never re-propose; a custom one that
            # keeps doing so must not spin the loop forever
            stalls += 1
            if stalls >= 3:
                break
            continue
        stalls = 0
        recs = executor.run_specs(cands, record=False)
        batch: List[Evaluated] = []
        for cand, rec in zip(cands, recs):
            valid = _point_valid(rec)
            if not valid:
                invalid += 1
            ev = Evaluated(spec=cand, digest=rec.get("spec_digest", ""),
                           record=rec, metrics=point_metrics(rec),
                           valid=valid)
            batch.append(ev)
            evaluated.append(ev)
            evaluated_specs.add(cand)
        sel.observe(batch)
    after = executor.stats()
    stats = {"selector": str(getattr(selector, "value", selector)),
             "objective": objective,
             "constraints": dict(constraints or {}),
             "budget": budget, "rounds": rounds,
             "proposed": proposed, "evaluated": len(evaluated),
             "statically_invalid": invalid,
             "space_size": space.size(),
             "executor": {k: after[k] - before[k] for k in after}}
    frontier = pareto_frontier(evaluated)
    stats["frontier_size"] = len(frontier)
    return SearchResult(frontier=frontier, evaluated=evaluated,
                        stats=stats)
