"""Search space over :class:`~repro.core.spec.InterconnectSpec`.

A :class:`SearchSpace` is a base spec plus named axes — the same
``{field: values}`` shape :func:`repro.core.spec.spec_grid` sweeps
exhaustively — with the mutation/neighborhood operators the selectors
need: uniform sampling, single-axis mutation, adjacent-value neighbors,
and full enumeration for small spaces. Axes are canonicalized once at
construction (:func:`repro.core.spec.spec_axes`): unknown fields and
unconstructible values fail here, with the axis named, not deep inside
a search run.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, Iterator, List, Sequence, Tuple

from ..spec import (InterconnectSpec, mutate_spec, neighbor_specs,
                    spec_axes, spec_grid)


class SearchSpace:
    """Axes over a base spec, with selector operators.

    Membership, sampling and enumeration all range over the *projected*
    grid: every point is ``base`` with each axis field set to one of its
    allowed values (off-axis fields pinned at the base's values)."""

    def __init__(self, base: InterconnectSpec,
                 axes: Dict[str, Sequence]):
        if not isinstance(base, InterconnectSpec):
            raise TypeError("base must be an InterconnectSpec, got "
                            f"{type(base).__name__}")
        if not axes:
            raise ValueError("a SearchSpace needs at least one axis")
        self.base = base
        self.axes: Dict[str, Tuple] = spec_axes(base, axes)

    # ------------------------------------------------------------ geometry
    def size(self) -> int:
        """Number of points in the full grid (the search's upper bound —
        a selector earning its keep evaluates fewer)."""
        n = 1
        for vals in self.axes.values():
            n *= len(vals)
        return n

    def grid(self) -> List[InterconnectSpec]:
        """Every point, axis-major order (deterministic)."""
        return [s for s, _ in spec_grid(self.base, self.axes)]

    def __iter__(self) -> Iterator[InterconnectSpec]:
        return iter(self.grid())

    def __len__(self) -> int:
        return self.size()

    def contains(self, spec: InterconnectSpec) -> bool:
        """Whether ``spec`` lies on the projected grid: every axis field
        at an allowed value, every off-axis field equal to the base's."""
        for name, vals in self.axes.items():
            if getattr(spec, name) not in vals:
                return False
        pinned = {n: getattr(spec, n) for n in self.axes}
        return replace(self.base, **pinned) == spec

    def origin(self) -> InterconnectSpec:
        """The canonical start point: the base projected onto the grid —
        axis fields already at an allowed value stay, others snap to the
        axis's middle value (a central start gives a local search the
        most room in both directions)."""
        pinned = {}
        for name, vals in self.axes.items():
            cur = getattr(self.base, name)
            pinned[name] = cur if cur in vals else vals[len(vals) // 2]
        return replace(self.base, **pinned)

    # ----------------------------------------------------------- operators
    def sample(self, rng) -> InterconnectSpec:
        """One uniform grid point."""
        pinned = {name: rng.choice(vals)
                  for name, vals in self.axes.items()}
        return replace(self.base, **pinned)

    def mutate(self, spec: InterconnectSpec, rng) -> InterconnectSpec:
        """Single-axis local mutation (:func:`spec.mutate_spec`)."""
        return mutate_spec(spec, self.axes, rng)

    def neighbors(self, spec: InterconnectSpec) -> List[InterconnectSpec]:
        """Adjacent grid points (:func:`spec.neighbor_specs`),
        deterministic order."""
        return neighbor_specs(spec, self.axes)

    # --------------------------------------------------------------- misc
    def to_dict(self) -> Dict:
        """JSON-safe description (CLI/artifact output)."""
        from ..spec import _json_safe
        return {"base": self.base.canonical_dict(),
                "axes": {n: [_json_safe(v) for v in vals]
                         for n, vals in self.axes.items()},
                "size": self.size()}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        dims = "x".join(str(len(v)) for v in self.axes.values())
        return (f"SearchSpace(axes={list(self.axes)}, "
                f"dims={dims}, size={self.size()})")
