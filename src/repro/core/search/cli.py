"""``python -m canal.search`` — the search-driven DSE CLI.

Runs :func:`repro.core.search.search` over axes given as JSON and
emits the Pareto frontier (plus the scalarized best point and run
stats) as a JSON document, store-backed by default so repeated runs
are pure store hits.

Exit codes: 0 = frontier non-empty, 1 = empty frontier (nothing valid
evaluated), 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from ..spec import InterconnectSpec


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m canal.search",
        description="Search-driven DSE over InterconnectSpec space: "
                    "selector proposes, the store-backed executor "
                    "evaluates, the Pareto frontier over (area, "
                    "critical-path delay, routability) comes out as "
                    "JSON.")
    g = p.add_argument_group("search space")
    g.add_argument("--base", metavar="FILE",
                   help="base spec as a JSON file (InterconnectSpec "
                        "fields); default: a width x height fabric "
                        "with an IO ring")
    g.add_argument("--width", type=int, default=4,
                   help="base fabric width when --base is not given "
                        "(default 4)")
    g.add_argument("--height", type=int, default=None,
                   help="base fabric height (default: width)")
    g.add_argument("--axes", required=True, metavar="JSON",
                   help="search axes as a JSON object, e.g. "
                        "'{\"num_tracks\": [2, 3, 4]}'")
    g = p.add_argument_group("search policy")
    g.add_argument("--selector", default="greedy",
                   choices=["random", "greedy", "evolutionary"])
    g.add_argument("--objective", default="area",
                   choices=["area", "critical_path_ns", "routability",
                            "throughput", "min_slack_ns"])
    g.add_argument("--max-delay", type=float, default=None,
                   metavar="NS",
                   help="constraint: max critical path (ns)")
    g.add_argument("--max-area", type=float, default=None,
                   help="constraint: max SB+CB area")
    g.add_argument("--min-routability", type=float, default=None,
                   metavar="FRAC",
                   help="constraint: min routed-app fraction")
    g.add_argument("--min-throughput", type=float, default=None,
                   metavar="TOK",
                   help="constraint: min static throughput bound "
                        "(tokens/cycle, from the routed analyzer)")
    g.add_argument("--min-slack", type=float, default=None,
                   metavar="NS",
                   help="constraint: min per-net slack (ns) against "
                        "the reference clock")
    g.add_argument("--budget", type=int, default=32,
                   help="max candidates to evaluate (default 32)")
    g.add_argument("--batch", type=int, default=4,
                   help="candidates per executor batch (default 4)")
    g.add_argument("--seed", type=int, default=0)
    g = p.add_argument_group("evaluation")
    g.add_argument("--apps", default=None, metavar="NAMES",
                   help="comma-separated benchmark apps (default: all "
                        "of repro.core.pnr.app.BENCH_APPS)")
    g.add_argument("--emulate-cycles", type=int, default=0)
    g.add_argument("--store", default=None, metavar="PATH",
                   help="result-store root (default: "
                        "CANAL_RESULT_STORE, else .canal_store)")
    g.add_argument("--no-store", action="store_true",
                   help="run cold: no persistent memoization")
    g.add_argument("--pallas", action="store_true",
                   help="emulate with the Pallas kernels (default: "
                        "pure-JAX interpreter path)")
    p.add_argument("-o", "--output", default=None, metavar="FILE",
                   help="write the JSON document here (default: "
                        "stdout)")
    p.add_argument("--include-records", action="store_true",
                   help="embed the full DSE records in the output")
    return p


def _load_base(ns) -> InterconnectSpec:
    if ns.base:
        with open(ns.base) as f:
            return InterconnectSpec.from_dict(json.load(f))
    h = ns.height if ns.height is not None else ns.width
    return InterconnectSpec(width=ns.width, height=h, io_ring=True,
                            reg_density=1.0)


def run(argv: Optional[List[str]] = None) -> int:
    parser = build_parser()
    ns = parser.parse_args(argv)
    try:
        axes = json.loads(ns.axes)
        if not isinstance(axes, dict):
            raise ValueError("--axes must be a JSON object")
        base = _load_base(ns)
    except (OSError, ValueError) as e:
        parser.exit(2, f"error: {e}\n")
    constraints = {}
    if ns.max_delay is not None:
        constraints["max_critical_path_ns"] = ns.max_delay
    if ns.max_area is not None:
        constraints["max_area"] = ns.max_area
    if ns.min_routability is not None:
        constraints["min_routability"] = ns.min_routability
    if ns.min_throughput is not None:
        constraints["min_throughput"] = ns.min_throughput
    if ns.min_slack is not None:
        constraints["min_slack_ns"] = ns.min_slack

    apps = None
    if ns.apps:
        from ..pnr.app import BENCH_APPS
        names = [a.strip() for a in ns.apps.split(",") if a.strip()]
        unknown = sorted(set(names) - set(BENCH_APPS))
        if unknown:
            parser.exit(2, f"error: unknown apps {unknown}; "
                           f"one of {sorted(BENCH_APPS)}\n")
        apps = {n: BENCH_APPS[n] for n in names}

    from .driver import search
    from .space import SearchSpace
    try:
        space = SearchSpace(base, axes)
    except (TypeError, ValueError) as e:
        parser.exit(2, f"error: {e}\n")
    store = False if ns.no_store else ns.store
    if store is None and not ns.no_store:
        import os
        from ..store import STORE_ENV
        store = os.environ.get(STORE_ENV) or ".canal_store"
    result = search(space=space, selector=ns.selector,
                    objective=ns.objective,
                    constraints=constraints or None,
                    budget=ns.budget, batch_size=ns.batch,
                    seed=ns.seed, store=store, apps=apps,
                    emulate_cycles=ns.emulate_cycles,
                    use_pallas=ns.pallas)
    best = result.best(ns.objective, constraints or None)
    doc = {"selector": ns.selector,
           "objective": ns.objective,
           "constraints": constraints,
           "space": space.to_dict(),
           "best": (best.to_dict(ns.include_records)
                    if best is not None else None),
           "frontier": [p.to_dict(ns.include_records)
                        for p in result.frontier],
           "evaluated": [p.to_dict(ns.include_records)
                         for p in result.evaluated],
           "stats": result.stats}
    text = json.dumps(doc, indent=2, sort_keys=True, default=str)
    if ns.output:
        with open(ns.output, "w") as f:
            f.write(text + "\n")
    else:
        sys.stdout.write(text + "\n")
    return 0 if result.frontier else 1


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(run())
