"""Pluggable candidate selectors for the DSE search driver.

One protocol — :class:`Selector` — behind which the proposal policies
live, mirroring the selector-enum shape of rapidstream-noc's
``noc_pass`` (RANDOM / GREEDY / solver-backed): :class:`SelectorKind`
names the policies, :func:`make_selector` builds one.

A selector alternates ``propose(n)`` (up to ``n`` unseen candidate
specs) with ``observe(evaluated)`` (the driver feeding back the
evaluated batch, statically-invalid points included). All randomness
flows from the driver's seeded ``random.Random`` — same seed, same
proposal stream. ``propose`` returning ``[]`` means the selector has
exhausted the space (or its neighborhood) and the search stops early.
"""
from __future__ import annotations

import enum
from typing import Dict, List, Optional, Protocol

from ..spec import InterconnectSpec
from .pareto import Evaluated, best_point, pareto_frontier
from .space import SearchSpace


class SelectorKind(str, enum.Enum):
    """Selector policies (the ``selector=`` knob of ``canal.search``)."""
    RANDOM = "random"
    GREEDY = "greedy"
    EVOLUTIONARY = "evolutionary"


class Selector(Protocol):
    """The pluggable policy interface the driver loops over."""

    def propose(self, n: int) -> List[InterconnectSpec]:
        """Up to ``n`` unseen candidates; ``[]`` = exhausted."""
        ...

    def observe(self, evaluated: List[Evaluated]) -> None:
        """Feed back the evaluated batch (archive + adapt)."""
        ...


def _random_unseen(space: SearchSpace, rng, seen, n: int
                   ) -> List[InterconnectSpec]:
    """Up to ``n`` unseen uniform samples. Bounded rejection sampling
    first (cheap while the space is mostly unseen); when the space is
    small enough to enumerate, fall back to a shuffled sweep of the
    remaining grid so exhaustion is detected exactly instead of
    probabilistically."""
    out: List[InterconnectSpec] = []
    batch_seen = set()
    for _ in range(max(20 * n, 100)):
        if len(out) >= n:
            return out
        cand = space.sample(rng)
        if cand not in seen and cand not in batch_seen:
            batch_seen.add(cand)
            out.append(cand)
    if len(out) < n and space.size() <= 4096:
        rest = [s for s in space.grid()
                if s not in seen and s not in batch_seen]
        rng.shuffle(rest)
        out.extend(rest[:n - len(out)])
    return out


class RandomSelector:
    """Uniform exploration — the baseline every adaptive selector must
    beat, and the coverage workhorse for tiny spaces (it enumerates
    them exactly, never proposing a duplicate)."""

    def __init__(self, space: SearchSpace, rng, **_ignored):
        self.space = space
        self.rng = rng
        self.seen: set = set()

    def propose(self, n: int) -> List[InterconnectSpec]:
        cands = _random_unseen(self.space, self.rng, self.seen, n)
        self.seen.update(cands)
        return cands

    def observe(self, evaluated: List[Evaluated]) -> None:
        self.seen.update(p.spec for p in evaluated)


class GreedySelector:
    """Local search: walk the axis-neighborhood of the incumbent (the
    best point so far by the scalarized objective, constraint-feasible
    preferred), proposing its unseen neighbors each round. When the
    neighborhood is exhausted — a local optimum — restart from a random
    unseen point rather than stopping, until the budget runs out or the
    space is exhausted."""

    def __init__(self, space: SearchSpace, rng,
                 objective: str = "area",
                 constraints: Optional[Dict[str, float]] = None,
                 **_ignored):
        self.space = space
        self.rng = rng
        self.objective = objective
        self.constraints = constraints
        self.seen: set = set()
        self.archive: List[Evaluated] = []

    def _incumbent(self) -> Optional[Evaluated]:
        # strict=False: while nothing satisfies the constraints yet the
        # best unconstrained point still provides a descent direction
        return best_point(self.archive, self.objective,
                          self.constraints, strict=False)

    def propose(self, n: int) -> List[InterconnectSpec]:
        cands: List[InterconnectSpec] = []
        inc = self._incumbent()
        if inc is None:
            start = self.space.origin()
            cands = ([start] if start not in self.seen
                     else _random_unseen(self.space, self.rng,
                                         self.seen, 1))
        else:
            cands = [s for s in self.space.neighbors(inc.spec)
                     if s not in self.seen][:n]
            if not cands:
                # local optimum: random restart keeps the budget useful
                cands = _random_unseen(self.space, self.rng,
                                       self.seen, 1)
        self.seen.update(cands)
        return cands[:n]

    def observe(self, evaluated: List[Evaluated]) -> None:
        self.seen.update(p.spec for p in evaluated)
        self.archive.extend(evaluated)


class EvolutionarySelector:
    """Pareto-archive evolution: parents are the current frontier of
    the valid archive; children are axis-crossovers of two parents with
    a mutation step, deduplicated against everything seen; random
    unseen samples fill the remainder (and are the entire first
    generation)."""

    def __init__(self, space: SearchSpace, rng,
                 mutation_rate: float = 0.5, **_ignored):
        self.space = space
        self.rng = rng
        self.mutation_rate = mutation_rate
        self.seen: set = set()
        self.archive: List[Evaluated] = []

    def _crossover(self, a: InterconnectSpec, b: InterconnectSpec
                   ) -> InterconnectSpec:
        from dataclasses import replace
        pinned = {name: getattr(self.rng.choice((a, b)), name)
                  for name in self.space.axes}
        return replace(self.space.base, **pinned)

    def propose(self, n: int) -> List[InterconnectSpec]:
        parents = pareto_frontier(self.archive)
        cands: List[InterconnectSpec] = []
        batch_seen = set()
        if parents:
            for _ in range(10 * n):
                if len(cands) >= n:
                    break
                a = self.rng.choice(parents).spec
                b = self.rng.choice(parents).spec
                child = self._crossover(a, b)
                if self.rng.random() < self.mutation_rate:
                    child = self.space.mutate(child, self.rng)
                if child not in self.seen and child not in batch_seen:
                    batch_seen.add(child)
                    cands.append(child)
        if len(cands) < n:
            fill = _random_unseen(self.space, self.rng,
                                  self.seen | batch_seen,
                                  n - len(cands))
            cands.extend(fill)
        self.seen.update(cands)
        return cands

    def observe(self, evaluated: List[Evaluated]) -> None:
        self.seen.update(p.spec for p in evaluated)
        self.archive.extend(evaluated)


_REGISTRY = {
    SelectorKind.RANDOM: RandomSelector,
    SelectorKind.GREEDY: GreedySelector,
    SelectorKind.EVOLUTIONARY: EvolutionarySelector,
}


def make_selector(kind, space: SearchSpace, rng,
                  objective: str = "area",
                  constraints: Optional[Dict[str, float]] = None,
                  **options) -> Selector:
    """Build a selector by kind (a :class:`SelectorKind` or its string
    value). Unknown kinds raise with the valid names listed."""
    try:
        kind = SelectorKind(kind)
    except ValueError:
        raise ValueError(
            f"unknown selector {kind!r}; one of "
            f"{[k.value for k in SelectorKind]}") from None
    cls = _REGISTRY[kind]
    return cls(space, rng, objective=objective,
               constraints=constraints, **options)
