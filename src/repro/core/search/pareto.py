"""Pareto machinery for the DSE optimizer.

The search optimizes the (area, critical-path delay, routability)
triple :func:`repro.core.store.record_metrics` stamps on every record:
smaller area, smaller delay, larger routability. :func:`dominates` is
the partial order, :func:`pareto_frontier` the non-dominated subset,
and :func:`best_point` the scalarized pick the single-objective verbs
(``recommend``, the greedy selector's incumbent) use — an objective to
minimize plus optional hard constraints.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..spec import InterconnectSpec
from ..store import record_metrics

#: metric keys and their sense: True = minimize, False = maximize.
#: ``throughput`` (static tokens/cycle bound) and ``min_slack_ns``
#: (worst per-net slack vs the reference clock) come from the routed
#: static analyzer and appear only on records whose apps carry the
#: static stamps — the dominance/constraint machinery treats them as
#: optional (see :func:`dominates` / :func:`satisfies`).
METRIC_SENSE = {"area": True, "critical_path_ns": True,
                "routability": False, "throughput": False,
                "min_slack_ns": False}

#: constraint keys accepted by :func:`satisfies`
CONSTRAINT_KEYS = ("max_area", "max_critical_path_ns", "min_routability",
                   "min_throughput", "min_slack_ns")


@dataclass
class Evaluated:
    """One evaluated design point: the spec, its store address, the DSE
    record, the frontier metrics, and the static-validity verdict
    (``valid=False`` — analyzer-rejected or unroutable — points are
    archived for dedup but never enter the frontier)."""
    spec: InterconnectSpec
    digest: str
    record: Dict
    metrics: Dict[str, float]
    valid: bool

    def to_dict(self, include_record: bool = False) -> Dict:
        out = {"spec": self.spec.canonical_dict(), "digest": self.digest,
               "metrics": dict(self.metrics), "valid": self.valid}
        if include_record:
            out["record"] = self.record
        return out


#: the always-present metric triple every record summarizes to
_CORE_METRICS = ("area", "critical_path_ns", "routability")

#: pessimistic fallbacks for the optional routed metrics when a point
#: predates them: no throughput claim and no slack headroom — a point
#: that never ran the routed analyzer cannot win on what it never
#: measured
_METRIC_DEFAULTS = {"area": float("inf"),
                    "critical_path_ns": float("inf"),
                    "routability": 0.0, "throughput": 0.0,
                    "min_slack_ns": float("-inf")}


def point_metrics(record: Dict) -> Dict[str, float]:
    """Frontier metrics of a DSE record: the stamped ``metrics`` field
    when present (compute-time or merge-time stamp), else re-derived.
    A stamp is honored for the keys it carries (it may be the exact
    three-key shape of pre-routed-analyzer records, or carry the
    optional ``throughput`` / ``min_slack_ns``); core keys it lacks are
    filled from :func:`record_metrics`."""
    m = record.get("metrics")
    if isinstance(m, dict) and set(_CORE_METRICS) <= set(m):
        out = {k: float(m[k]) for k in METRIC_SENSE if k in m}
        if len(out) < len(METRIC_SENSE):
            derived = record_metrics(record)
            for k, v in derived.items():
                out.setdefault(k, float(v))
        return out
    return record_metrics(record)


def dominates(a: Dict[str, float], b: Dict[str, float]) -> bool:
    """Pareto dominance: ``a`` is no worse than ``b`` on every metric
    (<= on minimized, >= on maximized) and strictly better on at least
    one. Ties on every metric dominate in neither direction. Only
    metrics *both* points carry participate — the optional routed
    metrics never disqualify a point that predates them."""
    strict = False
    for key, minimize in METRIC_SENSE.items():
        if key not in a or key not in b:
            continue
        av, bv = a[key], b[key]
        if minimize:
            if av > bv:
                return False
            strict = strict or av < bv
        else:
            if av < bv:
                return False
            strict = strict or av > bv
    return strict


def pareto_frontier(points: List[Evaluated]) -> List[Evaluated]:
    """The non-dominated subset of the *valid* points, in
    first-appearance order: a point survives iff no other valid point
    strictly dominates it. Metric-identical points dominate in neither
    direction, so ties all stay — every excluded point is *strictly*
    dominated by some frontier point (the invariant the property tests
    pin)."""
    frontier: List[Evaluated] = []
    for p in points:
        if not p.valid:
            continue
        if any(dominates(q.metrics, p.metrics) for q in frontier):
            continue
        frontier = [q for q in frontier
                    if not dominates(p.metrics, q.metrics)]
        frontier.append(p)
    return frontier


def objective_value(metrics: Dict[str, float], objective: str) -> float:
    """Scalarize one metric for minimization (maximized metrics are
    negated, so ``min`` over objective values always means "best")."""
    if objective not in METRIC_SENSE:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {sorted(METRIC_SENSE)}")
    v = float(metrics.get(objective, _METRIC_DEFAULTS[objective]))
    return v if METRIC_SENSE[objective] else -v


def satisfies(metrics: Dict[str, float],
              constraints: Optional[Dict[str, float]]) -> bool:
    """Hard-constraint check: ``max_area``, ``max_critical_path_ns``,
    ``min_routability``, ``min_throughput`` (static tokens/cycle bound
    from the routed analyzer), ``min_slack_ns`` (worst per-net slack vs
    the reference clock). Points lacking an optional routed metric get
    the pessimistic default (no throughput, no slack) — a constraint on
    what was never measured excludes them. Unknown keys raise (a typo'd
    constraint must not silently admit everything)."""
    if not constraints:
        return True
    for key, bound in constraints.items():
        if key == "max_area":
            ok = metrics["area"] <= bound
        elif key == "max_critical_path_ns":
            ok = metrics["critical_path_ns"] <= bound
        elif key == "min_routability":
            ok = metrics["routability"] >= bound
        elif key == "min_throughput":
            ok = metrics.get("throughput", 0.0) >= bound
        elif key == "min_slack_ns":
            ok = metrics.get("min_slack_ns", float("-inf")) >= bound
        else:
            raise ValueError(f"unknown constraint {key!r}; "
                             f"one of {CONSTRAINT_KEYS}")
        if not ok:
            return False
    return True


def best_point(points: List[Evaluated], objective: str = "area",
               constraints: Optional[Dict[str, float]] = None,
               strict: bool = True) -> Optional[Evaluated]:
    """Best valid point by ``objective`` among those satisfying
    ``constraints``. With ``strict`` (the default) an infeasible set
    yields None; ``strict=False`` falls back to the best objective
    value ignoring constraints — the greedy selector's gradient signal
    while it is still outside the feasible region. Deterministic: ties
    go to the earliest point."""
    feasible = [p for p in points
                if p.valid and satisfies(p.metrics, constraints)]
    if not feasible and not strict:
        feasible = [p for p in points if p.valid]
    if not feasible:
        return None
    return min(feasible,
               key=lambda p: objective_value(p.metrics, objective))


@dataclass
class SearchResult:
    """What :func:`repro.core.search.search` returns: the Pareto
    frontier, every evaluated point, and run statistics."""
    frontier: List[Evaluated]
    evaluated: List[Evaluated]
    stats: Dict = field(default_factory=dict)

    def best(self, objective: str = "area",
             constraints: Optional[Dict[str, float]] = None
             ) -> Optional[Evaluated]:
        """Scalarized pick over the evaluated points (strict: None when
        nothing satisfies the constraints)."""
        return best_point(self.evaluated, objective, constraints)

    def to_dict(self, include_records: bool = False) -> Dict:
        return {"frontier": [p.to_dict(include_records)
                             for p in self.frontier],
                "evaluated": [p.to_dict(include_records)
                              for p in self.evaluated],
                "stats": self.stats}
