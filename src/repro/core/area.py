"""Analytical PPA model (§4, GF12 calibration).

We cannot run GlobalFoundries 12 nm synthesis in this container, so area is
an analytical standard-cell model computed *from the IR graph itself* — the
same graph the hardware is generated from — with constants calibrated so
the paper's reported ratios reproduce:

* Fig. 8 — ready-valid FIFO SBs: full depth-2 FIFOs ≈ +54 % SB area over
  the static baseline; split FIFOs ≈ +32 %.
* Fig. 10 — SB and CB area grow with track count (near-linear).
* Fig. 13 — SB/CB area shrink as core-port connections are depopulated.

All constants are µm²-scale GF12-ish numbers; *ratios* are the validated
quantity (see tests/test_area.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, Optional

from .graph import Interconnect, InterconnectGraph, Node, NodeKind


@dataclass(frozen=True)
class AreaConstants:
    """GF12-calibrated standard-cell areas (µm²)."""

    mux2_per_bit: float = 0.6       # 2:1 mux slice
    config_bit: float = 1.2         # config store flop + scan
    ff_per_bit: float = 1.0         # pipeline register flop
    rv_join_per_input: float = 0.4  # Fig. 5 one-hot AOI join, per input
    rv_join_lut_per_input: float = 3.2   # naive LUT join (rejected design)
    fifo_ctrl_full: float = 15.35   # depth-2 FIFO ctrl (registered ready)
    fifo_ctrl_split: float = 16.2   # split-FIFO controller (chained handshake)
    valid_wire_bit: float = 0.0     # valid net is routed with data muxes


CONST = AreaConstants()


def mux_area(n_inputs: int, width: int, c: AreaConstants = CONST) -> float:
    """n:1 mux tree + its configuration bits."""
    if n_inputs <= 1:
        return 0.0
    sel_bits = max(1, math.ceil(math.log2(n_inputs)))
    return (n_inputs - 1) * c.mux2_per_bit * width + sel_bits * c.config_bit


def register_area(width: int, c: AreaConstants = CONST) -> float:
    return width * c.ff_per_bit


def rv_mux_overhead(n_inputs: int, c: AreaConstants = CONST,
                    use_lut: bool = False) -> float:
    """Ready-valid overhead of one mux: the 1-bit valid copy of the mux plus
    the ready-join. ``use_lut=True`` models the naive LUT join the paper
    rejects (Fig. 5 discussion)."""
    if n_inputs <= 1:
        return 0.0
    valid = (n_inputs - 1) * c.mux2_per_bit * 1
    join = n_inputs * (c.rv_join_lut_per_input if use_lut
                       else c.rv_join_per_input)
    return valid + join


def fifo_overhead(width: int, mode: str, c: AreaConstants = CONST) -> float:
    """Per-register FIFO overhead (Fig. 6 / Fig. 8).

    full:  one extra data slot (depth-2) + a registered-ready controller.
    split: storage reused from the neighbouring tile's register; only the
           (slightly larger, chained-handshake) controller is added.
    """
    if mode == "none":
        return 0.0
    if mode == "full":
        return width * c.ff_per_bit + c.fifo_ctrl_full
    if mode == "split":
        return c.fifo_ctrl_split
    raise ValueError(f"unknown fifo mode {mode}")


# ---------------------------------------------------------------------------
# Graph-driven area accounting
# ---------------------------------------------------------------------------


def _tile_nodes(g: InterconnectGraph, x: int, y: int) -> Iterable[Node]:
    tile = g.get_tile(x, y)
    if tile is None:
        return []
    nodes = list(tile.nodes())
    nodes += [r for r in g.registers if (r.x, r.y) == (x, y)]
    nodes += [m for m in g.reg_muxes if (m.x, m.y) == (x, y)]
    return nodes


def tile_area_breakdown(ic: Interconnect, x: int, y: int,
                        rv: Optional[str] = None,
                        c: AreaConstants = CONST,
                        use_lut_join: bool = False) -> Dict[str, float]:
    """Area of one tile's interconnect, split into SB / CB / FIFO parts.

    rv: None (static), "full", or "split" — the ready-valid FIFO mode.
    """
    sb = cb = fifo = 0.0
    if rv is None:
        rv_mode = "none"
    else:
        rv_mode = rv
    for g in ic.graphs.values():
        for node in _tile_nodes(g, x, y):
            n_in = len(node.fan_in)
            a = mux_area(n_in, node.width, c)
            rv_a = (rv_mux_overhead(n_in, c, use_lut_join)
                    if rv_mode != "none" else 0.0)
            if node.kind == NodeKind.PORT:
                if n_in:                      # CB mux in front of core input
                    cb += a + rv_a
            elif node.kind == NodeKind.REGISTER:
                sb += register_area(node.width, c)
                fifo += fifo_overhead(node.width, rv_mode, c)
            else:                             # SB + register muxes
                sb += a + rv_a
    return {"sb": sb, "cb": cb, "fifo": fifo, "total": sb + cb + fifo}


def switch_box_area(ic: Interconnect, rv: Optional[str] = None,
                    c: AreaConstants = CONST, x: Optional[int] = None,
                    y: Optional[int] = None) -> float:
    """SB area (incl. track registers + FIFO overhead) of an interior tile —
    the quantity plotted in Figs. 8/10/13."""
    if x is None or y is None:
        w, h = ic.dims()
        x, y = w // 2, h // 2
    b = tile_area_breakdown(ic, x, y, rv=rv, c=c)
    return b["sb"] + b["fifo"]


def connection_box_area(ic: Interconnect, c: AreaConstants = CONST,
                        x: Optional[int] = None, y: Optional[int] = None
                        ) -> float:
    if x is None or y is None:
        w, h = ic.dims()
        x, y = w // 2, h // 2
    return tile_area_breakdown(ic, x, y, c=c)["cb"]


def interconnect_area(ic: Interconnect, rv: Optional[str] = None,
                      c: AreaConstants = CONST) -> Dict[str, float]:
    """Whole-array interconnect area."""
    w, h = ic.dims()
    tot = {"sb": 0.0, "cb": 0.0, "fifo": 0.0, "total": 0.0}
    for x in range(w):
        for y in range(h):
            b = tile_area_breakdown(ic, x, y, rv=rv, c=c)
            for k in tot:
                tot[k] += b[k]
    return tot


# ---------------------------------------------------------------------------
# Energy model (coarse): per-access switching energy, used for DSE ranking
# ---------------------------------------------------------------------------

ENERGY_PJ = {
    "mux_per_bit": 0.0022,
    "wire_hop_per_bit": 0.011,
    "reg_per_bit": 0.0045,
}


def route_energy_pj(n_mux_crossings: int, n_hops: int, n_regs: int,
                    width: int = 16) -> float:
    e = (n_mux_crossings * ENERGY_PJ["mux_per_bit"]
         + n_hops * ENERGY_PJ["wire_hop_per_bit"]
         + n_regs * ENERGY_PJ["reg_per_bit"])
    return e * width
