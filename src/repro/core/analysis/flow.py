"""Channel-dependency-graph helper for the routed-scope analyses.

Dally's classic argument: a routed network deadlocks iff the *channel
dependency graph* — "an agent holding channel A waits for channel B" —
contains a cycle no buffer stage breaks. On Canal's hybrid ready-valid
fabric (paper §5) the channels are the configured routing nodes: a flit
occupies a mux/wire node until the downstream node accepts it, so every
configured edge (parent -> child of a route tree) is a wait-for
dependency, and a processing element couples its input channels to its
output channels (it holds operands until the result is accepted). FIFO
stages (``rv_fifo``-tagged registers, lowered to depth-1/2 FIFOs by
:class:`repro.fabric.RVFabric`) decouple the handshake: they are the
cycle-breakers.

Two verdicts fall out of the same graph:

* a cycle that remains after removing every FIFO node is a
  *combinational handshake ring* — the ready chain closes on itself with
  zero buffering, the hard deadlock ``rv-deadlock`` rejects;
* a cycle broken only by FIFOs still bounds throughput: with ``S``
  sequential stages and total capacity ``C`` slots, a token needs at
  least ``S`` cycles per lap and at most ``C`` tokens are in flight, so
  the initiation interval obeys ``II >= S / C`` (and the loop deadlocks
  outright once ``C`` tokens are trapped in it). ``throughput-bound``
  turns that into a static lower bound on the emulated II.

Everything here is pure data-plumbing over ``(PackedGraph,
RoutingResult, RoutingResources)`` — the rules in
:mod:`repro.core.analysis.routed` wrap it in diagnostics.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from ..graph import NodeKind


@dataclass
class ChannelDepGraph:
    """The channel dependency graph of one routed application: node ids
    are :class:`RoutingResources` fine-node ids, edges follow the
    configured data flow (route-tree parent -> child, plus PE
    input-sink -> output-source coupling), and ``fifo_capacity`` maps
    each FIFO stage on the used graph to its slot count."""

    #: every routing node used by some net (tree nodes + sources)
    used: Set[int] = field(default_factory=set)
    #: configured wait-for edges, src -> [dst]
    adj: Dict[int, List[int]] = field(default_factory=dict)
    #: FIFO stage node id -> buffer slots (0 never appears: a register
    #: with no credit is not a cycle-breaker and is simply absent here)
    fifo_capacity: Dict[int, int] = field(default_factory=dict)

    def add_edge(self, src: int, dst: int) -> None:
        self.adj.setdefault(src, []).append(dst)

    def sccs(self) -> List[List[int]]:
        """Cyclic strongly-connected components (size > 1 or self-loop),
        deterministic order."""
        return list(_cyclic_sccs(self.adj, sorted(self.used)))

    def unbuffered_cycles(self) -> List[List[int]]:
        """Cycles that survive removing every FIFO stage — the Dally
        deadlock condition with FIFO capacities as cycle-breakers."""
        out: List[List[int]] = []
        for scc in self.sccs():
            members = set(scc) - set(self.fifo_capacity)
            sub = {n: [m for m in self.adj.get(n, []) if m in members]
                   for n in members}
            out.extend(_cyclic_sccs(sub, sorted(members)))
        return out

    def buffered_cycles(self) -> List[Tuple[List[int], int, int]]:
        """Cycles every path of which crosses a FIFO stage, as
        ``(scc_nodes, fifo_stages, total_capacity)`` — the throughput-
        limiting (but deadlock-free while under capacity) loops."""
        out: List[Tuple[List[int], int, int]] = []
        for scc in self.sccs():
            fifos = [n for n in scc if n in self.fifo_capacity]
            members = set(scc) - set(self.fifo_capacity)
            sub = {n: [m for m in self.adj.get(n, []) if m in members]
                   for n in members}
            if fifos and not list(_cyclic_sccs(sub, sorted(members))):
                out.append((scc, len(fifos),
                            sum(self.fifo_capacity[n] for n in fifos)))
        return out

    def static_ii(self) -> float:
        """Static initiation-interval lower bound of this routed app:
        1.0 when the channel dependency graph is acyclic (fully
        pipelined — one token per cycle), ``S / C`` per buffered loop
        (slowest registered loop over its min-cut FIFO capacity,
        clamped at 1.0), ``inf`` when an unbuffered handshake ring
        makes any steady throughput impossible."""
        if self.unbuffered_cycles():
            return float("inf")
        ii = 1.0
        for _, stages, capacity in self.buffered_cycles():
            ii = max(ii, stages / max(capacity, 1))
        return ii


def fifo_depth_of(ic) -> int:
    """Per-stage FIFO slots of the lowered ready-valid fabric: the
    ``readyvalid_transform`` pass records the mode on the IR, and the
    lowering maps full -> depth 2, split -> depth 1 (paper Fig. 6)."""
    return 2 if ic.params.get("rv_fifo_mode", "full") == "full" else 1


def build_channel_graph(packed, routing,
                        fifo_depth: Optional[int] = None
                        ) -> ChannelDepGraph:
    """Build the channel dependency graph of a routed application.

    ``packed`` is the :class:`repro.core.pnr.packing.PackedGraph`,
    ``routing`` the :class:`repro.core.pnr.route.RoutingResult`;
    ``fifo_depth`` overrides the per-stage capacity (default: derived
    from the IR's ``rv_fifo_mode``)."""
    res = routing.resources
    if fifo_depth is None:
        fifo_depth = fifo_depth_of(res.ic)
    cdg = ChannelDepGraph()
    net_by_name = {n.name: n for n in routing.nets}
    # instance coupling tables: which routed nodes feed / leave each
    # placeable instance
    inst_in: Dict[str, List[int]] = {}
    inst_out: Dict[str, List[int]] = {}
    for net in routing.nets:
        cdg.used |= net.nodes_used()
        for parent, child in net.edges():
            cdg.add_edge(parent, child)
    for net in packed.nets:
        rnet = net_by_name.get(net.name)
        if rnet is None:
            continue
        inst_out.setdefault(net.src[0], []).append(rnet.src)
        for (sink_inst, _), sink_id in zip(net.sinks, rnet.sinks):
            inst_in.setdefault(sink_inst, []).append(sink_id)
    # a PE holds its input channels until its output is accepted: the
    # wait-for dependency crosses the core
    for inst in inst_in:
        for src_id in inst_out.get(inst, []):
            for sink_id in inst_in[inst]:
                cdg.add_edge(sink_id, src_id)
    for nid in cdg.used:
        node = res.nodes[nid]
        if (node.kind == NodeKind.REGISTER
                and node.attributes.get("rv_fifo")):
            cdg.fifo_capacity[nid] = fifo_depth
    return cdg


def _cyclic_sccs(adj: Dict[int, List[int]],
                 nodes: Sequence[int]) -> Iterator[List[int]]:
    """Cyclic strongly-connected components of an integer adjacency map
    (iterative Tarjan — routed node sets reach 10^4+, recursion would
    blow the stack). Yields only SCCs that contain a cycle: size > 1,
    or a node with a self-loop."""
    index: Dict[int, int] = {}
    low: Dict[int, int] = {}
    on_stack: Set[int] = set()
    stack: List[int] = []
    counter = 0
    for root in nodes:
        if root in index:
            continue
        work: List[Tuple[int, int]] = [(root, 0)]
        while work:
            n, ei = work[-1]
            if ei == 0:
                index[n] = low[n] = counter
                counter += 1
                stack.append(n)
                on_stack.add(n)
            succ = adj.get(n, ())
            advanced = False
            while ei < len(succ):
                m = succ[ei]
                ei += 1
                if m not in index:
                    work[-1] = (n, ei)
                    work.append((m, 0))
                    advanced = True
                    break
                if m in on_stack:
                    low[n] = min(low[n], index[m])
            if advanced:
                continue
            work.pop()
            if low[n] == index[n]:
                scc: List[int] = []
                while True:
                    m = stack.pop()
                    on_stack.discard(m)
                    scc.append(m)
                    if m == n:
                        break
                if len(scc) > 1 or n in adj.get(n, ()):
                    yield sorted(scc)
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[n])
