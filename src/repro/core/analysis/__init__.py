"""``canal.analyze`` — rule-based static analysis over the interconnect IR.

Public surface:

* :func:`analyze` — run registered rules over an ``Interconnect``,
  returning an :class:`AnalysisReport` of :class:`Diagnostic` findings;
* :func:`register_rule` / :data:`RULES` / :func:`rule_table` — the
  ``AnalysisPass`` registry (the read-only twin of ``DEFAULT_PASSES``);
* ``Severity`` / ``AnalysisError`` — the gating model used by
  ``canal.compile(analyze=...)`` and the DSE pre-screen.

Importing the package registers the built-in rules (``rules`` — the
seven IR rules of ISSUE 6), the post-lowering verification rules
(``lowered`` — the §3.3 checks folded in from ``repro.core.verify``)
and the routed-design rules (``routed`` — deadlock / throughput /
slack / congestion / X-propagation audits over one PnR'd application).
"""
from .diagnostics import (AnalysisError, AnalysisReport, Diagnostic,
                          Severity)
from .framework import (RULES, AnalysisContext, AnalysisPass, analyze,
                        register_rule, rule_set_version, rule_table)
from . import rules as _builtin_rules  # noqa: F401  (registration import)
from . import lowered as _lowered_rules  # noqa: F401
from . import routed as _routed_rules  # noqa: F401
from .routed import DEFAULT_CLOCK_NS, routed_static_metrics  # noqa: F401

__all__ = [
    "AnalysisContext", "AnalysisError", "AnalysisPass", "AnalysisReport",
    "DEFAULT_CLOCK_NS", "Diagnostic", "RULES", "Severity", "analyze",
    "register_rule", "routed_static_metrics", "rule_set_version",
    "rule_table",
]
