"""``canal.analyze`` — rule-based static analysis over the interconnect IR.

Public surface:

* :func:`analyze` — run registered rules over an ``Interconnect``,
  returning an :class:`AnalysisReport` of :class:`Diagnostic` findings;
* :func:`register_rule` / :data:`RULES` / :func:`rule_table` — the
  ``AnalysisPass`` registry (the read-only twin of ``DEFAULT_PASSES``);
* ``Severity`` / ``AnalysisError`` — the gating model used by
  ``canal.compile(analyze=...)`` and the DSE pre-screen.

Importing the package registers the built-in rules (``rules`` — the
seven IR rules of ISSUE 6) and the post-lowering verification rules
(``lowered`` — the §3.3 checks folded in from ``repro.core.verify``).
"""
from .diagnostics import (AnalysisError, AnalysisReport, Diagnostic,
                          Severity)
from .framework import (RULES, AnalysisContext, AnalysisPass, analyze,
                        register_rule, rule_table)
from . import rules as _builtin_rules  # noqa: F401  (registration import)
from . import lowered as _lowered_rules  # noqa: F401

__all__ = [
    "AnalysisContext", "AnalysisError", "AnalysisPass", "AnalysisReport",
    "Diagnostic", "RULES", "Severity", "analyze", "register_rule",
    "rule_table",
]
