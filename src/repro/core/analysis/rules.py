"""Built-in analysis rules over the interconnect IR.

Each rule is a registered :class:`AnalysisPass` (see ``framework``); ids
are stable and kebab-case — they are the contract CI configs, severity
policies and the mutation tests key on:

========================  =====================================================
rule id                   what it rejects
========================  =====================================================
``combinational-loop``    hardwired register-free cycle: oscillates in
                          silicon under every possible configuration
``dead-mux``              node whose output can never reach an observer
                          (core input / boundary output) — also the
                          ``prune_dead_muxes`` convergence cross-check
``unreachable-node``      node no source (core output / boundary input)
                          can ever drive
``dangling-port``         core port with no interconnect attachment, or a
                          port width with no routing layer at all
``fanin-overflow``        mux fan-in (or per-tile config population, or
                          tile coordinates) the bitstream encoding cannot
                          address
``sb-topology-conformance``  switch-box internal edges deviate from the
                          declared Wilton/Disjoint/Imran pattern
``rv-handshake``          ready-valid design with a handshake dependency
                          cycle not broken by a FIFO stage, or a pipeline
                          register the RV transform never FIFO-tagged
``static-routability``    supply-vs-demand bounds a router can never beat:
                          a core tile whose CB network delivers fewer
                          distinct signals than the core has input ports,
                          or an array bisection with no (or too little)
                          crossing capacity
========================  =====================================================

Severity policy: structural impossibilities (loops, dangling interface,
encoding overflow, topology deviation, handshake deadlock, zero bisection
capacity) are errors — PnR or lowering on such an IR wastes minutes to
discover what these rules prove in milliseconds. Waste and tight-capacity
findings (dead/unreachable nodes, sub-demand supply) are warnings: the
fabric still works for some workloads.
"""
from __future__ import annotations

from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

from ..graph import (IO, InterconnectGraph, Node, NodeKind, SwitchBoxNode)
from .diagnostics import Diagnostic, Severity
from .framework import AnalysisContext, register_rule


def _diag(rule: str, severity: Severity, message: str,
          g: Optional[InterconnectGraph] = None,
          node: Optional[Node] = None, tile: Optional[Tuple[int, int]] = None,
          hint: Optional[str] = None) -> Diagnostic:
    if node is not None and tile is None:
        tile = (node.x, node.y)
    return Diagnostic(rule=rule, severity=severity, message=message,
                      width=g.width if g is not None else None,
                      tile=tile,
                      node=repr(node) if node is not None else None,
                      hint=hint)


def _sorted_nodes(nodes: Iterable[Node]) -> List[Node]:
    """Deterministic report order, independent of uid allocation."""
    return sorted(nodes, key=lambda n: repr(n))


# ---------------------------------------------------------------------------
# Cycle analyses (combinational-loop, rv-handshake)
# ---------------------------------------------------------------------------

def _sccs(nodes: List[Node],
          follow: "Callable[[Node, Node], bool]") -> Iterator[List[Node]]:
    """Cyclic strongly-connected components of the node graph restricted
    to edges where ``follow(src, dst)`` holds. Iterative Tarjan — IR
    graphs run to 10^5 nodes, recursion would blow the stack. Yields only
    SCCs that actually contain a cycle (size > 1, or a self-loop)."""
    index: Dict[Node, int] = {}
    low: Dict[Node, int] = {}
    on_stack: Set[Node] = set()
    stack: List[Node] = []
    counter = 0
    for root in nodes:
        if root in index:
            continue
        work: List[Tuple[Node, int]] = [(root, 0)]
        while work:
            n, ei = work[-1]
            if ei == 0:
                index[n] = low[n] = counter
                counter += 1
                stack.append(n)
                on_stack.add(n)
            advanced = False
            while ei < len(n.fan_out):
                m = n.fan_out[ei]
                ei += 1
                if not follow(n, m):
                    continue
                if m not in index:
                    work[-1] = (n, ei)
                    work.append((m, 0))
                    advanced = True
                    break
                if m in on_stack:
                    low[n] = min(low[n], index[m])
            if advanced:
                continue
            work.pop()
            if low[n] == index[n]:
                scc: List[Node] = []
                while True:
                    m = stack.pop()
                    on_stack.discard(m)
                    scc.append(m)
                    if m is n:
                        break
                if len(scc) > 1 or any(
                        x is n and follow(n, n) for x in n.fan_out):
                    yield scc
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[n])


def _hardwired_combinational(src: Node, dst: Node) -> bool:
    """An edge a configuration cannot sever: its destination is a
    register-free fan-in-1 node, i.e. a plain wire, not a mux. Muxes
    (fan-in > 1) leave loop avoidance to the router; registers end the
    combinational path entirely. Any interconnect mesh is full of
    *configurable* register-free cycles — route east then back west —
    and those are healthy; only a cycle made purely of hardwired edges
    is a structural combinational loop that exists in silicon no matter
    what the bitstream says."""
    return dst.kind != NodeKind.REGISTER and len(dst.fan_in) <= 1


@register_rule(
    "combinational-loop",
    description="hardwired register-free cycle: oscillates in hardware "
                "and never converges in emulation, under every possible "
                "configuration")
def combinational_loop(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """Registers are sequential boundaries (their fan-in feeds next-state,
    not this cycle's value), and muxes are the router's loop-avoidance
    points — so the statically-illegal shape is a cycle of hardwired
    combinational edges (see :func:`_hardwired_combinational`): no
    configuration and no router decision can break it."""
    for g in ctx.graphs():
        nodes = list(g.nodes())
        for scc in _sccs(nodes, follow=_hardwired_combinational):
            members = _sorted_nodes(scc)
            sample = ", ".join(repr(n) for n in members[:3])
            yield _diag(
                "combinational-loop", Severity.ERROR,
                f"hardwired register-free cycle through {len(members)} "
                f"node(s): {sample}"
                f"{', ...' if len(members) > 3 else ''}",
                g, node=members[0],
                hint="insert a pipeline register on the cycle, or give "
                     "one of its nodes a second (mux) input so the "
                     "router can break it")


def _is_rv(ctx: AnalysisContext) -> bool:
    if ctx.ic.params.get("rv_fifo_mode"):
        return True
    return bool(ctx.spec is not None and ctx.spec.ready_valid)


@register_rule(
    "rv-handshake",
    description="ready-valid handshake dependency cycle with no FIFO "
                "break, or a register the RV transform never tagged",
    when=_is_rv)
def rv_handshake(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """The hybrid ready-valid interconnect derives ``valid`` forward and
    ``ready`` backward along the same mux network; only a FIFO stage
    (a register tagged ``rv_fifo`` by ``readyvalid_transform``) cuts the
    combinational handshake dependency in both directions. A register
    the transform never tagged lowers as a bare pipeline stage with no
    credit, and a cycle whose registers are all untagged deadlocks: the
    ready chain closes on itself."""
    for g in ctx.graphs():
        nodes = list(g.nodes())
        untagged = [n for n in nodes if n.kind == NodeKind.REGISTER
                    and not n.attributes.get("rv_fifo")]
        for n in _sorted_nodes(untagged):
            yield _diag(
                "rv-handshake", Severity.ERROR,
                "pipeline register is not FIFO-tagged in a ready-valid "
                "design: the handshake dependency through it is never "
                "broken",
                g, node=n,
                hint="run readyvalid_transform (or tag the register's "
                     "rv_fifo attribute)")
        def follow(src: Node, dst: Node) -> bool:
            # a FIFO stage cuts the handshake dependency both ways; a
            # bare (untagged) register does NOT — ready still chains
            # through it combinationally. Mux nodes stay the router's
            # responsibility, as in combinational-loop.
            if dst.kind == NodeKind.REGISTER:
                return not dst.attributes.get("rv_fifo")
            return len(dst.fan_in) <= 1

        for scc in _sccs(nodes, follow=follow):
            members = _sorted_nodes(scc)
            sample = ", ".join(repr(n) for n in members[:3])
            yield _diag(
                "rv-handshake", Severity.ERROR,
                f"cyclic ready-valid handshake dependency through "
                f"{len(members)} node(s) with no FIFO break: {sample}"
                f"{', ...' if len(members) > 3 else ''}",
                g, node=members[0],
                hint="ensure a FIFO stage (rv_fifo register) on every "
                     "feedback path")


# ---------------------------------------------------------------------------
# Reachability analyses (dead-mux, unreachable-node)
# ---------------------------------------------------------------------------

@register_rule(
    "dead-mux",
    description="node whose output can never reach a core input "
                "or boundary output (prune_dead_muxes convergence "
                "cross-check)",
    default_severity=Severity.WARNING)
def dead_mux(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    for g in ctx.graphs():
        live = ctx.reaches_sink(g)
        for n in _sorted_nodes(g.nodes()):
            if n.kind == NodeKind.PORT or n in live:
                continue
            if (ctx.faces_off_array(g, n)
                    and (n.fan_in or n.fan_out)):
                continue  # boundary stubs are the array's external pins
            if not n.fan_in and not n.fan_out:
                yield _diag(
                    "dead-mux", Severity.WARNING,
                    "fully isolated node survived to the final IR — "
                    "prune_dead_muxes did not run or did not converge",
                    g, node=n,
                    hint="run the prune_dead_muxes pass (it prunes "
                         "isolated and observer-free nodes to fixpoint)")
            else:
                yield _diag(
                    "dead-mux", Severity.WARNING,
                    "no path from this node to any core input or boundary "
                    "output: no configuration can make its output "
                    "observable",
                    g, node=n,
                    hint="dead hardware burns area; prune_dead_muxes "
                         "removes such chains to fixpoint")


@register_rule(
    "unreachable-node",
    description="node no core output or boundary input can ever "
                "drive",
    default_severity=Severity.WARNING)
def unreachable_node(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    for g in ctx.graphs():
        fed = ctx.reachable_forward(g)
        dead_live = ctx.reaches_sink(g)
        for n in _sorted_nodes(g.nodes()):
            if n.kind == NodeKind.PORT or n in fed:
                continue
            if ctx.faces_off_array(g, n):
                continue
            if not n.fan_in and not n.fan_out:
                continue  # dead-mux owns fully isolated nodes
            if n not in dead_live:
                continue  # already reported as dead-mux; don't double up
            yield _diag(
                "unreachable-node", Severity.WARNING,
                "no path from any core output or boundary input to "
                "this node: it only ever carries reset values",
                g, node=n,
                hint="check connect_core_ports / apply_sb_topology "
                     "coverage for this tile")


# ---------------------------------------------------------------------------
# Interface analyses (dangling-port)
# ---------------------------------------------------------------------------

@register_rule(
    "dangling-port",
    description="core port with no interconnect attachment, or a port "
                "width with no routing layer")
def dangling_port(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    widths = set(ctx.ic.widths)
    first = True
    for g in ctx.graphs():
        for (x, y) in sorted(g.tiles):
            tile = g.tiles[(x, y)]
            if tile.core is None:
                continue
            for p in tile.core.ports:
                if p.width not in widths:
                    if first:  # every layer materializes every port once
                        yield _diag(
                            "dangling-port", Severity.ERROR,
                            f"core port {p.name!r} is {p.width}b but the "
                            f"interconnect has no {p.width}b routing "
                            f"layer (layers: {sorted(widths)})",
                            g, tile=(x, y),
                            hint="add the layer via "
                                 "InterconnectSpec.extra_layers")
                    continue
                if p.width != g.width:
                    continue  # connected in its own layer, checked there
                node = tile.ports[p.name]
                if p.is_input and not node.fan_in:
                    yield _diag(
                        "dangling-port", Severity.ERROR,
                        f"core input port {p.name!r} has no incoming "
                        "connection-box track: the core can never be fed",
                        g, node=node,
                        hint="raise cb_track_fc / cb_sides (the CB "
                             "stride left this port unpopulated)")
                elif not p.is_input and not node.fan_out:
                    yield _diag(
                        "dangling-port", Severity.ERROR,
                        f"core output port {p.name!r} drives no "
                        "switch-box track: results can never leave the "
                        "core",
                        g, node=node,
                        hint="raise sb_track_fc / sb_sides")
        first = False


# ---------------------------------------------------------------------------
# Encoding analyses (fanin-overflow)
# ---------------------------------------------------------------------------

@register_rule(
    "fanin-overflow",
    description="mux fan-in, per-tile config population or tile "
                "coordinates the bitstream encoding cannot address")
def fanin_overflow(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """The bitstream word is ``x:8 | y:8 | feature:8 | reg:8`` with the
    select in a ``config_data_width``-bit data field (see
    ``repro.core.bitstream``). Three statically-checkable budgets fall
    out: a mux's select must fit the data field, each tile's per-feature
    configurable-node count must fit the 8-bit reg index, and tile
    coordinates must fit their 8-bit address fields. Overflow only
    surfaces today when ``BitstreamCodec`` raises at encode time — after
    PnR already spent its minutes."""
    max_select = 1 << ctx.ic.config_data_width
    for g in ctx.graphs():
        feat_counts: Dict[Tuple[int, int, str], int] = {}
        for n in _sorted_nodes(g.nodes()):
            fi = len(n.fan_in)
            if fi > max_select:
                yield _diag(
                    "fanin-overflow", Severity.ERROR,
                    f"mux fan-in {fi} needs select values up to {fi - 1} "
                    f"but the config data field is "
                    f"{ctx.ic.config_data_width} bit(s) "
                    f"(max {max_select - 1})",
                    g, node=n,
                    hint="widen config_data_width or depopulate the mux")
            if fi > 1 and n.kind != NodeKind.REGISTER:
                feature = (f"CB_{n.port_name}"
                           if n.kind == NodeKind.PORT else "SB")
                key = (n.x, n.y, feature)
                feat_counts[key] = feat_counts.get(key, 0) + 1
            if not (0 <= n.x < 256 and 0 <= n.y < 256):
                yield _diag(
                    "fanin-overflow", Severity.ERROR,
                    f"tile coordinate ({n.x},{n.y}) exceeds the 8-bit "
                    "bitstream address fields",
                    g, node=n,
                    hint="arrays beyond 256x256 need a wider address "
                         "encoding")
        for (x, y, feature), count in sorted(feat_counts.items()):
            if count > 256:
                yield _diag(
                    "fanin-overflow", Severity.ERROR,
                    f"{count} configurable {feature} muxes in one tile "
                    "exceed the 256-entry per-feature register index",
                    g, tile=(x, y),
                    hint="reduce num_tracks or split the feature space")


# ---------------------------------------------------------------------------
# Topology conformance (sb-topology-conformance)
# ---------------------------------------------------------------------------

def _has_spec(ctx: AnalysisContext) -> bool:
    return ctx.spec is not None


@register_rule(
    "sb-topology-conformance",
    description="switch-box internal edges deviate from the declared "
                "Wilton/Disjoint/Imran pattern",
    when=_has_spec)
def sb_topology_conformance(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """Recomputes the declared topology's (in, out) pairs per switch box
    and diffs them against the edges actually present — catching both a
    mis-applied pattern and later passes (or hand edits) that severed or
    added internal SB edges. The declared pattern comes from the same
    generator ``apply_sb_topology`` uses, so a legitimate topology change
    updates both sides at once."""
    from ..edsl import SB_TOPOLOGIES
    assert ctx.spec is not None
    topo = SB_TOPOLOGIES[ctx.spec.sb_type]
    expected_cache: Dict[int, Set[Tuple[int, int, int, int]]] = {}
    for g in ctx.graphs():
        for (x, y) in sorted(g.tiles):
            sb = g.tiles[(x, y)].switchbox
            nt = sb.num_tracks
            expected = expected_cache.get(nt)
            if expected is None:
                expected = {(t_from, int(s_from), t_to, int(s_to))
                            for (t_from, s_from, t_to, s_to) in topo(nt)}
                expected_cache[nt] = expected
            actual: Set[Tuple[int, int, int, int]] = set()
            for side in sb.sbs:
                for src in sb.sbs[side][IO.SB_IN]:
                    for dst in src.fan_out:
                        if (isinstance(dst, SwitchBoxNode)
                                and dst.io == IO.SB_OUT
                                and dst.x == x and dst.y == y):
                            actual.add((src.track, int(src.side),
                                        dst.track, int(dst.side)))
            if actual == expected:
                continue
            missing = len(expected - actual)
            extra = len(actual - expected)
            sample = next(iter(sorted(expected - actual)
                               or sorted(actual - expected)))
            yield _diag(
                "sb-topology-conformance", Severity.ERROR,
                f"switch box deviates from the declared "
                f"{ctx.spec.sb_type.value} pattern: {missing} edge(s) "
                f"missing, {extra} extra (e.g. track{sample[0]} "
                f"side{sample[1]} -> track{sample[2]} side{sample[3]})",
                g, tile=(x, y),
                hint="the IR was mutated after apply_sb_topology, or a "
                     "custom pipeline skipped/duplicated the pass")


# ---------------------------------------------------------------------------
# Routability bound (static-routability)
# ---------------------------------------------------------------------------

@register_rule(
    "static-routability",
    description="supply-vs-demand bound a router can never beat: "
                "under-fed core tiles or a starved array bisection",
    default_severity=Severity.WARNING)
def static_routability(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """Cheap necessary conditions for routing N-port applications,
    checked in milliseconds instead of a PathFinder run:

    * **tile operand supply** — an app net occupies one distinct signal
      into the tile per core input port it feeds; if the CB network
      delivers fewer distinct driving nodes than the core has input
      ports, no placement can ever use all of them (Hall's condition on
      the port-to-track bipartite graph, the cheap half);
    * **bisection supply** — any app communicating across the array's
      middle cut needs at least one crossing wire per direction, and an
      app feeding one max-fan-in core entirely from across the cut needs
      at least that core's input count. Zero capacity with cores on both
      sides is a hard error; sub-demand capacity is a warning."""
    for g in ctx.graphs():
        max_inputs = 0
        for (x, y) in sorted(g.tiles):
            tile = g.tiles[(x, y)]
            if tile.core is None:
                continue
            ports = [tile.ports[p.name] for p in tile.core.inputs()
                     if p.width == g.width]
            if not ports:
                continue
            max_inputs = max(max_inputs, len(ports))
            supply = {src for p in ports for src in p.fan_in}
            if len(supply) < len(ports):
                yield _diag(
                    "static-routability", Severity.WARNING,
                    f"core has {len(ports)} input port(s) but the CB "
                    f"network delivers only {len(supply)} distinct "
                    "signal(s): apps using every port can never route "
                    "here",
                    g, tile=(x, y),
                    hint="raise num_tracks, cb_track_fc or cb_sides")
        w, h = g.dims()
        for axis, extent in (("x", w), ("y", h)):
            if extent < 2:
                continue
            cut = extent // 2
            coord = (lambda n: n.x) if axis == "x" else (lambda n: n.y)
            lo = hi = 0
            cores_lo = cores_hi = False
            for tile in g.tiles.values():
                if tile.core is not None:
                    if (tile.x if axis == "x" else tile.y) < cut:
                        cores_lo = True
                    else:
                        cores_hi = True
            for u, v, _delay in g.edges():
                cu, cv = coord(u), coord(v)
                if cu < cut <= cv:
                    lo += 1
                elif cv < cut <= cu:
                    hi += 1
            if not (cores_lo and cores_hi):
                continue
            for direction, crossing in (("->", lo), ("<-", hi)):
                if crossing == 0:
                    yield _diag(
                        "static-routability", Severity.ERROR,
                        f"no routing capacity {direction} across the "
                        f"middle {axis}-cut: cores on the two halves "
                        "can never communicate",
                        g,
                        hint="the inter-tile wiring is severed; check "
                             "insert_pipeline_registers coverage")
                elif crossing < max_inputs:
                    yield _diag(
                        "static-routability", Severity.WARNING,
                        f"only {crossing} wire(s) {direction} across "
                        f"the middle {axis}-cut but a core needs up to "
                        f"{max_inputs} operands: apps feeding it from "
                        "across the cut can never route",
                        g,
                        hint="raise num_tracks")
