"""Routed-design analysis rules (``scope="routed"``).

PR 6's IR rules reject designs no configuration can save; these rules
audit one *configured* design point — ``(PackedGraph, RoutingResult,
RoutingResources, bitstream config)`` as produced by
:func:`repro.core.pnr.place_and_route` — in milliseconds, before any
emulation minutes are spent:

========================  =====================================================
rule id                   what it rejects / reports
========================  =====================================================
``rv-deadlock``           Dally-style cycle on the channel dependency
                          graph of the routed ready-valid fabric with no
                          FIFO break (error), or buffered only by finite
                          FIFO capacity (warning: deadlocks once full)
``throughput-bound``      static initiation-interval lower bound from the
                          slowest registered loop over its min-cut FIFO
                          capacity; warns when a loop caps throughput,
                          errors when the bound exceeds a measured
                          emulated II (the bound must be a lower bound)
``sta-slack``             per-net slack against a target clock
                          (``analyze(..., clock_ns=...)``): negative
                          slack errors, a near-critical cluster warns
``congestion-hotspot``    routing-node overuse (two nets on one node:
                          the bitstream can only select one) and
                          per-tile switch-node utilization >= 90%
``x-propagation``         uninitialized-register reachability on the
                          configured fabric: a configured driver chain
                          that never reaches live data, or a route tree
                          edge with no physical fan-in behind it
========================  =====================================================

All five gate on the routed artifacts being present on the
:class:`AnalysisContext` (``analyze(..., pnr=result)``), so ``scope=
"all"`` sweeps stay safe on un-routed designs. A clean routed report is
zero findings — success is silent, metrics travel separately via
:func:`routed_static_metrics` (what the DSE executor stamps into store
records for the ``min_throughput`` / ``min_slack_ns`` search
objectives).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

from ..graph import SwitchBoxNode
from .diagnostics import Diagnostic, Severity
from .framework import AnalysisContext, register_rule
from .flow import ChannelDepGraph, build_channel_graph
from .rules import _diag, _is_rv

#: reference clock for the stored ``min_slack_ns`` static metric (a
#: 100 MHz target): record-level slack must be comparable across design
#: points, so it is taken against one fixed period, not each point's own
#: critical path
DEFAULT_CLOCK_NS = 10.0

#: per-tile switch-node utilization at which congestion-hotspot warns
CONGESTION_WARN_UTILIZATION = 0.9

#: fraction of the target period under which a net counts near-critical
NEAR_CRITICAL_FRACTION = 0.1


def _has_routed(ctx: AnalysisContext) -> bool:
    return ctx.routing is not None and ctx.packed is not None


def _routed_rv(ctx: AnalysisContext) -> bool:
    return _has_routed(ctx) and _is_rv(ctx)


def _channel_graph(ctx: AnalysisContext) -> ChannelDepGraph:
    cdg = getattr(ctx, "_routed_cdg", None)
    if cdg is None:
        cdg = build_channel_graph(ctx.packed, ctx.routing)
        ctx._routed_cdg = cdg
    return cdg


def _cycle_sample(ctx: AnalysisContext, members: List[int]) -> str:
    nodes = ctx.routing.resources.nodes
    sample = ", ".join(repr(nodes[n]) for n in members[:3])
    return f"{sample}{', ...' if len(members) > 3 else ''}"


def _split_ctrl_delay(ctx: AnalysisContext) -> float:
    if ctx.spec is not None and ctx.spec.split_fifo_ctrl_delay:
        return float(ctx.spec.split_fifo_ctrl_delay)
    return 0.0


# ---------------------------------------------------------------------------
# rv-deadlock
# ---------------------------------------------------------------------------

@register_rule(
    "rv-deadlock",
    description="configured ready-valid channel-dependency cycle: "
                "unbuffered rings deadlock unconditionally, FIFO-"
                "buffered loops deadlock once their capacity fills",
    scope="routed",
    when=_routed_rv)
def rv_deadlock(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """Dally's condition on the *routed* fabric: the IR-scope
    ``rv-handshake`` rule rejects structures where a deadlock is
    wired-in; this rule checks the one configuration PnR actually chose.
    Route-tree edges are wait-for dependencies (a flit holds a node
    until downstream accepts), PEs couple their input channels to their
    output channels, and ``rv_fifo`` stages are the cycle-breakers."""
    cdg = _channel_graph(ctx)
    nodes = ctx.routing.resources.nodes
    for members in cdg.unbuffered_cycles():
        yield _diag(
            "rv-deadlock", Severity.ERROR,
            f"configured handshake cycle through {len(members)} routed "
            f"node(s) with no FIFO stage: {_cycle_sample(ctx, members)}"
            " — the ready chain closes combinationally and the fabric "
            "deadlocks",
            node=nodes[members[0]],
            hint="re-route the loop through an rv_fifo register stage "
                 "(raise reg_density) or break the feedback in the app")
    for members, stages, capacity in cdg.buffered_cycles():
        yield _diag(
            "rv-deadlock", Severity.WARNING,
            f"FIFO-constrained channel-dependency cycle: {stages} FIFO "
            f"stage(s) provide {capacity} slot(s) of credit on a "
            f"{len(members)}-node loop ({_cycle_sample(ctx, members)}); "
            f"the loop deadlocks once {capacity} token(s) are trapped "
            "in flight",
            node=nodes[members[0]],
            hint="bound in-flight tokens below the loop capacity, or "
                 "use full-mode FIFOs for more credit per stage")


# ---------------------------------------------------------------------------
# throughput-bound
# ---------------------------------------------------------------------------

@register_rule(
    "throughput-bound",
    description="static initiation-interval lower bound from the "
                "slowest registered loop over its min-cut FIFO "
                "capacity, cross-checked against emulated throughput",
    scope="routed",
    when=_has_routed,
    default_severity=Severity.WARNING)
def throughput_bound(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """An acyclic routed design streams one token per cycle (II = 1).
    A loop with S sequential stages and C total FIFO slots obeys
    II >= S / C; an unbuffered loop has no steady state at all. When
    the caller measured an emulated II (``timing["emulated_ii"]``),
    violating ``static <= emulated`` is an error: the static bound
    must be a true lower bound."""
    ii = static_ii_bound(ctx.packed, ctx.routing)
    if ii == float("inf"):
        yield _diag(
            "throughput-bound", Severity.ERROR,
            "no steady-state throughput: a configured loop has no FIFO "
            "credit (see rv-deadlock) — static II bound is infinite",
            hint="break or buffer the loop before emulating")
    elif ii > 1.0:
        yield _diag(
            "throughput-bound", Severity.WARNING,
            f"registered loop bounds the initiation interval: "
            f"II >= {ii:.2f} (slowest loop stages / min-cut FIFO "
            "capacity) — the app cannot accept one token per cycle",
            hint="add FIFO capacity on the loop (full-mode FIFOs or "
                 "more register stages) to lower the bound")
    emulated = (ctx.timing or {}).get("emulated_ii")
    if emulated is not None and ii != float("inf") \
            and ii > float(emulated) + 1e-9:
        yield _diag(
            "throughput-bound", Severity.ERROR,
            f"static II bound {ii:.2f} exceeds the emulated II "
            f"{float(emulated):.2f}: the 'lower bound' is not one — "
            "the channel-dependency model disagrees with the fabric",
            hint="file the routed design as an analyzer regression")


def static_ii_bound(packed, routing) -> float:
    """Static initiation-interval lower bound of one routed app: 1.0
    for acyclic channel graphs and non-handshake (static) fabrics —
    both stream fully pipelined — else the slowest-loop bound from
    :meth:`ChannelDepGraph.static_ii`."""
    ic = routing.resources.ic
    if not ic.params.get("rv_fifo_mode"):
        return 1.0
    return build_channel_graph(packed, routing).static_ii()


# ---------------------------------------------------------------------------
# sta-slack
# ---------------------------------------------------------------------------

@register_rule(
    "sta-slack",
    description="per-net slack against the target clock "
                "(analyze(..., clock_ns=...)): negative slack errors, "
                "near-critical clusters warn",
    scope="routed",
    when=_has_routed)
def sta_slack(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """Full per-net slack histogram extending ``sta_critical_path``:
    every routed net sink gets ``slack = clock_ns - arrival``. Without
    a target clock there is no period to violate — the rule stays
    silent and the histogram remains available via
    :func:`repro.core.pnr.timing.sta_net_slacks`."""
    if ctx.clock_ns is None:
        return
    from ..pnr.timing import sta_net_slacks
    table = sta_net_slacks(ctx.packed, ctx.routing, ctx.placement or {},
                           clock_ns=ctx.clock_ns,
                           split_fifo_ctrl_delay=_split_ctrl_delay(ctx))
    period = table["period_ns"]
    near = []
    for row in table["nets"]:
        if row["slack_ns"] < 0:
            yield _diag(
                "sta-slack", Severity.ERROR,
                f"net {row['net']!r} -> {row['sink']!r} arrives at "
                f"{row['arrival_ns']:.3f} ns against a {period:.3f} ns "
                f"clock: slack {row['slack_ns']:.3f} ns",
                hint="lower the clock target, re-route with a higher "
                     "alpha (timing-driven), or pipeline the path")
        elif row["slack_ns"] < NEAR_CRITICAL_FRACTION * period:
            near.append(row)
    if near:
        worst = near[0]
        yield _diag(
            "sta-slack", Severity.WARNING,
            f"{len(near)} net(s) within "
            f"{NEAR_CRITICAL_FRACTION:.0%} of the {period:.3f} ns "
            f"clock (worst: {worst['net']!r} at "
            f"{worst['arrival_ns']:.3f} ns, slack "
            f"{worst['slack_ns']:.3f} ns): little margin for wire "
            "variation",
            hint="inspect sta_net_slacks() for the near-critical "
                 "cluster before committing the clock")


# ---------------------------------------------------------------------------
# congestion-hotspot
# ---------------------------------------------------------------------------

@register_rule(
    "congestion-hotspot",
    description="routing-node overuse (illegal: one select per mux) "
                "and per-tile switch-node utilization margins",
    scope="routed",
    when=_has_routed,
    default_severity=Severity.WARNING)
def congestion_hotspot(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """PathFinder legality audited after the fact, plus the congestion
    margin PathFinder does not report: a mux carries exactly one select
    value, so two nets on one node is a hard error, and a tile whose
    switch nodes are nearly all occupied has no slack for the next app
    or a rip-up — the per-tile track-utilization hotspot map."""
    res = ctx.routing.resources
    usage: Dict[int, int] = {}
    for net in ctx.routing.nets:
        for nid in net.nodes_used():
            usage[nid] = usage.get(nid, 0) + 1
    for nid in sorted(n for n, c in usage.items() if c > 1):
        yield _diag(
            "congestion-hotspot", Severity.ERROR,
            f"routing node used by {usage[nid]} nets but a mux select "
            "can express only one driver: the routing is illegal",
            node=res.nodes[nid],
            hint="the router left overuse behind — raise route_iters")
    total: Dict[Tuple[int, int], int] = {}
    used: Dict[Tuple[int, int], int] = {}
    for nid, node in enumerate(res.nodes):
        if not isinstance(node, SwitchBoxNode):
            continue
        key = (node.x, node.y)
        total[key] = total.get(key, 0) + 1
        if nid in usage:
            used[key] = used.get(key, 0) + 1
    for key in sorted(used):
        u, t = used[key], total[key]
        if t and u / t >= CONGESTION_WARN_UTILIZATION:
            yield _diag(
                "congestion-hotspot", Severity.WARNING,
                f"tile switch-node utilization {u}/{t} "
                f"({u / t:.0%}): only {t - u} node(s) of margin "
                "before the tile saturates",
                tile=key,
                hint="raise num_tracks or spread the placement "
                     "(higher sa_steps)")


# ---------------------------------------------------------------------------
# x-propagation
# ---------------------------------------------------------------------------

@register_rule(
    "x-propagation",
    description="uninitialized-register reachability on the configured "
                "fabric: a configured driver chain that never reaches "
                "live data",
    scope="routed",
    when=_has_routed)
def x_propagation(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    """The bitstream configures one driver per used node (the route-tree
    parent). A sink whose configured driver chain never terminates at
    the net's source — an orphaned or cyclic chain, or a tree edge with
    no physical fan-in behind it — observes whatever an uninitialized
    register or an undriven mux default happens to hold: X in silicon,
    reset garbage in emulation. Well-formed route trees can never
    trip this; it guards decoded/hand-edited bitstreams and router
    regressions."""
    res = ctx.routing.resources
    for net in ctx.routing.nets:
        for child in sorted(net.tree):
            parent = net.tree[child]
            if res.nodes[parent] not in res.nodes[child].fan_in:
                yield _diag(
                    "x-propagation", Severity.ERROR,
                    f"net {net.name!r}: configured driver "
                    f"{res.nodes[parent]!r} is not a physical fan-in of "
                    f"{res.nodes[child]!r} — no bitstream can express "
                    "this route",
                    node=res.nodes[child],
                    hint="the route tree was corrupted after routing "
                         "(or decoded from a foreign bitstream)")
        limit = len(net.tree) + 1
        for sink in sorted(net.sinks):
            node, steps = sink, 0
            while node != net.src and node in net.tree and steps < limit:
                node = net.tree[node]
                steps += 1
            if node != net.src:
                yield _diag(
                    "x-propagation", Severity.ERROR,
                    f"net {net.name!r}: sink {res.nodes[sink]!r}'s "
                    "configured driver chain never reaches the net "
                    "source — it reads uninitialized register / "
                    "undriven mux state",
                    node=res.nodes[sink],
                    hint="re-route the net; the tree is orphaned or "
                         "cyclic at this sink")


# ---------------------------------------------------------------------------
# static metrics for the store / search wiring
# ---------------------------------------------------------------------------

def routed_static_metrics(packed, routing, placement,
                          clock_ns: float = DEFAULT_CLOCK_NS,
                          core_delay: float = 0.8,
                          split_fifo_ctrl_delay: float = 0.0
                          ) -> Dict[str, float]:
    """The per-app static metrics the DSE executor stamps into store
    records (and :mod:`repro.core.search.pareto` consumes with no extra
    PnR): ``static_ii`` (initiation-interval lower bound),
    ``throughput`` (its reciprocal, tokens/cycle; 0.0 when deadlocked)
    and ``min_slack_ns`` (worst per-net slack against the fixed
    ``clock_ns`` reference period, default {DEFAULT_CLOCK_NS} ns)."""
    from ..pnr.timing import sta_net_slacks
    ii = static_ii_bound(packed, routing)
    table = sta_net_slacks(packed, routing, placement or {},
                           clock_ns=clock_ns, core_delay=core_delay,
                           split_fifo_ctrl_delay=split_fifo_ctrl_delay)
    return {"static_ii": ii,
            "throughput": 0.0 if ii == float("inf") else 1.0 / ii,
            "min_slack_ns": float(table["min_slack_ns"])}
