"""Post-lowering analyses: ``verify.py`` folded into the rule framework.

The paper (§3.3) verifies generated hardware two ways — connectivity
against the IR, and an exhaustive configuration sweep. Those checks lived
in ``repro.core.verify`` as bare assert-raising functions, orphaned from
the compile front door. Here they are registered as ``scope="lowered"``
rules so the same driver, report model, CLI and CI plumbing cover them:

* ``structural-equivalence`` — the lowered fabric's gather tables must
  reproduce the IR fan-in lists exactly (order included — select-bit
  semantics);
* ``config-sweep`` — every (mux, input) connection is driven and observed
  once through the batched fabric.

Both need a compiled :class:`FabricModule` (and the sweep needs device
time), so they are *not* part of the default ``scope="ir"`` set — reach
them via ``CompiledFabric.verify()``, ``analyze(..., scope="lowered",
fabric=...)`` or ``python -m canal.lint --lowered``. The underlying
functions stay importable from ``repro.core.verify`` unchanged.
"""
from __future__ import annotations

from typing import Iterator

from .diagnostics import Diagnostic, Severity
from .framework import AnalysisContext, register_rule


def _has_fabric(ctx: AnalysisContext) -> bool:
    return ctx.fabric is not None


@register_rule(
    "structural-equivalence",
    description="lowered fabric gather tables reproduce the IR fan-in "
                "lists exactly (paper §3.3 RTL-vs-IR check)",
    scope="lowered", when=_has_fabric)
def structural_equivalence(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    from ..verify import verify_structural
    try:
        verify_structural(ctx.ic, ctx.fabric)
    except AssertionError as e:
        yield Diagnostic(
            rule="structural-equivalence", severity=Severity.ERROR,
            message=f"lowered connectivity deviates from the IR: {e}",
            hint="the lowering or a post-freeze IR mutation is buggy; "
                 "re-lower from the frozen IR")


@register_rule(
    "config-sweep",
    description="every (mux, input) connection drives and observes "
                "correctly through the lowered fabric (paper §3.3 "
                "exhaustive configuration test)",
    scope="lowered", when=_has_fabric)
def config_sweep_rule(ctx: AnalysisContext) -> Iterator[Diagnostic]:
    from ..verify import config_sweep
    try:
        checked = config_sweep(ctx.fabric)
    except AssertionError as e:
        yield Diagnostic(
            rule="config-sweep", severity=Severity.ERROR,
            message=f"configuration sweep failed: {e}",
            hint="a mux select routes the wrong source; check the "
                 "config-slot assignment in lowering")
    else:
        yield Diagnostic(
            rule="config-sweep", severity=Severity.INFO,
            message=f"{checked} mux connection(s) verified")
