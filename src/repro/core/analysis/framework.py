"""The analysis-pass framework: ``IRPass``/``PassManager``'s read-only twin.

An :class:`AnalysisPass` is a named, registered *rule*: a pure function
``(AnalysisContext) -> Iterable[Diagnostic]`` over the frozen IR. Rules
never mutate the graph — they observe it and report. The registry mirrors
the compiler-pass registry so tooling can enumerate, subset and document
rules the same way it does passes; :func:`analyze` is the single driver
(``canal.analyze``), used by the compile front door, the DSE pre-screen
and the ``python -m canal.lint`` CLI.

The :class:`AnalysisContext` carries memoized whole-graph facts —
source/sink sets, forward/backward reachability, array-boundary
exemptions — so rules that share them (``dead-mux``,
``unreachable-node``, ``static-routability``) pay for one traversal, not
three.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

from ..graph import IO, Interconnect, InterconnectGraph, Node, SwitchBoxNode
from ..spec import InterconnectSpec
from .diagnostics import AnalysisReport, Diagnostic, Severity

RuleFn = Callable[["AnalysisContext"], Iterable[Diagnostic]]


@dataclass
class AnalysisContext:
    """Read-only state threaded through the rules: the IR, the spec when
    known (hand-built IR legitimately has none — spec-dependent rules
    gate themselves off), and memoized graph facts."""

    ic: Interconnect
    spec: Optional[InterconnectSpec] = None
    #: lowered FabricModule when the caller has one — enables the
    #: scope="lowered" rules (structural equivalence, config sweep)
    fabric: Optional[object] = None
    #: routed artifacts when the caller has a PnR result — enable the
    #: scope="routed" rules (rv-deadlock, throughput-bound, sta-slack,
    #: congestion-hotspot, x-propagation). ``packed`` is the
    #: :class:`PackedGraph`, ``routing`` the :class:`RoutingResult`
    #: (which carries its :class:`RoutingResources`), ``placement`` the
    #: instance -> (x, y) map and ``timing`` the STA summary dict.
    packed: Optional[object] = None
    routing: Optional[object] = None
    placement: Optional[Dict] = None
    timing: Optional[Dict] = None
    #: target clock period for slack checks; None = report-only (no
    #: period to violate, so ``sta-slack`` stays silent)
    clock_ns: Optional[float] = None
    _sources: Dict[int, Set[Node]] = field(default_factory=dict)
    _sinks: Dict[int, Set[Node]] = field(default_factory=dict)
    _fwd: Dict[int, Set[Node]] = field(default_factory=dict)
    _bwd: Dict[int, Set[Node]] = field(default_factory=dict)

    def graphs(self) -> List[InterconnectGraph]:
        return [self.ic.graphs[w] for w in self.ic.widths]

    # ----------------------------------------------------------- boundary
    @staticmethod
    def faces_off_array(g: InterconnectGraph, node: Node) -> bool:
        """True for switch-box nodes on a side with no neighbouring tile:
        the array's external interface (chip IO in a real CGRA). They
        legitimately have no on-array driver (SB_IN) or consumer
        (SB_OUT), so reachability rules treat them as sources/sinks
        rather than defects."""
        if not isinstance(node, SwitchBoxNode):
            return False
        dx, dy = node.side.delta()
        return g.get_tile(node.x + dx, node.y + dy) is None

    # -------------------------------------------------------- sources/sinks
    def sources(self, g: InterconnectGraph) -> Set[Node]:
        """Nodes that inject data into the routing graph: core *output*
        ports of this layer's width and array-boundary SB inputs.
        Registers are deliberately NOT sources — a register chain fed by
        nothing only ever replays reset values; reachability traverses
        *through* registers instead."""
        key = id(g)
        out = self._sources.get(key)
        if out is None:
            out = set()
            for tile in g.tiles.values():
                if tile.core is not None:
                    for p in tile.core.outputs():
                        if p.width == g.width:
                            out.add(tile.ports[p.name])
            for n in g.nodes():
                if (isinstance(n, SwitchBoxNode) and n.io == IO.SB_IN
                        and self.faces_off_array(g, n)):
                    out.add(n)
            self._sources[key] = out
        return out

    def sinks(self, g: InterconnectGraph) -> Set[Node]:
        """Nodes whose value is externally observable: core *input*
        ports of this layer's width and array-boundary SB outputs.
        Registers are deliberately NOT sinks — a register nobody reads
        is dead state; reachability traverses *through* registers
        instead."""
        key = id(g)
        out = self._sinks.get(key)
        if out is None:
            out = set()
            for tile in g.tiles.values():
                if tile.core is not None:
                    for p in tile.core.inputs():
                        if p.width == g.width:
                            out.add(tile.ports[p.name])
            for n in g.nodes():
                if (isinstance(n, SwitchBoxNode) and n.io == IO.SB_OUT
                        and self.faces_off_array(g, n)):
                    out.add(n)
            self._sinks[key] = out
        return out

    # --------------------------------------------------------- reachability
    def reachable_forward(self, g: InterconnectGraph) -> Set[Node]:
        """Nodes reachable from any source along fan-out edges."""
        key = id(g)
        out = self._fwd.get(key)
        if out is None:
            out = self._bfs(self.sources(g), lambda n: n.fan_out)
            self._fwd[key] = out
        return out

    def reaches_sink(self, g: InterconnectGraph) -> Set[Node]:
        """Nodes from which some sink is reachable (backward BFS)."""
        key = id(g)
        out = self._bwd.get(key)
        if out is None:
            out = self._bfs(self.sinks(g), lambda n: n.fan_in)
            self._bwd[key] = out
        return out

    @staticmethod
    def _bfs(seeds: Set[Node],
             nbrs: Callable[[Node], Sequence[Node]]) -> Set[Node]:
        seen = set(seeds)
        frontier = list(seeds)
        while frontier:
            n = frontier.pop()
            for m in nbrs(n):
                if m not in seen:
                    seen.add(m)
                    frontier.append(m)
        return seen


@dataclass(frozen=True)
class AnalysisPass:
    """A registered rule. ``name`` is the stable diagnostic id;
    ``when`` gates spec- or mode-dependent rules (e.g. ``rv-handshake``
    only applies to ready-valid designs); ``scope`` separates cheap IR
    rules (``"ir"``, run by default everywhere) from post-lowering
    verification (``"lowered"``: structural equivalence and the config
    sweep, which need a compiled :class:`FabricModule` and device time —
    reachable via ``CompiledFabric.verify()`` and ``canal.lint
    --lowered``)."""

    name: str
    run: RuleFn
    description: str = ""
    scope: str = "ir"
    when: Callable[[AnalysisContext], bool] = lambda ctx: True
    #: the severity this rule's findings carry when it flags a defect —
    #: documentation for ``--list-rules`` and input to the rule-set
    #: version stamp; the rule body remains free to emit lower
    #: severities for secondary findings
    default_severity: Severity = Severity.ERROR


#: the rule registry, in registration order (report order follows it)
RULES: Dict[str, AnalysisPass] = {}


def register_rule(name: str, description: str = "", scope: str = "ir",
                  when: Callable[[AnalysisContext], bool] = lambda ctx: True,
                  default_severity: "str | Severity" = Severity.ERROR
                  ) -> Callable[[RuleFn], RuleFn]:
    """Decorator registering a rule function under a stable id — the
    analysis mirror of adding an :class:`IRPass` to ``DEFAULT_PASSES``.
    Re-registering an id replaces the rule (supports reload/monkeypatch
    in tests) but third-party ids must not collide with built-ins."""

    def deco(fn: RuleFn) -> RuleFn:
        RULES[name] = AnalysisPass(
            name=name, run=fn, description=description, scope=scope,
            when=when,
            default_severity=Severity.from_str(default_severity))
        return fn
    return deco


def rule_table(scope: Optional[str] = None) -> List[AnalysisPass]:
    """Registered rules (optionally one scope), registration-ordered."""
    return [r for r in RULES.values()
            if scope is None or r.scope == scope]


def rule_set_version(scope: Optional[str] = None) -> str:
    """Deterministic short hash of the registered rule set (ids, scopes,
    descriptions, default severities). Stamped onto persisted analysis
    verdicts (:class:`repro.core.dse.SweepExecutor`) so a record written
    under an older rule set re-analyzes instead of serving a stale
    verdict — adding, removing or re-documenting a rule changes the
    stamp."""
    h = hashlib.sha256()
    for r in sorted(rule_table(scope), key=lambda r: r.name):
        h.update(f"{r.name}\x00{r.scope}\x00{r.description}\x00"
                 f"{int(r.default_severity)}\n".encode())
    return h.hexdigest()[:12]


def _resolve_spec(ic: Interconnect,
                  spec: Optional[InterconnectSpec]) -> Optional[
                      InterconnectSpec]:
    if spec is not None:
        return spec
    return getattr(ic, "spec", None)


def analyze(ic: Interconnect,
            spec: Optional[InterconnectSpec] = None,
            rules: Optional[Sequence[str]] = None,
            scope: str = "ir",
            severities: Optional[Dict[str, "str | Severity"]] = None,
            fail_on: Optional["str | Severity"] = None,
            fabric: Optional[object] = None,
            pnr: Optional[object] = None,
            packed: Optional[object] = None,
            routing: Optional[object] = None,
            placement: Optional[Dict] = None,
            timing: Optional[Dict] = None,
            clock_ns: Optional[float] = None) -> AnalysisReport:
    """Run the registered analysis rules over an interconnect IR.

    ``spec`` enables spec-dependent rules when the IR was not produced
    by the pass pipeline (pipeline IR carries its spec already);
    ``rules`` selects a subset by id (unknown ids raise — a misspelled
    CI config must fail loudly, not silently skip the check);
    ``severities`` remaps per-rule severity (project policy, e.g. demote
    ``dead-mux`` to info, or ``"off"`` to suppress a rule entirely;
    unknown rule ids raise); ``fail_on`` raises :class:`AnalysisError`
    when any finding reaches that severity. ``pnr`` (a successful
    :class:`repro.core.pnr.PnRResult`) — or the individual ``packed`` /
    ``routing`` / ``placement`` / ``timing`` artifacts — enables the
    ``scope="routed"`` rules; ``clock_ns`` sets the target period the
    slack rules check against. This is the one driver behind
    ``canal.compile(analyze=...)``, the DSE pre-screen and the lint CLI.
    """
    if not isinstance(ic, Interconnect) and hasattr(ic, "interconnect"):
        spec = spec if spec is not None else getattr(ic, "spec", None)
        ic = ic.interconnect                     # a CompiledFabric
    if pnr is not None:
        packed = packed if packed is not None else \
            getattr(pnr, "packed", None)
        routing = routing if routing is not None else \
            getattr(pnr, "routing", None)
        placement = placement if placement is not None else \
            getattr(pnr, "placement", None)
        timing = timing if timing is not None else \
            getattr(pnr, "timing", None)
    ctx = AnalysisContext(ic=ic, spec=_resolve_spec(ic, spec),
                          fabric=fabric, packed=packed, routing=routing,
                          placement=placement, timing=timing,
                          clock_ns=clock_ns)
    if rules is None:
        selected = rule_table(None if scope == "all" else scope)
    else:
        unknown = sorted(set(rules) - set(RULES))
        if unknown:
            raise ValueError(f"unknown analysis rules {unknown}; "
                             f"registered: {sorted(RULES)}")
        selected = [RULES[r] for r in rules]
    unknown_sev = sorted(set(severities or {}) - set(RULES))
    if unknown_sev:
        raise ValueError(f"unknown analysis rules in severities "
                         f"{unknown_sev}; registered: {sorted(RULES)}")
    suppressed = {k for k, v in (severities or {}).items()
                  if isinstance(v, str) and v.lower() == "off"}
    overrides = {k: Severity.from_str(v)
                 for k, v in (severities or {}).items()
                 if k not in suppressed}
    # suppressed rules did not run: leaving them out of rules_run keeps
    # "clean" distinguishable from "not checked"
    report = AnalysisReport(rules_run=tuple(
        r.name for r in selected if r.name not in suppressed))
    for r in selected:
        if r.name in suppressed or not r.when(ctx):
            continue
        found = list(r.run(ctx))
        sev = overrides.get(r.name)
        if sev is not None:
            found = [replace(d, severity=sev) for d in found]
        report.extend(found)
    if fail_on is not None:
        report.raise_if(fail_on)
    return report
