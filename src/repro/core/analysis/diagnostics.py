"""The diagnostics model of ``canal.analyze``.

A :class:`Diagnostic` is one finding of one analysis rule over the
interconnect IR: a stable rule id, a severity, a location (routing layer,
tile, node) and a human-readable message plus an actionable fix hint.
:class:`AnalysisReport` is the ordered collection the analyzer returns —
it renders as lint-style text, serializes to JSON for CI artifacts, and
carries the severity arithmetic (``ok()``, ``raise_if()``) the compile
front door and the DSE pre-screen gate on.
"""
from __future__ import annotations

import enum
import json
from dataclasses import asdict, dataclass, field, replace
from typing import Dict, Iterable, Iterator, List, Optional, Tuple


class Severity(enum.IntEnum):
    """Ordered: comparisons like ``d.severity >= Severity.WARNING`` give
    threshold filtering for free."""

    INFO = 0
    WARNING = 1
    ERROR = 2

    @classmethod
    def from_str(cls, s: "str | Severity") -> "Severity":
        if isinstance(s, Severity):
            return s
        try:
            return _SEVERITY_ALIASES[s.lower()]
        except (KeyError, AttributeError):
            raise ValueError(
                f"unknown severity {s!r}; use one of "
                f"{sorted(set(_SEVERITY_ALIASES))}") from None


_SEVERITY_ALIASES: Dict[str, Severity] = {
    "info": Severity.INFO,
    "warn": Severity.WARNING, "warning": Severity.WARNING,
    "error": Severity.ERROR,
}


@dataclass(frozen=True)
class Diagnostic:
    """One finding: ``rule`` is the stable lint id (kebab-case, the thing
    CI configs and suppressions key on), location is as precise as the
    rule can make it (``width`` = routing layer bit width, ``tile`` =
    (x, y), ``node`` = ``node_key()`` repr), and ``pass_name`` — filled
    by the per-pass pipeline mode — names the first IR pass after which
    the finding appears."""

    rule: str
    severity: Severity
    message: str
    width: Optional[int] = None          # routing layer (graph bit width)
    tile: Optional[Tuple[int, int]] = None
    node: Optional[str] = None           # node_key() repr
    hint: Optional[str] = None
    pass_name: Optional[str] = None

    def location(self) -> str:
        parts = []
        if self.width is not None:
            parts.append(f"layer{self.width}b")
        if self.tile is not None:
            parts.append(f"tile({self.tile[0]},{self.tile[1]})")
        if self.node is not None:
            parts.append(self.node)
        return ":".join(parts) if parts else "<design>"

    def key(self) -> Tuple:
        """Identity used to match findings across pipeline snapshots (the
        per-pass attribution) and to dedupe: the rule plus the location —
        *not* the message, which may carry run-varying counts."""
        return (self.rule, self.width, self.tile, self.node)

    def with_pass(self, pass_name: str) -> "Diagnostic":
        return replace(self, pass_name=pass_name)

    def to_dict(self) -> Dict:
        d = asdict(self)
        d["severity"] = self.severity.name.lower()
        if self.tile is not None:
            d["tile"] = list(self.tile)
        return d

    def render(self) -> str:
        origin = f" [{self.pass_name}]" if self.pass_name else ""
        hint = f" (hint: {self.hint})" if self.hint else ""
        return (f"{self.severity.name.lower()}: {self.rule} @ "
                f"{self.location()}: {self.message}{origin}{hint}")

    def __str__(self) -> str:
        return self.render()


class AnalysisError(RuntimeError):
    """Raised by ``analyze="error"`` compiles: the report rode along so
    callers can inspect every finding, not just the first."""

    def __init__(self, report: "AnalysisReport", level: Severity):
        self.report = report
        self.level = level
        bad = report.at_least(level)
        lines = "\n".join(f"  {d.render()}" for d in bad[:8])
        more = f"\n  ... and {len(bad) - 8} more" if len(bad) > 8 else ""
        super().__init__(
            f"static analysis found {len(bad)} finding(s) at severity "
            f">= {level.name.lower()}:\n{lines}{more}")


@dataclass
class AnalysisReport:
    """The analyzer's output: diagnostics in rule-registration order,
    plus the set of rule ids that actually ran (so "clean" is
    distinguishable from "not checked")."""

    diagnostics: List[Diagnostic] = field(default_factory=list)
    rules_run: Tuple[str, ...] = ()

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    def __len__(self) -> int:
        return len(self.diagnostics)

    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    # ------------------------------------------------------------ filtering
    def at_least(self, level: "str | Severity") -> List[Diagnostic]:
        level = Severity.from_str(level)
        return [d for d in self.diagnostics if d.severity >= level]

    @property
    def errors(self) -> List[Diagnostic]:
        return self.at_least(Severity.ERROR)

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity == Severity.WARNING]

    def by_rule(self, rule: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.rule == rule]

    def rule_ids(self) -> List[str]:
        seen: Dict[str, None] = {}
        for d in self.diagnostics:
            seen.setdefault(d.rule, None)
        return list(seen)

    # -------------------------------------------------------------- gating
    def ok(self, fail_on: "str | Severity" = Severity.ERROR) -> bool:
        """True when no finding reaches ``fail_on`` — the CI exit-code
        predicate and the DSE pre-screen verdict."""
        return not self.at_least(fail_on)

    def raise_if(self, level: "str | Severity" = Severity.ERROR) -> None:
        level = Severity.from_str(level)
        if not self.ok(level):
            raise AnalysisError(self, level)

    # ------------------------------------------------------- serialization
    def counts(self) -> Dict[str, int]:
        out = {"error": 0, "warning": 0, "info": 0}
        for d in self.diagnostics:
            out[d.severity.name.lower()] += 1
        return out

    def to_dict(self, max_diagnostics: Optional[int] = None) -> Dict:
        diags = self.diagnostics
        truncated = 0
        if max_diagnostics is not None and len(diags) > max_diagnostics:
            # keep the most severe findings when truncating for storage
            diags = sorted(diags, key=lambda d: -int(d.severity))
            truncated = len(diags) - max_diagnostics
            diags = diags[:max_diagnostics]
        out = {"clean": self.ok(), "counts": self.counts(),
               "rules_run": list(self.rules_run),
               "diagnostics": [d.to_dict() for d in diags]}
        if truncated:
            out["truncated"] = truncated
        return out

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def render(self) -> str:
        c = self.counts()
        lines = [d.render() for d in self.diagnostics]
        lines.append(f"{c['error']} error(s), {c['warning']} warning(s), "
                     f"{c['info']} info in {len(self.rules_run)} rule(s)")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        c = self.counts()
        return (f"AnalysisReport(errors={c['error']}, "
                f"warnings={c['warning']}, info={c['info']})")
