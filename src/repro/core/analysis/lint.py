"""``python -m canal.lint`` — the static analyzer as a CI-friendly CLI.

Lints interconnect design points — spec JSON files and/or importable
Python design points — through the same :func:`repro.core.analysis.analyze`
driver the compile front door and the DSE pre-screen use.

Targets:

* positional arguments: paths to ``InterconnectSpec`` JSON files
  (``spec.to_json()`` output);
* ``--config module:attr``: an importable design point — an
  ``InterconnectSpec``, a ``CompiledFabric``, an ``Interconnect``, a
  spec dict, or a zero-argument callable returning any of those
  (e.g. ``--config repro.configs.cgra_amber:smoke``).

Output: lint-style text (default) or ``--format json`` (one document
covering all targets, the CI artifact shape); ``--output`` writes the
report to a file *in addition to* the terminal summary.

Exit codes (CI contract): ``0`` every target clean at the ``--fail-on``
severity (default ``error``); ``1`` at least one finding reached it;
``2`` usage or load error (unreadable file, unknown rule id, bad
import) — distinct from ``1`` so a misconfigured CI job cannot pass as
"findings found" or vice versa.
"""
from __future__ import annotations

import argparse
import importlib
import json
import sys
from dataclasses import replace
from typing import List, Optional, Tuple

from .diagnostics import AnalysisReport, Diagnostic, Severity
from .framework import RULES, analyze, rule_set_version, rule_table

USAGE_ERROR = 2


class LintError(Exception):
    """A target could not be loaded/analyzed (exit code 2)."""


def _load_config(ref: str):
    """Resolve ``module:attr`` (or ``module.attr``) to a design point."""
    mod_name, sep, attr = ref.partition(":")
    if not sep:
        mod_name, _, attr = ref.rpartition(".")
        if not mod_name:
            raise LintError(f"--config {ref!r}: expected module:attr")
    try:
        mod = importlib.import_module(mod_name)
    except ImportError as e:
        raise LintError(f"--config {ref!r}: cannot import "
                        f"{mod_name!r}: {e}") from e
    try:
        obj = getattr(mod, attr)
    except AttributeError:
        raise LintError(
            f"--config {ref!r}: module {mod_name!r} has no "
            f"attribute {attr!r}") from None
    if callable(obj) and not hasattr(obj, "graphs") \
            and not hasattr(obj, "interconnect"):
        obj = obj()
    return obj


def _to_point(obj, origin: str) -> Tuple[object, Optional[object]]:
    """Normalize a loaded design point to ``(ic, spec)``."""
    from ..graph import Interconnect
    from ..spec import InterconnectSpec

    if isinstance(obj, dict):
        obj = InterconnectSpec.from_dict(obj)
    if isinstance(obj, InterconnectSpec):
        from ..passes import PassManager
        return PassManager().run(obj), obj
    if hasattr(obj, "interconnect") and hasattr(obj, "spec"):
        return obj.interconnect, obj.spec         # CompiledFabric
    if isinstance(obj, Interconnect):
        return obj, getattr(obj, "spec", None)
    raise LintError(
        f"{origin}: cannot lint a {type(obj).__name__} — expected an "
        "InterconnectSpec, spec dict, Interconnect or CompiledFabric")


def _load_spec_file(path: str):
    from ..spec import InterconnectSpec
    try:
        with open(path) as fh:
            text = fh.read()
    except OSError as e:
        raise LintError(f"{path}: {e}") from e
    try:
        return InterconnectSpec.from_json(text)
    except (ValueError, TypeError, KeyError) as e:
        raise LintError(f"{path}: not a spec JSON: {e}") from e


def _list_rules() -> str:
    lines = [f"{'RULE':26s} {'SCOPE':8s} {'SEVERITY':8s} DESCRIPTION"]
    for r in rule_table():
        lines.append(f"{r.name:26s} {r.scope:8s} "
                     f"{r.default_severity.name.lower():8s} "
                     f"{r.description}")
    return "\n".join(lines)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m canal.lint",
        description="Static analysis over interconnect design points.")
    ap.add_argument("specs", nargs="*", metavar="SPEC.json",
                    help="InterconnectSpec JSON files to lint")
    ap.add_argument("--config", action="append", default=[],
                    metavar="MODULE:ATTR",
                    help="importable design point (spec, CompiledFabric, "
                         "Interconnect, or zero-arg factory); repeatable")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all IR rules)")
    ap.add_argument("--fail-on", default="error",
                    choices=["info", "warn", "warning", "error"],
                    help="severity that sets exit code 1 — one of "
                         "'info', 'warn'/'warning', 'error' "
                         "(default: error)")
    ap.add_argument("--format", default="text",
                    choices=["text", "json"], help="report format")
    ap.add_argument("--output", "-o", default=None, metavar="FILE",
                    help="also write the report (always JSON) to FILE")
    ap.add_argument("--lowered", action="store_true",
                    help="additionally run the post-lowering verification "
                         "rules (compiles the fabric; costs device time)")
    ap.add_argument("--routed", action="store_true",
                    help="additionally run the routed-scope rules: each "
                         "design point is placed-and-routed on the --app "
                         "benchmark(s) (costs PnR time); with --store, "
                         "also audits the persisted routed verdicts")
    ap.add_argument("--app", action="append", default=[], metavar="NAME",
                    help="benchmark app(s) to place-and-route for "
                         "--routed (default: pointwise; repeatable; see "
                         "repro.core.pnr.app.BENCH_APPS)")
    ap.add_argument("--clock", type=float, default=None, metavar="NS",
                    help="target clock period for the routed sta-slack "
                         "rule (default: no target — slack not gated)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="lint the result store at PATH: every record's "
                         "persisted analysis verdict (and, with "
                         "--routed, per-app routed verdicts) becomes a "
                         "target — stale rule-set stamps and non-clean "
                         "stored verdicts are findings")
    ap.add_argument("--per-pass", action="store_true", dest="per_pass",
                    help="attribute each finding to the pipeline pass "
                         "that introduced it (spec targets only; slower)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    return ap


def run(argv: Optional[List[str]] = None,
        out=None) -> int:
    out = out or sys.stdout
    args = build_parser().parse_args(argv)
    if args.list_rules:
        print(_list_rules(), file=out)
        return 0
    if not args.specs and not args.config and not args.store:
        print("error: no targets (pass SPEC.json files, --config "
              "module:attr and/or --store PATH; see --help)",
              file=sys.stderr)
        return USAGE_ERROR
    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    fail_on = Severity.from_str(
        {"warn": "warning"}.get(args.fail_on, args.fail_on))

    targets: List[Tuple[str, object]] = []
    results = []
    worst_clean = True
    try:
        for path in args.specs:
            targets.append((path, _load_spec_file(path)))
        for ref in args.config:
            targets.append((ref, _load_config(ref)))
        if rules is not None:
            unknown = sorted(set(rules) - set(RULES))
            if unknown:
                raise LintError(f"unknown rule id(s) {unknown}; "
                                f"see --list-rules")
        for origin, obj in targets:
            report = _lint_one(obj, origin, rules, args)
            clean = report.ok(fail_on)
            worst_clean = worst_clean and clean
            results.append((origin, report, clean))
        if args.store:
            for origin, report in _lint_store(args.store, args.routed):
                clean = report.ok(fail_on)
                worst_clean = worst_clean and clean
                results.append((origin, report, clean))
    except LintError as e:
        print(f"error: {e}", file=sys.stderr)
        return USAGE_ERROR

    doc = {"fail_on": fail_on.name.lower(),
           "clean": worst_clean,
           "targets": {origin: rep.to_dict()
                       for origin, rep, _ in results}}
    if args.format == "json":
        print(json.dumps(doc, indent=2, sort_keys=True), file=out)
    else:
        for origin, rep, clean in results:
            verdict = "clean" if clean else "FAILED"
            print(f"== {origin}: {verdict} ==", file=out)
            print(rep.render(), file=out)
    if args.output:
        with open(args.output, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
    return 0 if worst_clean else 1


def _lint_one(obj, origin: str, rules, args):
    from ..spec import InterconnectSpec

    if isinstance(obj, dict):
        obj = InterconnectSpec.from_dict(obj)
    if args.per_pass and isinstance(obj, InterconnectSpec):
        from ..passes import PassManager
        from ..passes import PassContext, _default_core_fn
        pm = PassManager()
        ctx = PassContext(spec=obj, core_fn=_default_core_fn(obj))
        pm.run(obj, core_fn=ctx.core_fn, ctx=ctx, analyze_per_pass=True)
        report = ctx.analysis_report
        ic, spec = ctx.ic, obj
    else:
        ic, spec = _to_point(obj, origin)
        report = analyze(ic, spec=spec, rules=rules)
    if rules is not None and args.per_pass:
        report.diagnostics = [d for d in report.diagnostics
                              if d.rule in set(rules)]
    if args.lowered:
        if spec is not None and getattr(spec, "ready_valid", False):
            pass  # lowered verification covers the static interconnect
        else:
            from ..lowering import FabricModule
            lowered = analyze(ic, spec=spec, scope="lowered",
                              fabric=FabricModule(ic))
            report.extend(lowered.diagnostics)
            report.rules_run = tuple(report.rules_run) + tuple(
                lowered.rules_run)
    if args.routed:
        report.extend(_routed_findings(ic, spec, args))
        report.rules_run = tuple(report.rules_run) + tuple(
            r.name for r in rule_table(scope="routed"))
    return report


def _routed_findings(ic, spec, args) -> List[Diagnostic]:
    """Place-and-route the requested bench apps on the design point and
    run the routed-scope rules over each result; findings are prefixed
    with the app they came from."""
    from ..pnr import place_and_route
    from ..pnr.app import BENCH_APPS

    names = args.app or ["pointwise"]
    unknown = sorted(set(names) - set(BENCH_APPS))
    if unknown:
        raise LintError(f"unknown app(s) {unknown}; "
                        f"one of {sorted(BENCH_APPS)}")
    diags: List[Diagnostic] = []
    for name in names:
        try:
            r = place_and_route(ic, BENCH_APPS[name](), alphas=(2.0,),
                                sa_steps=60, sa_batch=16)
            error = r.error if not r.success else None
        except ValueError as e:       # unplaceable (app > fabric)
            r, error = None, str(e)
        if error is not None:
            diags.append(Diagnostic(
                "routed-verdict", Severity.WARNING,
                f"app {name!r} could not be routed ({error}): the "
                "routed rules did not run for it"))
            continue
        rep = analyze(ic, spec=spec, scope="routed", pnr=r,
                      clock_ns=args.clock)
        diags.extend(replace(d, message=f"app {name!r}: {d.message}")
                     for d in rep.diagnostics)
    return diags


def _stored_diags(doc: dict) -> List[Diagnostic]:
    """Rehydrate the diagnostics a store record persisted (they were
    serialized with ``Diagnostic.to_dict``); malformed entries are
    skipped — a corrupt record must not abort the audit."""
    out: List[Diagnostic] = []
    for d in doc.get("diagnostics") or []:
        if not isinstance(d, dict):
            continue
        try:
            out.append(Diagnostic(
                rule=str(d.get("rule", "?")),
                severity=Severity.from_str(d.get("severity", "error")),
                message=str(d.get("message", "")),
                width=d.get("width"),
                tile=tuple(d["tile"]) if d.get("tile") else None,
                node=d.get("node"), hint=d.get("hint"),
                pass_name=d.get("pass_name")))
        except (TypeError, ValueError):
            continue
    return out


#: pseudo-rule ids of the store audit (these findings reflect *stored*
#: verdicts, not a fresh analysis run)
_STORE_AUDIT_RULES = ("stale-rule-set", "stored-verdict")


def _lint_store(root: str, routed: bool
                ) -> List[Tuple[str, AnalysisReport]]:
    """Audit the persisted analysis verdicts of a result store: one
    report per record. A record stamped by a different rule set is
    stale (warning — the executor will recompute it on next use); a
    stored non-clean verdict re-surfaces its persisted diagnostics;
    with ``routed``, each routed app's persisted ``routed_analysis``
    verdict is audited the same way."""
    from ..store import ResultStore

    store = ResultStore(root)
    current = rule_set_version()
    out: List[Tuple[str, AnalysisReport]] = []
    for digest in store.digests():
        rec = store.get(digest)
        if rec is None:
            continue
        diags: List[Diagnostic] = []
        analysis = rec.get("analysis")
        if isinstance(analysis, dict):
            stamp = analysis.get("rule_set")
            if stamp != current:
                diags.append(Diagnostic(
                    "stale-rule-set", Severity.WARNING,
                    f"record analyzed under rule set {stamp!r} but the "
                    f"current rule set is {current!r}: the stored "
                    "verdict is stale and will be recomputed on next "
                    "executor use"))
            if not analysis.get("clean", True):
                diags.extend(_stored_diags(analysis))
        if routed:
            for name, entry in sorted((rec.get("apps") or {}).items()):
                if not isinstance(entry, dict) \
                        or not entry.get("success"):
                    continue
                ra = entry.get("routed_analysis")
                if not isinstance(ra, dict):
                    diags.append(Diagnostic(
                        "stored-verdict", Severity.WARNING,
                        f"app {name!r}: routed without a persisted "
                        "routed-analysis verdict (record predates the "
                        "routed analyzer)"))
                elif not ra.get("clean", True):
                    diags.extend(
                        replace(d, message=f"app {name!r}: {d.message}")
                        for d in _stored_diags(ra))
        rules_run = _STORE_AUDIT_RULES + (tuple(
            r.name for r in rule_table(scope="routed")) if routed else ())
        out.append((f"store:{digest[:12]}",
                    AnalysisReport(diagnostics=diags,
                                   rules_run=rules_run)))
    if not out:
        raise LintError(f"--store {root}: no records to audit")
    return out
