"""Pass-based interconnect compiler (the Canal eDSL reworked as IR passes).

The paper's central claim is that a graph-based IR makes interconnect
generation *composable*: the hybrid ready-valid interconnect is produced
by transforming the static IR, not by a second generator. This module
realizes that as a linear pipeline of named, individually-testable passes
over :mod:`repro.core.graph`:

    materialize_tiles        tiles + bare switch boxes, one graph per layer
    apply_sb_topology        internal SB edges (disjoint/wilton/imran)
    insert_pipeline_registers  inter-tile wires, REG/RMUX at reg_density
    connect_core_ports       CB-in / SB-out core connections (Fc, sides)
    readyvalid_transform     (spec.ready_valid only) tag the IR for the
                             hybrid ready-valid lowering
    prune_dead_muxes         drop fully isolated nodes
    freeze                   attach spec + params; the IR is now a design

Each pass is a plain function ``(PassContext) -> None`` mutating
``ctx.ic``; :class:`PassManager` sequences them and records a per-pass
log. ``PassManager().compile(spec)`` is the single front door (also
exported as ``canal.compile``); the legacy
``edsl.create_uniform_interconnect`` is a deprecation shim over the same
pipeline, so both produce isomorphic IR by construction.

Determinism contract: passes iterate tiles row-major and sides in
``ALL_SIDES`` order, and every pass appends to disjoint fan-in lists, so
compiling the same spec twice yields identical connectivity — node order,
mux input order (config-bit semantics) and edge delays included.
``ir_digest`` condenses that into one hash for golden tests.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import (IO, Interconnect, InterconnectGraph, NodeKind,
                    RegisterMuxNode, RegisterNode, SBConnection, Side,
                    SwitchBox, SwitchBoxNode, Tile)
from .spec import InterconnectSpec, SwitchBoxType
from .tiles import Core, default_core_assigner

ALL_SIDES: Tuple[Side, ...] = (Side.NORTH, Side.SOUTH, Side.EAST, Side.WEST)

CoreFn = Callable[[int, int, int, int], Optional[Core]]


@dataclass
class PassContext:
    """Mutable state threaded through the pipeline: the spec being
    compiled, the core assigner, the IR under construction, and a
    per-pass log (inspect it to see e.g. what ``prune_dead_muxes``
    removed)."""

    spec: InterconnectSpec
    core_fn: CoreFn
    ic: Optional[Interconnect] = None
    log: List[Dict] = field(default_factory=list)
    #: filled by ``PassManager.run(..., analyze_per_pass=True)``: the
    #: final AnalysisReport with each diagnostic's ``pass_name`` set to
    #: the first pass after which the finding appears (and persists)
    analysis_report: Optional[object] = None

    def graphs(self) -> Dict[int, InterconnectGraph]:
        assert self.ic is not None, "materialize_tiles has not run"
        return self.ic.graphs


# ---------------------------------------------------------------------------
# Switch-box topologies (§4.2.1, Fig. 9) — imported lazily from edsl to keep
# the historical home of the connection generators (and avoid an import
# cycle: edsl's deprecation shim calls back into this module).
# ---------------------------------------------------------------------------

def _topology_fn(sb_type: SwitchBoxType) -> Callable[[int],
                                                     List[SBConnection]]:
    from .edsl import SB_TOPOLOGIES
    return SB_TOPOLOGIES[sb_type]


# ---------------------------------------------------------------------------
# Passes
# ---------------------------------------------------------------------------

def materialize_tiles(ctx: PassContext) -> None:
    """One :class:`InterconnectGraph` per routing layer, populated with
    tiles, cores and *bare* switch boxes (no edges yet)."""
    spec = ctx.spec
    graphs: Dict[int, InterconnectGraph] = {}
    for bit_width, n_tracks in spec.layers().items():
        g = InterconnectGraph(bit_width)
        for y in range(spec.height):
            for x in range(spec.width):
                sb = SwitchBox(x, y, n_tracks, bit_width, [],
                               mux_delay=spec.mux_delay)
                core = ctx.core_fn(x, y, spec.width, spec.height)
                g.add_tile(Tile(x, y, sb, core))
        graphs[bit_width] = g
    ctx.ic = Interconnect(graphs)
    ctx.log.append({"pass": "materialize_tiles",
                    "layers": len(graphs),
                    "tiles": spec.width * spec.height})


def apply_sb_topology(ctx: PassContext) -> None:
    """Wire each switch box's internal topology (track permutations)."""
    topo = _topology_fn(ctx.spec.sb_type)
    conns_cache: Dict[int, List[SBConnection]] = {}
    n_edges = 0
    for g in ctx.graphs().values():
        for tile in g.tiles.values():
            nt = tile.switchbox.num_tracks
            conns = conns_cache.get(nt)
            if conns is None:
                conns = conns_cache.setdefault(nt, topo(nt))
            tile.switchbox.add_internal_connections(conns)
            n_edges += len(conns)
    ctx.log.append({"pass": "apply_sb_topology",
                    "topology": ctx.spec.sb_type.value,
                    "edges": n_edges})


def _reg_pattern(spec: InterconnectSpec, x: int, y: int, track: int) -> bool:
    """Deterministic register placement at the requested density."""
    if spec.reg_density <= 0.0:
        return False
    if spec.reg_density >= 1.0:
        return True
    period = max(1, round(1.0 / spec.reg_density))
    return (x + y + track) % period == 0


def _insert_register(g: InterconnectGraph, src: SwitchBoxNode,
                     dst: SwitchBoxNode, side: Side, track: int,
                     spec: InterconnectSpec) -> None:
    """src -> REG -> RMUX -> dst, with src -> RMUX bypass (canal pattern)."""
    name = f"{side.name}_{track}"
    reg = RegisterNode(name, src.x, src.y, track, src.width, delay=0.0)
    rmux = RegisterMuxNode(name, src.x, src.y, track, src.width,
                           delay=spec.mux_delay)
    src.add_edge(reg)
    reg.add_edge(rmux)
    src.add_edge(rmux)                      # bypass path
    rmux.add_edge(dst, delay=spec.wire_delay)
    g.add_register(reg)
    g.add_reg_mux(rmux)


def insert_pipeline_registers(ctx: PassContext) -> None:
    """Inter-tile wiring: each SB_OUT drives the facing SB_IN of the
    neighbouring tile — through a REG/RMUX pipeline stage on tracks
    selected by the deterministic ``reg_density`` pattern, as a plain
    wire otherwise."""
    spec = ctx.spec
    n_regs = 0
    for g in ctx.graphs().values():
        for (x, y), tile in g.tiles.items():
            for side in ALL_SIDES:
                dx, dy = side.delta()
                nbr = g.get_tile(x + dx, y + dy)
                if nbr is None:
                    continue
                for t in range(tile.switchbox.num_tracks):
                    src = tile.switchbox.get_sb(side, t, IO.SB_OUT)
                    dst = nbr.switchbox.get_sb(side.opposite(), t, IO.SB_IN)
                    if _reg_pattern(spec, x, y, t):
                        _insert_register(g, src, dst, side, t, spec)
                        n_regs += 1
                    else:
                        src.add_edge(dst, delay=spec.wire_delay)
    ctx.log.append({"pass": "insert_pipeline_registers",
                    "registers": n_regs})


def connect_core_ports(ctx: PassContext) -> None:
    """Core <-> interconnect: CB in (SB_IN -> port) and SB out
    (port -> SB_OUT), honouring the Fig. 12 side reduction and the track
    population fraction Fc (staggered per port, VPR-style)."""
    spec = ctx.spec
    cb_sides = spec.cb_connection_sides()
    sb_sides = spec.sb_connection_sides()
    cb_stride = max(1, round(1.0 / max(spec.cb_track_fc, 1e-6)))
    sb_stride = max(1, round(1.0 / max(spec.sb_track_fc, 1e-6)))
    n_edges = 0
    for g in ctx.graphs().values():
        bit_width = g.width
        for tile in g.tiles.values():
            if tile.core is None:
                continue
            n_tracks = tile.switchbox.num_tracks
            for pi, p in enumerate(tile.core.inputs()):
                if p.width != bit_width:
                    continue
                port = tile.get_port(p.name)
                for side in cb_sides:
                    for t in range(n_tracks):
                        if (t + pi) % cb_stride != 0:
                            continue
                        sb_in = tile.switchbox.get_sb(side, t, IO.SB_IN)
                        sb_in.add_edge(port, delay=spec.cb_delay)
                        n_edges += 1
            for pi, p in enumerate(tile.core.outputs()):
                if p.width != bit_width:
                    continue
                port = tile.get_port(p.name)
                for side in sb_sides:
                    for t in range(n_tracks):
                        if (t + pi) % sb_stride != 0:
                            continue
                        sb_out = tile.switchbox.get_sb(side, t, IO.SB_OUT)
                        port.add_edge(sb_out)
                        n_edges += 1
    ctx.log.append({"pass": "connect_core_ports", "edges": n_edges})


def readyvalid_transform(ctx: PassContext) -> None:
    """Hybrid ready-valid interconnect as an IR *transform* (paper §3.3):
    the static IR is annotated — every pipeline register becomes a FIFO
    stage (full depth-2 or split single-slot chain per the spec) and the
    top-level params request the ready-valid lowering. The structural
    graph is untouched: valid reuses the data mux network and ready is
    derived from the same one-hot selects at lowering time
    (:class:`repro.fabric.RVFabric`)."""
    spec = ctx.spec
    if spec.fifo_depth != 2:
        # the architecture fixes the effective depth at 2 (a depth-2 FIFO
        # in full mode, two chained single-slot stages in split mode);
        # silently compiling a different request would make the spec
        # field decorative and split caches for identical hardware
        raise ValueError(
            f"ready-valid lowering implements depth-2 FIFOs only "
            f"(full: one depth-2 FIFO; split: chained 1+1), got "
            f"fifo_depth={spec.fifo_depth}")
    mode = "split" if spec.split_fifo else "full"
    n_fifos = 0
    for g in ctx.graphs().values():
        for reg in g.registers:
            reg.attributes["rv_fifo"] = mode
            reg.attributes["fifo_depth"] = spec.fifo_depth
            n_fifos += 1
    assert ctx.ic is not None
    ctx.ic.params["rv_fifo_mode"] = mode
    ctx.log.append({"pass": "readyvalid_transform", "mode": mode,
                    "fifos": n_fifos})


def prune_dead_muxes(ctx: PassContext) -> None:
    """Drop nodes no configuration can ever observe, iterated to a
    fixpoint: a non-port node with no fan-out drives nothing, so it (and
    its incoming edges) can go — which may leave an upstream mux
    observer-free in turn, so the pass repeats until a round removes
    nothing. Pruning only ever detaches *incoming* edges (see
    ``InterconnectGraph.prune``), so surviving mux fan-in order — and
    with it config-bit semantics — is untouched. Two node classes are
    interface, not waste, and always kept: core ports, and switch-box
    nodes on an array boundary (their missing on-array consumer is the
    chip pin). On the stock uniform topologies this pass is a no-op
    (every generated node is wired), which is exactly what keeps legacy
    sweep results bit-identical; the ``dead-mux`` analysis rule is the
    convergence oracle."""
    from .analysis.framework import AnalysisContext
    removed = 0
    rounds = 0
    for g in ctx.graphs().values():
        while True:
            # boundary nodes are only exempt while *connected*: a fully
            # isolated boundary node is no pin, just leftover hardware
            dead = [n for n in g.nodes()
                    if n.kind != NodeKind.PORT
                    and not n.fan_out
                    and (not n.fan_in
                         or not AnalysisContext.faces_off_array(g, n))]
            if not dead:
                break
            g.prune(dead)
            removed += len(dead)
            rounds += 1
    ctx.log.append({"pass": "prune_dead_muxes", "removed": removed,
                    "rounds": rounds})


def freeze(ctx: PassContext) -> None:
    """Finalize: attach the spec and flat params to the IR (consumed by
    PnR, area and the DSE record stream) plus the spec digest, the
    content address of this design point."""
    spec = ctx.spec
    ic = ctx.ic
    assert ic is not None
    ic.params.update(dict(
        width=spec.width, height=spec.height, sb_type=spec.sb_type.value,
        num_tracks=spec.num_tracks, track_width=spec.track_width,
        reg_density=spec.reg_density, cb_sides=spec.cb_sides,
        sb_sides=spec.sb_sides, ready_valid=spec.ready_valid,
        fifo_depth=spec.fifo_depth, split_fifo=spec.split_fifo,
        wire_delay=spec.wire_delay, mux_delay=spec.mux_delay,
    ))
    ic.params["spec_digest"] = spec.digest()
    ic.spec = spec  # type: ignore[attr-defined]
    ctx.log.append({"pass": "freeze", "spec_digest": spec.digest(),
                    "nodes": ic.num_nodes()})


def _default_core_fn(spec: InterconnectSpec) -> CoreFn:
    """The one place the spec's core-related fields turn into a core
    assigner — shared by PassManager.run/.compile and (through them) the
    legacy edsl shim, so the three entry points cannot diverge."""
    return default_core_assigner(
        mem_columns=spec.mem_columns, io_ring=spec.io_ring,
        pe_inputs=spec.pe_inputs, pe_outputs=spec.pe_outputs,
        width=spec.track_width)


# ---------------------------------------------------------------------------
# Pass manager
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class IRPass:
    """A named pipeline stage; ``when`` gates optional passes on the
    spec (e.g. the ready-valid transform)."""

    name: str
    run: Callable[[PassContext], None]
    when: Callable[[InterconnectSpec], bool] = lambda spec: True


DEFAULT_PASSES: Tuple[IRPass, ...] = (
    IRPass("materialize_tiles", materialize_tiles),
    IRPass("apply_sb_topology", apply_sb_topology),
    IRPass("insert_pipeline_registers", insert_pipeline_registers),
    IRPass("connect_core_ports", connect_core_ports),
    IRPass("readyvalid_transform", readyvalid_transform,
           when=lambda spec: spec.ready_valid),
    IRPass("prune_dead_muxes", prune_dead_muxes),
    IRPass("freeze", freeze),
)


class PassManager:
    """Sequences IR passes over a spec. ``run`` yields the raw
    :class:`Interconnect`; ``compile`` wraps it in a
    :class:`repro.core.compile.CompiledFabric` handle (PnR, emulation,
    area, bitstream)."""

    def __init__(self, passes: Sequence[IRPass] = DEFAULT_PASSES):
        self.passes: Tuple[IRPass, ...] = tuple(passes)
        names = [p.name for p in self.passes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate pass names in {names}")

    def pipeline_for(self, spec: InterconnectSpec) -> List[str]:
        """The pass names that would run for ``spec`` (gates applied)."""
        return [p.name for p in self.passes if p.when(spec)]

    def run(self, spec: InterconnectSpec,
            core_fn: Optional[CoreFn] = None,
            ctx: Optional[PassContext] = None,
            analyze_per_pass: bool = False) -> Interconnect:
        """Compile ``spec`` into the IR by running every (enabled) pass
        in order. ``core_fn`` is the non-serializable escape hatch for
        custom tile contents; ``ctx`` lets tests inject a pre-seeded
        context (e.g. to run a partial pipeline).

        ``analyze_per_pass`` re-runs the static analyzer after every
        pass and attributes each surviving diagnostic to the first pass
        that introduced it (``ctx.analysis_report``) — the "which pass
        broke my fabric" debugging mode. Transient findings that a later
        pass legitimately resolves (a half-built pipeline is full of
        them) are discarded: only findings still present in the final IR
        are reported."""
        if core_fn is None:
            core_fn = _default_core_fn(spec)
        if ctx is None:
            ctx = PassContext(spec=spec, core_fn=core_fn)
        snapshots: List[Tuple[str, object]] = []
        for p in self.passes:
            if p.when(spec):
                p.run(ctx)
                if analyze_per_pass and ctx.ic is not None:
                    from .analysis import analyze as _analyze
                    snapshots.append(
                        (p.name, _analyze(ctx.ic, spec=spec)))
        if analyze_per_pass:
            ctx.analysis_report = _attribute_to_passes(snapshots)
        assert ctx.ic is not None
        return ctx.ic

    def compile(self, spec: InterconnectSpec,
                core_fn: Optional[CoreFn] = None,
                use_pallas: bool = False,
                analyze: str = "warn",
                analyze_per_pass: bool = False):
        """The front door: spec -> CompiledFabric.

        ``analyze`` gates the static analyzer (``repro.core.analysis``)
        over the compiled IR: ``"warn"`` (default) attaches the report
        as ``CompiledFabric.diagnostics``; ``"error"`` additionally
        raises :class:`AnalysisError` when any finding is
        error-severity; ``"off"`` skips analysis. ``analyze_per_pass``
        attributes each finding to the pass that introduced it (slower:
        the analyzer runs once per pass)."""
        if analyze not in ("off", "warn", "error"):
            raise ValueError(
                f"analyze={analyze!r}: use 'error', 'warn' or 'off'")
        from .compile import CompiledFabric
        ctx = PassContext(spec=spec,
                          core_fn=core_fn or _default_core_fn(spec))
        ic = self.run(spec, core_fn=ctx.core_fn, ctx=ctx,
                      analyze_per_pass=(analyze_per_pass
                                        and analyze != "off"))
        report = None
        if analyze != "off":
            if ctx.analysis_report is not None:
                report = ctx.analysis_report
            else:
                from .analysis import analyze as _analyze
                report = _analyze(ic, spec=spec)
            if analyze == "error":
                report.raise_if("error")
        return CompiledFabric(spec, ic, pass_log=ctx.log,
                              use_pallas=use_pallas,
                              cacheable=core_fn is None,
                              diagnostics=report)


def _attribute_to_passes(snapshots: Sequence[Tuple[str, object]]):
    """Blame each *final* diagnostic on the pass that introduced it.

    ``snapshots`` is ``[(pass_name, AnalysisReport), ...]`` in pipeline
    order. A finding is matched across snapshots by ``Diagnostic.key()``
    (rule + location — messages may carry run-varying counts). The
    attributed pass is the first pass of the *final contiguous run* of
    snapshots containing the key: if a finding appeared, was fixed by a
    later pass, then reappeared, the reappearance is what the user needs
    to see. Returns the final report with ``pass_name`` filled in."""
    if not snapshots:
        return None
    final_name, final_report = snapshots[-1]
    key_sets = [{d.key() for d in rep} for _, rep in snapshots]
    attributed = []
    for d in final_report:
        first = len(snapshots) - 1
        while first > 0 and d.key() in key_sets[first - 1]:
            first -= 1
        attributed.append(d.with_pass(snapshots[first][0]))
    final_report.diagnostics = attributed
    return final_report


def ir_digest(ic: Interconnect) -> str:
    """Content hash of the *compiled IR*: sha256 over the sorted
    structural connectivity (node keys + ordered fan-in keys). Two
    interconnects with equal digests are isomorphic down to mux input
    order — the quantity the golden fixtures pin against silent drift."""
    h = hashlib.sha256()
    conn = ic.connectivity()
    for key in sorted(conn, key=repr):
        h.update(repr((key, conn[key])).encode())
    return h.hexdigest()
