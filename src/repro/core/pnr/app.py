"""Application dataflow graphs for place and route (§3.4).

An application is a netlist of instances (PE ops, memories, registers,
constants, IOs) and nets (driver port -> sink ports), mirroring the packed
netlist format the paper's PnR consumes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class AppInstance:
    name: str
    kind: str                    # pe | mem | io_in | io_out | reg | const
    op: str = "add"              # PE ALU op
    const: int = 0
    # PnR results / attributes
    packed_into: Optional[str] = None

    @property
    def is_movable(self) -> bool:
        return self.kind in ("pe", "mem")


@dataclass
class Net:
    name: str
    src: Tuple[str, str]                      # (instance, port)
    sinks: List[Tuple[str, str]]              # [(instance, port), ...]


@dataclass
class AppGraph:
    instances: Dict[str, AppInstance] = field(default_factory=dict)
    nets: List[Net] = field(default_factory=list)

    # ------------------------------------------------------------ builders
    def add(self, name: str, kind: str, op: str = "add",
            const: int = 0) -> AppInstance:
        if name in self.instances:
            raise ValueError(f"duplicate instance {name}")
        inst = AppInstance(name, kind, op, const)
        self.instances[name] = inst
        return inst

    def connect(self, src: str, src_port: str,
                *sinks: Tuple[str, str], name: Optional[str] = None) -> Net:
        net = Net(name or f"net{len(self.nets)}", (src, src_port),
                  list(sinks))
        self.nets.append(net)
        return net

    def fanin_of(self, inst: str) -> List[Net]:
        return [n for n in self.nets if any(s[0] == inst for s in n.sinks)]

    def fanout_of(self, inst: str) -> List[Net]:
        return [n for n in self.nets if n.src[0] == inst]

    def validate(self) -> None:
        for net in self.nets:
            if net.src[0] not in self.instances:
                raise ValueError(f"net {net.name}: unknown src {net.src[0]}")
            for s, _ in net.sinks:
                if s not in self.instances:
                    raise ValueError(f"net {net.name}: unknown sink {s}")

    def stats(self) -> Dict[str, int]:
        kinds: Dict[str, int] = {}
        for inst in self.instances.values():
            kinds[inst.kind] = kinds.get(inst.kind, 0) + 1
        kinds["nets"] = len(self.nets)
        return kinds


# ---------------------------------------------------------------------------
# Benchmark application suite — small image-pipeline-ish kernels used by the
# paper-style DSE experiments (Figs. 11/14/15 use application run time).
# ---------------------------------------------------------------------------

def app_pointwise(n_ops: int = 4) -> AppGraph:
    """in -> (+1) -> (+2) -> ... -> out : a pipeline of adds."""
    g = AppGraph()
    g.add("in0", "io_in")
    g.add("out0", "io_out")
    prev, prev_port = "in0", "io_out"   # io_in drives through port io_out
    for i in range(n_ops):
        c = g.add(f"c{i}", "const", op="const", const=i + 1)
        p = g.add(f"pe{i}", "pe", op="add")
        g.connect(prev, prev_port, (f"pe{i}", "data0"))
        g.connect(f"c{i}", "out", (f"pe{i}", "data1"))
        prev, prev_port = f"pe{i}", "res0"
    g.connect(prev, prev_port, ("out0", "io_in"))
    return g


def app_tree_reduce(leaves: int = 8, op: str = "add") -> AppGraph:
    """Binary reduction tree over `leaves` inputs."""
    g = AppGraph()
    frontier = []
    for i in range(leaves):
        g.add(f"in{i}", "io_in")
        frontier.append((f"in{i}", "io_out"))
    lvl = 0
    while len(frontier) > 1:
        nxt = []
        for j in range(0, len(frontier) - 1, 2):
            name = f"r{lvl}_{j // 2}"
            g.add(name, "pe", op=op)
            g.connect(frontier[j][0], frontier[j][1], (name, "data0"))
            g.connect(frontier[j + 1][0], frontier[j + 1][1],
                      (name, "data1"))
            nxt.append((name, "res0"))
        if len(frontier) % 2:
            nxt.append(frontier[-1])
        frontier = nxt
        lvl += 1
    g.add("out0", "io_out")
    g.connect(frontier[0][0], frontier[0][1], ("out0", "io_in"))
    return g


def app_fir(taps: int = 4) -> AppGraph:
    """FIR filter: delay line of registers, per-tap multiply, adder chain."""
    g = AppGraph()
    g.add("in0", "io_in")
    g.add("out0", "io_out")
    delayed = [("in0", "io_out")]
    for t in range(1, taps):
        g.add(f"d{t}", "reg")
        g.connect(delayed[-1][0], delayed[-1][1], (f"d{t}", "in"))
        delayed.append((f"d{t}", "out"))
    products = []
    for t in range(taps):
        g.add(f"k{t}", "const", op="const", const=t + 1)
        g.add(f"m{t}", "pe", op="mul")
        g.connect(delayed[t][0], delayed[t][1], (f"m{t}", "data0"))
        g.connect(f"k{t}", "out", (f"m{t}", "data1"))
        products.append((f"m{t}", "res0"))
    acc = products[0]
    for t in range(1, taps):
        g.add(f"a{t}", "pe", op="add")
        g.connect(acc[0], acc[1], (f"a{t}", "data0"))
        g.connect(products[t][0], products[t][1], (f"a{t}", "data1"))
        acc = (f"a{t}", "res0")
    g.connect(acc[0], acc[1], ("out0", "io_in"))
    return g


def app_stencil(width: int = 3) -> AppGraph:
    """1D stencil via mem line buffer + weighted sum (image-pipeline-ish)."""
    g = AppGraph()
    g.add("in0", "io_in")
    g.add("lb", "mem")
    g.add("out0", "io_out")
    g.connect("in0", "io_out", ("lb", "wdata"))
    taps = [("in0", "io_out"), ("lb", "rdata")]
    g.add("m0", "pe", op="add")
    g.connect(taps[0][0], taps[0][1], ("m0", "data0"))
    g.connect(taps[1][0], taps[1][1], ("m0", "data1"))
    prev = ("m0", "res0")
    for i in range(width - 2):
        g.add(f"s{i}", "pe", op="add")
        g.connect(prev[0], prev[1], (f"s{i}", "data0"))
        g.connect(taps[i % 2][0], taps[i % 2][1], (f"s{i}", "data1"))
        prev = (f"s{i}", "res0")
    g.connect(prev[0], prev[1], ("out0", "io_in"))
    return g


def app_butterfly(stages: int = 3) -> AppGraph:
    """FFT-like butterfly exchange network — routing-stressful fanout."""
    n = 1 << stages
    g = AppGraph()
    cur = []
    for i in range(n):
        g.add(f"in{i}", "io_in")
        cur.append((f"in{i}", "io_out"))
    for s in range(stages):
        nxt = []
        half = 1 << s
        for i in range(n):
            j = i ^ half
            name = f"b{s}_{i}"
            g.add(name, "pe", op="add" if i < j else "sub")
            g.connect(cur[i][0], cur[i][1], (name, "data0"))
            g.connect(cur[j][0], cur[j][1], (name, "data1"))
            nxt.append((name, "res0"))
        cur = nxt
    for i in range(n):
        g.add(f"out{i}", "io_out")
        g.connect(cur[i][0], cur[i][1], (f"out{i}", "io_in"))
    return g


BENCH_APPS = {
    "pointwise": lambda: app_pointwise(6),
    "tree_reduce": lambda: app_tree_reduce(8),
    "fir": lambda: app_fir(4),
    "stencil": lambda: app_stencil(3),
    "butterfly": lambda: app_butterfly(2),
}
