"""Detailed placement via simulated annealing (§3.4, Eq. 2).

Cost_net = (HPWL_net − γ · (Area_net ∩ Area_existing))^α

γ penalizes pass-through tiles (rewards nets whose bounding boxes overlap
already-used tiles, so routing reuses powered-on tiles); α penalizes long
potential routes. The paper sweeps α from 1 to 20 and keeps the best
post-route result.

TPU adaptation: instead of one-move-at-a-time CPU annealing, we evaluate a
*batch* of candidate swaps per temperature step with a dense, vectorized
cost (per-net bounding boxes via segment min/max + an occupancy integral
image for the overlap term), then accept the best Metropolis-passing move.
The per-net HPWL reduction is the Pallas kernel `repro.kernels.hpwl`.

Two engines sit behind the ``strategy=`` knob (mirroring the router's
``route_strategy``):

* ``"python"`` — the host loop below: the differential oracle. One
  chain, Python-side proposal, one device round-trip per step.
* ``"batched"`` — :mod:`batched_anneal`: K parallel-tempering chains as
  one jitted ``lax.scan`` device program (no per-step host sync).
* ``"auto"`` — ``"batched"`` on fabrics with at least
  ``_PLACE_AUTO_MIN_TILES`` tiles (env-overridable via
  ``CANAL_PLACE_AUTO_MIN_TILES``), ``"python"`` below it, where the
  host loop's lower fixed cost wins.
"""
from __future__ import annotations

import logging
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .packing import PackedGraph

_log = logging.getLogger(__name__)

#: "auto" strategy switches to the device-resident chains at this tile
#: count. Default only — override per process via the
#: CANAL_PLACE_AUTO_MIN_TILES env var (same calibration story as the
#: router's CANAL_AUTO_MIN_TILES).
_PLACE_AUTO_MIN_TILES = 49

PLACE_STRATEGIES = ("python", "batched", "auto")


def place_auto_min_tiles_threshold(explicit: Optional[int] = None) -> int:
    """Resolve the "auto" placement threshold: explicit override >
    ``CANAL_PLACE_AUTO_MIN_TILES`` env var > module default."""
    if explicit is not None:
        return int(explicit)
    env = os.environ.get("CANAL_PLACE_AUTO_MIN_TILES")
    if env is not None:
        try:
            return int(env)
        except ValueError:
            _log.warning("ignoring non-integer "
                         "CANAL_PLACE_AUTO_MIN_TILES=%r", env)
    return _PLACE_AUTO_MIN_TILES


def resolve_place_strategy(n_tiles: int, strategy: str,
                           auto_min_tiles: Optional[int] = None) -> str:
    """Resolve a placement-strategy knob to a concrete engine name."""
    if strategy in ("python", "batched"):
        return strategy
    if strategy == "auto":
        threshold = place_auto_min_tiles_threshold(auto_min_tiles)
        picked = "batched" if n_tiles >= threshold else "python"
        _log.info("place strategy auto -> %s (%d tiles, threshold %d)",
                  picked, n_tiles, threshold)
        return picked
    raise ValueError(f"unknown placement strategy {strategy!r}; "
                     f"expected one of {PLACE_STRATEGIES}")


class _Nets:
    """Dense pin tables for vectorized cost evaluation."""

    def __init__(self, packed: PackedGraph, inst_order: List[str]):
        idx = {n: i for i, n in enumerate(inst_order)}
        pin_net: List[int] = []
        pin_inst: List[int] = []
        self.n_nets = 0
        for net in packed.nets:
            members = [net.src[0]] + [s for s, _ in net.sinks]
            members = [m for m in members if m in idx]
            if len(members) < 2:
                continue
            for m in members:
                pin_net.append(self.n_nets)
                pin_inst.append(idx[m])
            self.n_nets += 1
        self.pin_net = jnp.asarray(np.array(pin_net, np.int32))
        self.pin_inst = jnp.asarray(np.array(pin_inst, np.int32))


def _net_cost(pos: jnp.ndarray, nets: _Nets, occ_grid: jnp.ndarray,
              gamma: float, alpha: float, width: int, height: int
              ) -> jnp.ndarray:
    """Total Eq. 2 cost for a placement. pos: (n_inst, 2) int tile coords."""
    p = pos[nets.pin_inst]                               # (n_pins, 2)
    n = max(nets.n_nets, 1)
    xmax = jax.ops.segment_max(p[:, 0], nets.pin_net, num_segments=n)
    xmin = jax.ops.segment_min(p[:, 0], nets.pin_net, num_segments=n)
    ymax = jax.ops.segment_max(p[:, 1], nets.pin_net, num_segments=n)
    ymin = jax.ops.segment_min(p[:, 1], nets.pin_net, num_segments=n)
    hpwl = (xmax - xmin + ymax - ymin).astype(jnp.float32)

    # Area_net ∩ Area_existing via an occupancy integral image
    ii = jnp.cumsum(jnp.cumsum(occ_grid, axis=0), axis=1)
    ii = jnp.pad(ii, ((1, 0), (1, 0)))

    def box_sum(x0, y0, x1, y1):
        return (ii[x1 + 1, y1 + 1] - ii[x0, y1 + 1]
                - ii[x1 + 1, y0] + ii[x0, y0])

    overlap = jax.vmap(box_sum)(xmin, ymin, xmax, ymax).astype(jnp.float32)
    base = jnp.maximum(hpwl - gamma * overlap, 1.0)
    return jnp.sum(base ** alpha)


def detailed_place(packed: PackedGraph,
                   placement: Dict[str, Tuple[int, int]],
                   width: int, height: int,
                   mem_columns: Sequence[int] = (),
                   io_ring: bool = True,
                   gamma: float = 0.3, alpha: float = 2.0,
                   n_steps: int = 300, batch: int = 64,
                   t0: float = 2.0, t_min: float = 0.01,
                   seed: int = 0,
                   use_pallas: bool = False,
                   strategy: str = "python"
                   ) -> Dict[str, Tuple[int, int]]:
    """Anneal the legalized placement. Only movable (pe/mem) instances move;
    swaps stay within compatible tile sets.

    ``strategy`` selects the engine: the host loop below (``"python"``,
    the oracle), the device-resident parallel-tempering chains
    (``"batched"``, :func:`batched_anneal.batched_place` with
    ``batch`` chains), or ``"auto"`` (tile-count switch)."""
    strat = resolve_place_strategy(width * height, strategy)
    if strat == "batched":
        from .batched_anneal import batched_place
        return batched_place(packed, placement, width, height,
                             mem_columns=mem_columns, io_ring=io_ring,
                             gamma=gamma, alpha=alpha, n_steps=n_steps,
                             n_chains=batch, t0=t0, t_min=t_min,
                             seed=seed)
    inst_order = list(packed.placeable)
    idx = {n: i for i, n in enumerate(inst_order)}
    nets = _Nets(packed, inst_order)
    if nets.n_nets == 0:
        return dict(placement)

    movable = [n for n in inst_order
               if packed.placeable[n].kind in ("pe", "mem")]
    if len(movable) == 0:
        return dict(placement)

    mem_cols = set(mem_columns)

    def tile_class(kind: str, x: int, y: int) -> str:
        if x in mem_cols:
            return "mem"
        return "pe"

    # legal empty tiles per class (move targets)
    used = set(placement.values())
    empties: Dict[str, List[Tuple[int, int]]] = {"pe": [], "mem": []}
    for x in range(width):
        for y in range(height):
            border = x in (0, width - 1) or y in (0, height - 1)
            if io_ring and border:
                continue
            if (x, y) in used:
                continue
            empties[tile_class("", x, y)].append((x, y))

    pos = np.array([placement[n] for n in inst_order], np.int32)
    mov_ids = np.array([idx[n] for n in movable], np.int32)
    mov_kind = [packed.placeable[n].kind for n in movable]

    occ = np.zeros((width, height), np.float32)
    for (x, y) in placement.values():
        occ[x, y] = 1.0

    cost_fn = jax.jit(lambda p, o: _net_cost(p, nets, o, gamma, alpha,
                                             width, height))
    rng = np.random.default_rng(seed)
    cur_cost = float(cost_fn(jnp.asarray(pos), jnp.asarray(occ)))
    temp = t0
    decay = (t_min / t0) ** (1.0 / max(n_steps, 1))

    batch_cost = jax.jit(jax.vmap(lambda p, o: _net_cost(
        p, nets, o, gamma, alpha, width, height)))

    for step in range(n_steps):
        # ---- propose a batch of moves ------------------------------------
        cand_pos = np.repeat(pos[None], batch, axis=0)
        cand_occ = np.repeat(occ[None], batch, axis=0)
        descr: List[Tuple] = []
        for b in range(batch):
            mi = rng.integers(len(movable))
            i = mov_ids[mi]
            kind = mov_kind[mi]
            cls = "mem" if kind == "mem" else "pe"
            x0, y0 = cand_pos[b, i]
            if empties[cls] and rng.random() < 0.4:
                x1, y1 = empties[cls][rng.integers(len(empties[cls]))]
                cand_pos[b, i] = (x1, y1)
                cand_occ[b, x0, y0] = 0.0
                cand_occ[b, x1, y1] = 1.0
                descr.append(("move", i, (x0, y0), (x1, y1)))
            else:
                mj = rng.integers(len(movable))
                j = mov_ids[mj]
                same = (("mem" if mov_kind[mj] == "mem" else "pe") == cls)
                if i == j or not same:
                    descr.append(None)
                    continue
                x1, y1 = cand_pos[b, j]
                cand_pos[b, i], cand_pos[b, j] = (x1, y1), (x0, y0)
                descr.append(("swap", i, j))

        costs = np.asarray(batch_cost(jnp.asarray(cand_pos),
                                      jnp.asarray(cand_occ)))
        order = np.argsort(costs)
        # ---- accept the best Metropolis-passing proposal -----------------
        # cheapest-first: each candidate gets its own Metropolis draw, and
        # the first (i.e. best) passer is applied — a rejected candidate
        # falls through to the next-best instead of ending the step
        for b in order:
            if descr[b] is None:
                continue
            d = costs[b] - cur_cost
            if d < 0 or rng.random() < np.exp(-d / max(temp, 1e-6)):
                pos = cand_pos[b]
                occ = cand_occ[b]
                cur_cost = float(costs[b])
                if descr[b][0] == "move":
                    _, _, old, new = descr[b]
                    cls = tile_class("", *new)
                    empties[cls].remove(new)
                    empties[tile_class("", *old)].append(old)
                break
        temp *= decay

    return {n: (int(pos[idx[n], 0]), int(pos[idx[n], 1]))
            for n in inst_order}
