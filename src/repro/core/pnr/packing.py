"""Packing stage (§3.4): fold constants and pipeline registers into PEs.

"Constants and registers in the application are analyzed to identify any
packing opportunities. For example, a pipeline register that feeds directly
into a PE can be packed within that PE, eliminating the need to place that
register on the configurable interconnect."
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .app import AppGraph, AppInstance, Net


@dataclass
class PackedGraph:
    """Post-packing netlist: only placeable instances (pe/mem/io) remain;
    packed consts/regs are recorded as attributes on their host PE."""

    app: AppGraph
    placeable: Dict[str, AppInstance] = field(default_factory=dict)
    nets: List[Net] = field(default_factory=list)
    #: host PE -> {port -> const value}
    const_ports: Dict[str, Dict[str, int]] = field(default_factory=dict)
    #: host PE -> input ports that absorb one register delay
    reg_ports: Dict[str, List[str]] = field(default_factory=dict)


def pack(app: AppGraph) -> PackedGraph:
    app.validate()
    packed = PackedGraph(app=app)
    drop: Dict[str, Tuple[str, str]] = {}   # folded inst -> (host, port)

    # 1. constants feeding exactly one PE input -> PE immediate
    for inst in app.instances.values():
        if inst.kind != "const":
            continue
        outs = app.fanout_of(inst.name)
        if len(outs) == 1 and len(outs[0].sinks) == 1:
            sink, port = outs[0].sinks[0]
            if app.instances[sink].kind == "pe":
                inst.packed_into = sink
                drop[inst.name] = (sink, port)
                packed.const_ports.setdefault(sink, {})[port] = inst.const

    # 2. registers feeding exactly one PE -> absorbed into PE input
    for inst in app.instances.values():
        if inst.kind != "reg":
            continue
        outs = app.fanout_of(inst.name)
        if len(outs) == 1 and len(outs[0].sinks) == 1:
            sink, port = outs[0].sinks[0]
            if app.instances[sink].kind == "pe":
                inst.packed_into = sink
                drop[inst.name] = (sink, port)
                packed.reg_ports.setdefault(sink, []).append(port)

    # 3. rebuild netlist: bypass dropped instances
    for name, inst in app.instances.items():
        if name in drop:
            continue
        if inst.kind in ("pe", "mem", "io_in", "io_out"):
            packed.placeable[name] = inst
        elif inst.kind == "reg":
            # unpacked register: becomes an interconnect register demand;
            # keep it placeable on a PE in pass mode (fallback)
            inst.kind = "pe"
            inst.op = "pass"
            packed.placeable[name] = inst

    for net in app.nets:
        src, sport = net.src
        if src in drop:
            # register absorbed: the net into the register is extended in
            # the loop below (we skip reg->pe nets; const nets vanish)
            continue
        sinks = []
        for s, p in net.sinks:
            if s in drop:
                host, hport = drop[s]
                if app.instances[s].kind == "const":
                    continue                     # const folded: net vanishes
                sinks.append((host, hport))      # reg folded: reconnect
            else:
                sinks.append((s, p))
        if not sinks:
            continue
        packed.nets.append(Net(net.name, (src, sport), sinks))

    # 4. merge nets sharing a driver port (fan-out is one net, §3.3)
    merged: Dict[Tuple[str, str], Net] = {}
    order: List[Tuple[str, str]] = []
    for net in packed.nets:
        key = net.src
        if key in merged:
            for s in net.sinks:
                if s not in merged[key].sinks:
                    merged[key].sinks.append(s)
        else:
            merged[key] = Net(net.name, net.src, list(net.sinks))
            order.append(key)
    packed.nets = [merged[k] for k in order]

    return packed
