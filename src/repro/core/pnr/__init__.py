from .app import AppGraph, AppInstance, Net  # noqa: F401
from .packing import pack                     # noqa: F401
from .global_place import global_place        # noqa: F401
from .detailed_place import detailed_place    # noqa: F401
from .route import RoutingResources, route_app, RoutingError  # noqa: F401
from .timing import sta_critical_path         # noqa: F401
from .driver import place_and_route, PnRResult  # noqa: F401
