"""Negotiated-congestion routing (§3.4): PathFinder-style iteration with A*.

"During each iteration, we compute the slack on a net and determine how
critical it is given global timing information. Then we route using the A*
algorithm on the weighted graph. The weights for each edge are based on
historical usage, net slack, and current congestion."

The router works directly on the interconnect IR (Fig. 7): edge weights are
the IR's embedded delays; congestion terms are negotiated over iterations;
net criticality (delay / max delay of the previous iteration) blends the
congestion cost with the pure-delay cost.

Two-level routing scheme (``strategy=`` knob on :func:`route_nets`):

``"python"``
    The oracle: pure-Python A* over the fine IR graph with a Manhattan
    lower bound. Exact, dependency-free, and the semantics every other
    strategy is measured against.

``"minplus"``
    Device-batched coarse wavefronts feeding the same fine expander. Per
    PathFinder iteration the router tile-coarsens the congestion-weighted
    graph (one node per tile, crossing-edge weights reduced to their
    cheapest member, inf-padded to 128 blocks), then runs ONE batched
    tropical Bellman-Ford fixpoint (``repro.kernels.minplus``) seeded at
    every distinct sink tile of every net being (re)routed. Each resulting
    cost field is an *admissible* A* lower bound: a coarse edge weight is
    ``min(delay-part, congestion-part)`` of the cheapest fine crossing
    edge — a lower bound of the blended fine cost for any net criticality
    — plus the source tile's transit toll (the cheapest exit node's base
    cost; refunded per-node for nodes that are themselves exits), while
    all other intra-tile moves cost 0: no fine path can be cheaper than
    the coarse field says. The expander adds a small per-remaining-tile
    hop bias on top (``_MINPLUS_HOP_BIAS``) that collapses equal-cost
    plateaus into a directed dive and steers ties toward fewer-hop,
    lower-wire-delay trees, so routes are cost-optimal up to a bounded
    few-percent premium while expanding far fewer nodes (the field
    prices in mux delays, register penalties and congestion history that
    the Manhattan bound ignores) and pruning coarse-unreachable tiles
    outright.
    The coarse structure is built once per :class:`RoutingResources` and
    cached; per iteration only the congestion weights are refreshed, and
    the history-free fields of iteration 0 are memoized per sink tile
    across calls (α sweeps re-route the same sinks).

``"auto"``
    ``"minplus"`` on fabrics with at least ``_AUTO_MIN_TILES`` tiles,
    ``"python"`` below — coarse fields only pay for themselves once the
    search space is big enough.

When each strategy wins: ``python`` on tiny fabrics (< ~7x7, where field
setup dominates) and as the differential oracle; ``minplus`` everywhere
else — the ≥8x8 DSE sweeps route the same trees legality-identically at a
multiple of the nets/sec (see ``benchmarks/pnr_speed.py``).
"""
from __future__ import annotations

import heapq
import logging
import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

_log = logging.getLogger(__name__)

from repro.core.graph import (Interconnect, Node, NodeKind)
from .packing import PackedGraph


class RoutingError(RuntimeError):
    pass


#: value used for "no coarse edge" — matches repro.kernels.minplus.INF
#: (float32-safe: two of these still add without overflowing to inf)
COARSE_INF = 3.0e38 / 4
#: anything above this is treated as coarse-unreachable
_INF_CUT = COARSE_INF / 2
#: "auto" strategy switches to the device-batched coarse fields at this
#: many tiles (~7x7): below, field setup costs more than it prunes.
#: Default only — override per process via the CANAL_AUTO_MIN_TILES env
#: var or per design point via InterconnectSpec.auto_min_tiles (plumbed
#: through route_nets/route_app/place_and_route ``auto_min_tiles=``).
_AUTO_MIN_TILES = 49


def auto_min_tiles_threshold(override: Optional[int] = None) -> int:
    """Resolve the "auto" strategy tile threshold: explicit override >
    ``CANAL_AUTO_MIN_TILES`` env var > module default. The env var exists
    so the ROADMAP calibration item can re-run sweeps at candidate
    thresholds without code edits."""
    if override is not None:
        return int(override)
    env = os.environ.get("CANAL_AUTO_MIN_TILES")
    if env:
        try:
            return int(env)
        except ValueError:
            _log.warning("ignoring non-integer CANAL_AUTO_MIN_TILES=%r",
                         env)
    return _AUTO_MIN_TILES
#: hop bias of the minplus expander, as a fraction of ``hop_cost`` per
#: remaining Manhattan tile: f = g + h + bias·manhattan. With a
#: near-exact h every monotone staircase between source and sink ties
#: within float ulps and plain A* floods that whole rectangle; the bias
#: makes nodes nearer the sink strictly preferred (collapsing the
#: plateau into a dive) *and* steers equal-cost ties toward fewer-hop —
#: lower wire-delay — trees. Cost premium is bounded by
#: bias·hop_cost·manhattan(src, sink), a few percent of a typical path,
#: which PathFinder's negotiation absorbs (the differential suite bounds
#: the delay drift at 10%).
_MINPLUS_HOP_BIAS = 0.05


# Port-name normalization for instances whose kind changed during packing
# (unpacked registers become pass-through PEs).
_PORT_ALIAS = {"out": "res0", "in": "data0"}


class RoutingResources:
    """Array view of the IR for the router: ids, adjacency, costs."""

    def __init__(self, ic: Interconnect, reg_penalty: float = 4.0):
        self.ic = ic
        self.reg_penalty = reg_penalty
        self.nodes: List[Node] = list(ic.nodes())
        self.node_id: Dict[Node, int] = {n: i for i, n in
                                         enumerate(self.nodes)}
        n = len(self.nodes)
        adj: List[List[Tuple[int, float]]] = [[] for _ in range(n)]
        # one pass builds every destination's fan-in position map, so the
        # edge loop below is O(E) instead of the old O(E * max_fanin)
        # (``dst.fan_in.index(node)`` per edge)
        fanin_pos: Dict[Node, Dict[Node, int]] = {
            node: {s: k for k, s in enumerate(node.fan_in)}
            for node in self.nodes}
        #: (src_id, dst_id) -> wire delay of that edge (STA / net delay)
        self.edge_delay_map: Dict[Tuple[int, int], float] = {}
        min_hop = np.inf
        for i, node in enumerate(self.nodes):
            for dst in node.fan_out:
                j = self.node_id[dst]
                k = fanin_pos[dst][node]
                wire = dst.edge_delay_in[k]
                d = wire + dst.delay
                adj[i].append((j, d))
                self.edge_delay_map[(i, j)] = wire
                if d > 0:
                    min_hop = min(min_hop, d)
        self.adj = adj
        self.kind = np.array([int(nd.kind) for nd in self.nodes], np.int8)
        self.xy = np.array([(nd.x, nd.y) for nd in self.nodes], np.int32)
        # base node cost: intrinsic delay + epsilon, registers discouraged
        # (keeps routed paths combinational unless pipelining is requested)
        eps = 1e-3
        self.base = np.array([
            nd.delay + eps + (reg_penalty
                              if nd.kind == NodeKind.REGISTER else 0.0)
            for nd in self.nodes], np.float64)
        self.hop_cost = float(min_hop if np.isfinite(min_hop) else 0.1)
        # plain-list coordinates: the minplus expander's hop bias reads
        # them per heap push, where list indexing beats numpy scalars
        self.x_list: List[int] = self.xy[:, 0].tolist()
        self.y_list: List[int] = self.xy[:, 1].tolist()
        self._coarse: Optional["CoarseGraph"] = None

    def coarse(self) -> "CoarseGraph":
        """The tile-coarsened view, built once and cached (per-iteration
        congestion weights are refreshed on top of this structure)."""
        if self._coarse is None:
            self._coarse = CoarseGraph(self)
        return self._coarse

    def port(self, x: int, y: int, name: str, width: int) -> int:
        g = self.ic.graph(width)
        tile = g.get_tile(x, y)
        if tile is None or name not in tile.ports:
            raise RoutingError(f"no port {name} at tile ({x},{y})")
        return self.node_id[tile.get_port(name)]


class CoarseGraph:
    """Tile-coarsened routing graph for the batched min-plus wavefronts.

    One coarse node per (x, y) tile; a coarse edge between two tiles
    carries the cheapest lower bound over all fine edges crossing between
    them. Only the static structure (crossing-edge index arrays) lives
    here — congestion weights are recomputed per PathFinder iteration by
    :meth:`lower_bound_weights`, and the dense matrix handed to the
    device is rebuilt from cached indices in O(E_crossing).
    """

    def __init__(self, res: RoutingResources):
        xy = res.xy
        if len(xy) == 0:
            raise RoutingError("cannot coarsen an empty routing graph")
        x0, y0 = int(xy[:, 0].min()), int(xy[:, 1].min())
        self.gw = int(xy[:, 0].max()) - x0 + 1
        self.gh = int(xy[:, 1].max()) - y0 + 1
        self.n_tiles = self.gw * self.gh
        #: fine node id -> coarse tile id
        self.tile_of = ((xy[:, 1] - y0) * self.gw
                        + (xy[:, 0] - x0)).astype(np.int32)
        srcs: List[int] = []
        dsts: List[int] = []
        statics: List[float] = []
        dst_nodes: List[int] = []
        #: node has at least one fine edge leaving its tile
        self.is_exit = np.zeros(len(res.nodes), bool)
        for i, nbrs in enumerate(res.adj):
            ti = int(self.tile_of[i])
            for j, d in nbrs:
                tj = int(self.tile_of[j])
                if ti == tj:
                    continue
                self.is_exit[i] = True
                srcs.append(ti)
                dsts.append(tj)
                # delay part of the blended fine cost: d + base[dst]
                statics.append(d + res.base[j])
                dst_nodes.append(j)
        self.e_src_tile = np.asarray(srcs, np.int32)
        self.e_dst_tile = np.asarray(dsts, np.int32)
        self.e_static = np.asarray(statics, np.float64)
        self.e_dst_node = np.asarray(dst_nodes, np.int32)
        # transit toll: leaving tile t costs at least the cheapest
        # exit node's own arrival cost (``base`` bounds the blended cost
        # for every criticality and congestion state). Charged on the
        # crossing's source side; nodes that *are* exits get it refunded
        # in sink_cost_fields, so the bound stays admissible — PROVIDED
        # no crossing lands directly on an exit node (true for SB-based
        # fabrics, where crossings terminate on SB_IN nodes with only
        # intra-tile fan-out). A graph that violates that (e.g. a torus
        # of chip nodes, every node both entry and exit) could transit a
        # tile through its entry node alone, and the toll would double-
        # charge it: drop the toll there, keeping the fields admissible
        # at the price of a looser bound.
        self.exit_toll = np.full(self.n_tiles, COARSE_INF, np.float64)
        exits = np.nonzero(self.is_exit)[0]
        if len(exits):
            np.minimum.at(self.exit_toll, self.tile_of[exits],
                          res.base[exits])
        if len(self.e_dst_node) and self.is_exit[self.e_dst_node].any():
            self.exit_toll[:] = 0.0
        #: history-free cost fields memoized per sink tile (iteration-0
        #: fields depend only on the static graph, so α sweeps and
        #: repeated apps on the same fabric reuse them across calls);
        #: _base_lists additionally memoizes the refund-adjusted per-node
        #: Python lists A* consumes (the tolist conversion is hot)
        self._base_rows: Dict[int, np.ndarray] = {}
        self._base_lists: Dict[int, List[float]] = {}

    def lower_bound_weights(self, cost_lb: np.ndarray) -> np.ndarray:
        """Dense (n_tiles, n_tiles) coarse adjacency of per-crossing lower
        bounds: ``min(delay_part, congestion_part)`` minimized over the
        fine edges of each tile pair, plus the source tile's transit toll
        (every fine path must pay its cheapest exit node before leaving);
        0 on the diagonal (intra-tile moves are otherwise free in the
        coarse model — underestimates, stays admissible).

        ``cost_lb`` must itself lower-bound the per-node negotiated cost
        for every net of the iteration (callers pass
        ``base * (1 + hist_w * hist)``, dropping the intra-iteration
        present-usage term)."""
        w = np.full((self.n_tiles, self.n_tiles), COARSE_INF, np.float64)
        if len(self.e_static):
            lb = np.minimum(self.e_static, cost_lb[self.e_dst_node])
            np.minimum.at(w, (self.e_src_tile, self.e_dst_tile), lb)
            has_exit = self.exit_toll < COARSE_INF
            w[has_exit] += self.exit_toll[has_exit, None]
        np.fill_diagonal(w, 0.0)
        return w

    def sink_cost_fields(self, res: RoutingResources, sinks: Sequence[int],
                         hist: np.ndarray, hist_w: float
                         ) -> Dict[int, np.ndarray]:
        """Per-sink admissible heuristic arrays, batched on device.

        One batched tropical Bellman-Ford fixpoint covers every distinct
        sink *tile* at once (lane b seeded 0 at its tile, INF elsewhere,
        relaxed over the transposed coarse weights = cost *to* the sink);
        the per-tile rows are then expanded to per-fine-node arrays.
        Nodes that are themselves tile exits get the transit toll of
        their own tile refunded: they can take a crossing edge directly,
        without first paying for an intra-tile hop to an exit.
        Returns {sink node id: (n_nodes,) per-node lower bounds} as
        Python lists (what the A* inner loop indexes fastest), memoized
        per sink tile for the history-free case."""
        tiles = sorted({int(self.tile_of[s]) for s in sinks})
        zero_hist = not hist.any()
        if zero_hist:
            missing = [t for t in tiles if t not in self._base_rows]
        else:
            missing = tiles
        rows: Dict[int, np.ndarray] = {}
        if missing:
            from repro.kernels import ops as kops

            w = self.lower_bound_weights(
                res.base * (1.0 + hist_w * hist))
            # bucket the seed batch to a power of two: the jitted
            # relaxation keys its trace on the batch size, and memoization
            # makes len(missing) vary call to call — without bucketing
            # every new count would pay a fresh XLA compile on the hot
            # routing path (padding lanes stay all-INF and converge
            # immediately)
            bucket = 1
            while bucket < len(missing):
                bucket *= 2
            d0 = np.full((bucket, self.n_tiles), COARSE_INF, np.float32)
            d0[np.arange(len(missing)), missing] = 0.0
            out = np.asarray(kops.minplus_wavefront(
                d0, w.T.astype(np.float32)), np.float64)
            for row, t in zip(out, missing):
                rows[t] = row
                if zero_hist:
                    self._base_rows[t] = row
        if zero_hist:
            for t in tiles:
                rows.setdefault(t, self._base_rows[t])
        refund = np.where(self.is_exit, self.exit_toll[self.tile_of], 0.0)
        lists: Dict[int, List[float]] = {}
        for t in tiles:
            if zero_hist and t in self._base_lists:
                lists[t] = self._base_lists[t]
                continue
            lists[t] = np.maximum(rows[t][self.tile_of] - refund,
                                  0.0).tolist()
            if zero_hist:
                self._base_lists[t] = lists[t]
        return {int(s): lists[int(self.tile_of[s])] for s in sinks}


@dataclass
class RoutedNet:
    name: str
    src: int
    sinks: List[int]
    #: route tree as child -> parent node ids
    tree: Dict[int, int] = field(default_factory=dict)
    delay: float = 0.0

    def nodes_used(self) -> Set[int]:
        used = set(self.tree.keys()) | {self.src}
        return used

    def edges(self) -> List[Tuple[int, int]]:
        return [(p, c) for c, p in self.tree.items()]


@dataclass
class RoutingResult:
    nets: List[RoutedNet]
    iterations: int
    overuse_history: List[int]
    resources: RoutingResources
    #: the engine that actually routed ("python"/"minplus" — "auto" is
    #: resolved before routing starts and recorded here)
    strategy: str = "python"

    def all_edges_nodes(self) -> List[Tuple[Node, Node]]:
        out = []
        for net in self.nets:
            for p, c in net.edges():
                out.append((self.resources.nodes[p],
                            self.resources.nodes[c]))
        return out

    def total_wirelength(self) -> int:
        return sum(len(net.tree) for net in self.nets)


def _astar(res: RoutingResources, sources: Dict[int, float], sink: int,
           cost_of: np.ndarray, crit: float, own_nodes: Set[int],
           blocked: np.ndarray,
           tie: Optional[np.ndarray] = None,
           h_arr: Optional[Sequence[float]] = None) -> Optional[List[int]]:
    """A* from a set of sources (the net's current route tree) to one sink.
    cost_of: per-node negotiated cost; crit blends congestion vs delay.
    ``tie`` is a node permutation used as the tertiary heap key, so
    equal-cost expansions pop in a seed-reproducible order.

    ``h_arr`` replaces the Manhattan bound with a precomputed per-node
    lower bound (the device-batched coarse min-plus field); entries at or
    above ``_INF_CUT`` mark coarse-unreachable nodes, pruned outright.
    Because that bound is near-exact, a small per-remaining-tile hop bias
    (``_MINPLUS_HOP_BIAS``) is added on top: it collapses the equal-cost
    staircase plateau into a directed dive and prefers fewer-hop (lower
    wire-delay) representatives among equal-cost trees, at a bounded
    cost premium of ``bias·hop_cost`` per tile of separation."""
    tx, ty = res.xy[sink]
    h_scale = res.hop_cost * 0.5     # admissible-ish under negotiation
    if tie is None:
        tie = np.arange(len(res.nodes))
    g_sign = 1.0 if h_arr is None else -1.0

    if h_arr is None:
        def h(i: int) -> float:
            x, y = res.xy[i]
            return (abs(int(x) - int(tx)) + abs(int(y) - int(ty))) * h_scale
    else:
        bias = res.hop_cost * _MINPLUS_HOP_BIAS
        xs, ys = res.x_list, res.y_list
        txi, tyi = int(tx), int(ty)

        def h(i: int) -> float:
            return h_arr[i] + (abs(xs[i] - txi) + abs(ys[i] - tyi)) * bias

    dist: Dict[int, float] = {}
    came: Dict[int, int] = {}
    pq: List[Tuple[float, float, int, int]] = []
    for s, c0 in sources.items():
        if h_arr is not None and h_arr[s] >= _INF_CUT:
            continue                      # cannot reach the sink from here
        dist[s] = c0
        heapq.heappush(pq, (c0 + h(s), g_sign * c0, int(tie[s]), s))
    while pq:
        f, sg, _, u = heapq.heappop(pq)
        g = g_sign * sg
        if u == sink:
            path = [u]
            while u in came:
                u = came[u]
                path.append(u)
            path.reverse()
            return path
        if g > dist.get(u, np.inf):
            continue
        for v, d in res.adj[u]:
            if v != sink:
                if blocked[v] and v not in own_nodes:
                    continue
                # ports are endpoints, never pass-throughs
                if res.kind[v] == int(NodeKind.PORT):
                    continue
            if h_arr is not None and h_arr[v] >= _INF_CUT:
                continue
            w = crit * (d + res.base[v]) + (1.0 - crit) * cost_of[v]
            ng = g + w
            if ng < dist.get(v, np.inf) - 1e-12:
                dist[v] = ng
                came[v] = u
                heapq.heappush(pq, (ng + h(v), g_sign * ng, int(tie[v]), v))
    return None


def _resolve_strategy(res: RoutingResources, strategy: str,
                      auto_min_tiles: Optional[int] = None) -> str:
    if strategy in ("python", "minplus"):
        return strategy
    if strategy == "auto":
        threshold = auto_min_tiles_threshold(auto_min_tiles)
        n_tiles = res.coarse().n_tiles
        picked = "minplus" if n_tiles >= threshold else "python"
        # logged (and recorded on RoutingResult.strategy) so DSE sweeps
        # produce the calibration data the ROADMAP item asks for
        _log.info("route strategy auto -> %s (%d tiles, threshold %d)",
                  picked, n_tiles, threshold)
        return picked
    # deliberately NOT a RoutingError: place_and_route treats those as
    # ordinary routing failures (unroutable design points), which would
    # silently turn a config typo into an all-failed sweep
    raise ValueError(f"unknown routing strategy {strategy!r}")


def route_nets(res: RoutingResources,
               nets: List[Tuple[str, int, List[int]]],
               max_iters: int = 40, pres_fac0: float = 0.6,
               pres_growth: float = 1.5, hist_w: float = 0.4,
               seed: int = 0,
               node_capacity: Optional[np.ndarray] = None,
               strategy: str = "python",
               auto_min_tiles: Optional[int] = None) -> RoutingResult:
    """PathFinder negotiation over (name, src, sinks) nets.

    ``seed`` drives the deterministic tie-break permutation used by A*
    when several expansions have equal cost, so DSE callers get
    reproducible (and seed-variable) routes.

    node_capacity: per-node net capacity (default 1; >1 models virtual
    channels, e.g. the pod-fabric ICI model).

    ``strategy``: ``"python"`` (Manhattan-bounded A*, the oracle),
    ``"minplus"`` (device-batched coarse cost fields as A* lower bounds;
    see the module docstring), or ``"auto"`` (tile-count switch at
    ``auto_min_tiles`` — defaulting to the CANAL_AUTO_MIN_TILES env var,
    then ``_AUTO_MIN_TILES``; the resolved pick is logged and recorded on
    ``RoutingResult.strategy``)."""
    strat = _resolve_strategy(res, strategy, auto_min_tiles)
    n = len(res.nodes)
    tie = np.random.default_rng(seed).permutation(n)
    usage = np.zeros(n, np.int32)
    hist = np.zeros(n, np.float64)
    cap = (np.ones(n, np.int32) if node_capacity is None
           else node_capacity.astype(np.int32))
    routed: Dict[str, RoutedNet] = {}
    crit: Dict[str, float] = {name: 0.0 for name, _, _ in nets}
    overuse_hist: List[int] = []
    # endpoints are exclusively owned: block them for every other net
    endpoint_owner = np.full(n, -1, np.int32)
    for k, (_, src, sinks) in enumerate(nets):
        for e in [src] + sinks:
            if endpoint_owner[e] not in (-1, k):
                raise RoutingError("two nets share an endpoint node")
            endpoint_owner[e] = k

    pres_fac = pres_fac0
    for it in range(max_iters):
        over_pen = 1.0 + pres_fac * np.maximum(usage + 1 - cap, 0)
        cost_of = res.base * (1.0 + hist_w * hist) * over_pen
        to_route = [k for k, (name, _, _) in enumerate(nets)
                    if it == 0 or _net_overused(routed.get(name), usage,
                                                cap)]
        if it > 0 and not to_route:
            break
        # one batched device fixpoint prices every sink of the iteration
        h_fields: Dict[int, List[float]] = {}
        if strat == "minplus":
            all_sinks = [s for k in to_route for s in nets[k][2]]
            if all_sinks:
                h_fields = res.coarse().sink_cost_fields(
                    res, all_sinks, hist, hist_w)
        for k in to_route:
            name, src, sinks = nets[k]
            old = routed.pop(name, None)
            if old is not None:
                for nid in old.nodes_used():
                    usage[nid] -= 1
            over_pen = 1.0 + pres_fac * np.maximum(usage + 1 - cap, 0)
            cost_of = res.base * (1.0 + hist_w * hist) * over_pen
            blocked = (endpoint_owner >= 0) & (endpoint_owner != k)
            net = RoutedNet(name, src, list(sinks))
            tree_nodes: Dict[int, float] = {src: 0.0}
            own: Set[int] = {src}
            def _span(s):
                return (-abs(res.xy[s][0] - res.xy[src][0])
                        - abs(res.xy[s][1] - res.xy[src][1]))

            for sink in sorted(sinks, key=_span):
                path = _astar(res, tree_nodes, sink, cost_of,
                              crit.get(name, 0.0), own, blocked, tie=tie,
                              h_arr=h_fields.get(sink))
                if path is None:
                    raise RoutingError(
                        f"unroutable net {name} -> {res.nodes[sink]} "
                        f"(iteration {it})")
                for a, b in zip(path, path[1:]):
                    if b not in net.tree:
                        net.tree[b] = a
                for nid in path:
                    tree_nodes.setdefault(nid, 0.0)
                    own.add(nid)
            for nid in net.nodes_used():
                usage[nid] += 1
            routed[name] = net

        over = int(np.sum(np.maximum(usage - cap, 0)))
        overuse_hist.append(over)
        if over == 0:
            break
        hist += np.maximum(usage - cap, 0)
        pres_fac *= pres_growth
        # update criticalities from current delays
        delays = {}
        for name, netr in routed.items():
            netr.delay = _net_delay(res, netr)
            delays[name] = netr.delay
        dmax = max(delays.values()) if delays else 1.0
        for name in delays:
            crit[name] = min(0.9, delays[name] / max(dmax, 1e-9))
    else:
        over = int(np.sum(np.maximum(usage - cap, 0)))
        if over:
            raise RoutingError(
                f"congestion not resolved after {max_iters} iterations "
                f"({over} overused nodes)")

    result_nets = []
    for name, src, sinks in nets:
        netr = routed[name]
        netr.delay = _net_delay(res, netr)
        result_nets.append(netr)
    return RoutingResult(result_nets, len(overuse_hist), overuse_hist, res,
                         strategy=strat)


def _net_overused(net: Optional[RoutedNet], usage: np.ndarray,
                  cap: np.ndarray) -> bool:
    if net is None:
        return True
    return any(usage[nid] > cap[nid] for nid in net.nodes_used())


def _net_delay(res: RoutingResources, net: RoutedNet) -> float:
    """Max source->sink delay along the route tree."""
    memo: Dict[int, float] = {net.src: res.base[net.src]}

    def delay_to(nid: int) -> float:
        if nid in memo:
            return memo[nid]
        parent = net.tree[nid]
        d = (delay_to(parent) + res.nodes[nid].delay
             + res.edge_delay_map[(parent, nid)])
        memo[nid] = d
        return d

    return max((delay_to(s) for s in net.sinks), default=0.0)


def route_app(ic: Interconnect, packed: PackedGraph,
              placement: Dict[str, Tuple[int, int]],
              width: int = 16, max_iters: int = 40,
              res: Optional[RoutingResources] = None,
              seed: int = 0, strategy: str = "python",
              auto_min_tiles: Optional[int] = None) -> RoutingResult:
    """Route a packed+placed application on the interconnect."""
    if res is None:
        res = RoutingResources(ic)
    track_width = ic.widths[-1]

    def port_of(inst_name: str, port: str) -> int:
        inst = packed.placeable[inst_name]
        x, y = placement[inst_name]
        if inst.kind == "io_in":
            pname = "io_out"
        elif inst.kind == "io_out":
            pname = "io_in"
        else:
            pname = _PORT_ALIAS.get(port, port)
        return res.port(x, y, pname, track_width)

    nets = []
    for net in packed.nets:
        if net.src[0] not in packed.placeable:
            continue
        src = port_of(net.src[0], net.src[1])
        sinks = [port_of(s, p) for s, p in net.sinks
                 if s in packed.placeable]
        if not sinks:
            continue
        nets.append((net.name, src, sinks))
    return route_nets(res, nets, max_iters=max_iters, seed=seed,
                      strategy=strategy, auto_min_tiles=auto_min_tiles)
