"""Negotiated-congestion routing (§3.4): PathFinder-style iteration with A*.

"During each iteration, we compute the slack on a net and determine how
critical it is given global timing information. Then we route using the A*
algorithm on the weighted graph. The weights for each edge are based on
historical usage, net slack, and current congestion."

The router works directly on the interconnect IR (Fig. 7): edge weights are
the IR's embedded delays; congestion terms are negotiated over iterations;
net criticality (delay / max delay of the previous iteration) blends the
congestion cost with the pure-delay cost.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.graph import (Interconnect, Node, NodeKind)
from .packing import PackedGraph


class RoutingError(RuntimeError):
    pass


# Port-name normalization for instances whose kind changed during packing
# (unpacked registers become pass-through PEs).
_PORT_ALIAS = {"out": "res0", "in": "data0"}


class RoutingResources:
    """Array view of the IR for the router: ids, adjacency, costs."""

    def __init__(self, ic: Interconnect, reg_penalty: float = 4.0):
        self.ic = ic
        self.nodes: List[Node] = list(ic.nodes())
        self.node_id: Dict[Node, int] = {n: i for i, n in
                                         enumerate(self.nodes)}
        n = len(self.nodes)
        adj: List[List[Tuple[int, float]]] = [[] for _ in range(n)]
        min_hop = np.inf
        for i, node in enumerate(self.nodes):
            for dst in node.fan_out:
                j = self.node_id[dst]
                k = dst.fan_in.index(node)
                d = dst.edge_delay_in[k] + dst.delay
                adj[i].append((j, d))
                if d > 0:
                    min_hop = min(min_hop, d)
        self.adj = adj
        self.kind = np.array([int(nd.kind) for nd in self.nodes], np.int8)
        self.xy = np.array([(nd.x, nd.y) for nd in self.nodes], np.int32)
        # base node cost: intrinsic delay + epsilon, registers discouraged
        # (keeps routed paths combinational unless pipelining is requested)
        eps = 1e-3
        self.base = np.array([
            nd.delay + eps + (reg_penalty
                              if nd.kind == NodeKind.REGISTER else 0.0)
            for nd in self.nodes], np.float64)
        self.hop_cost = float(min_hop if np.isfinite(min_hop) else 0.1)

    def port(self, x: int, y: int, name: str, width: int) -> int:
        g = self.ic.graph(width)
        tile = g.get_tile(x, y)
        if tile is None or name not in tile.ports:
            raise RoutingError(f"no port {name} at tile ({x},{y})")
        return self.node_id[tile.get_port(name)]


@dataclass
class RoutedNet:
    name: str
    src: int
    sinks: List[int]
    #: route tree as child -> parent node ids
    tree: Dict[int, int] = field(default_factory=dict)
    delay: float = 0.0

    def nodes_used(self) -> Set[int]:
        used = set(self.tree.keys()) | {self.src}
        return used

    def edges(self) -> List[Tuple[int, int]]:
        return [(p, c) for c, p in self.tree.items()]


@dataclass
class RoutingResult:
    nets: List[RoutedNet]
    iterations: int
    overuse_history: List[int]
    resources: RoutingResources

    def all_edges_nodes(self) -> List[Tuple[Node, Node]]:
        out = []
        for net in self.nets:
            for p, c in net.edges():
                out.append((self.resources.nodes[p],
                            self.resources.nodes[c]))
        return out

    def total_wirelength(self) -> int:
        return sum(len(net.tree) for net in self.nets)


def _astar(res: RoutingResources, sources: Dict[int, float], sink: int,
           cost_of: np.ndarray, crit: float, own_nodes: Set[int],
           blocked: np.ndarray,
           tie: Optional[np.ndarray] = None) -> Optional[List[int]]:
    """A* from a set of sources (the net's current route tree) to one sink.
    cost_of: per-node negotiated cost; crit blends congestion vs delay.
    ``tie`` is a node permutation used as the tertiary heap key, so
    equal-cost expansions pop in a seed-reproducible order."""
    tx, ty = res.xy[sink]
    h_scale = res.hop_cost * 0.5     # admissible-ish under negotiation
    if tie is None:
        tie = np.arange(len(res.nodes))

    def h(i: int) -> float:
        x, y = res.xy[i]
        return (abs(int(x) - int(tx)) + abs(int(y) - int(ty))) * h_scale

    dist: Dict[int, float] = {}
    came: Dict[int, int] = {}
    pq: List[Tuple[float, float, int, int]] = []
    for s, c0 in sources.items():
        dist[s] = c0
        heapq.heappush(pq, (c0 + h(s), c0, int(tie[s]), s))
    while pq:
        f, g, _, u = heapq.heappop(pq)
        if u == sink:
            path = [u]
            while u in came:
                u = came[u]
                path.append(u)
            path.reverse()
            return path
        if g > dist.get(u, np.inf):
            continue
        for v, d in res.adj[u]:
            if v != sink:
                if blocked[v] and v not in own_nodes:
                    continue
                # ports are endpoints, never pass-throughs
                if res.kind[v] == int(NodeKind.PORT):
                    continue
            w = crit * (d + res.base[v]) + (1.0 - crit) * cost_of[v]
            ng = g + w
            if ng < dist.get(v, np.inf) - 1e-12:
                dist[v] = ng
                came[v] = u
                heapq.heappush(pq, (ng + h(v), ng, int(tie[v]), v))
    return None


def route_nets(res: RoutingResources,
               nets: List[Tuple[str, int, List[int]]],
               max_iters: int = 40, pres_fac0: float = 0.6,
               pres_growth: float = 1.5, hist_w: float = 0.4,
               seed: int = 0,
               node_capacity: Optional[np.ndarray] = None) -> RoutingResult:
    """PathFinder negotiation over (name, src, sinks) nets.

    ``seed`` drives the deterministic tie-break permutation used by A*
    when several expansions have equal cost, so DSE callers get
    reproducible (and seed-variable) routes.

    node_capacity: per-node net capacity (default 1; >1 models virtual
    channels, e.g. the pod-fabric ICI model)."""
    n = len(res.nodes)
    tie = np.random.default_rng(seed).permutation(n)
    usage = np.zeros(n, np.int32)
    hist = np.zeros(n, np.float64)
    cap = (np.ones(n, np.int32) if node_capacity is None
           else node_capacity.astype(np.int32))
    routed: Dict[str, RoutedNet] = {}
    crit: Dict[str, float] = {name: 0.0 for name, _, _ in nets}
    overuse_hist: List[int] = []
    # endpoints are exclusively owned: block them for every other net
    endpoint_owner = np.full(n, -1, np.int32)
    for k, (_, src, sinks) in enumerate(nets):
        for e in [src] + sinks:
            if endpoint_owner[e] not in (-1, k):
                raise RoutingError("two nets share an endpoint node")
            endpoint_owner[e] = k

    pres_fac = pres_fac0
    for it in range(max_iters):
        over_pen = 1.0 + pres_fac * np.maximum(usage + 1 - cap, 0)
        cost_of = res.base * (1.0 + hist_w * hist) * over_pen
        to_route = [k for k, (name, _, _) in enumerate(nets)
                    if it == 0 or _net_overused(routed.get(name), usage,
                                                cap)]
        if it > 0 and not to_route:
            break
        for k in to_route:
            name, src, sinks = nets[k]
            old = routed.pop(name, None)
            if old is not None:
                for nid in old.nodes_used():
                    usage[nid] -= 1
            over_pen = 1.0 + pres_fac * np.maximum(usage + 1 - cap, 0)
            cost_of = res.base * (1.0 + hist_w * hist) * over_pen
            blocked = (endpoint_owner >= 0) & (endpoint_owner != k)
            net = RoutedNet(name, src, list(sinks))
            tree_nodes: Dict[int, float] = {src: 0.0}
            own: Set[int] = {src}
            for sink in sorted(sinks,
                               key=lambda s: -abs(res.xy[s][0] - res.xy[src][0])
                               - abs(res.xy[s][1] - res.xy[src][1])):
                path = _astar(res, tree_nodes, sink, cost_of,
                              crit.get(name, 0.0), own, blocked, tie=tie)
                if path is None:
                    raise RoutingError(
                        f"unroutable net {name} -> {res.nodes[sink]} "
                        f"(iteration {it})")
                for a, b in zip(path, path[1:]):
                    if b not in net.tree:
                        net.tree[b] = a
                for nid in path:
                    tree_nodes.setdefault(nid, 0.0)
                    own.add(nid)
            for nid in net.nodes_used():
                usage[nid] += 1
            routed[name] = net

        over = int(np.sum(np.maximum(usage - cap, 0)))
        overuse_hist.append(over)
        if over == 0:
            break
        hist += np.maximum(usage - cap, 0)
        pres_fac *= pres_growth
        # update criticalities from current delays
        delays = {}
        for name, netr in routed.items():
            netr.delay = _net_delay(res, netr)
            delays[name] = netr.delay
        dmax = max(delays.values()) if delays else 1.0
        for name in delays:
            crit[name] = min(0.9, delays[name] / max(dmax, 1e-9))
    else:
        over = int(np.sum(np.maximum(usage - cap, 0)))
        if over:
            raise RoutingError(
                f"congestion not resolved after {max_iters} iterations "
                f"({over} overused nodes)")

    result_nets = []
    for name, src, sinks in nets:
        netr = routed[name]
        netr.delay = _net_delay(res, netr)
        result_nets.append(netr)
    return RoutingResult(result_nets, len(overuse_hist), overuse_hist, res)


def _net_overused(net: Optional[RoutedNet], usage: np.ndarray,
                  cap: np.ndarray) -> bool:
    if net is None:
        return True
    return any(usage[nid] > cap[nid] for nid in net.nodes_used())


def _net_delay(res: RoutingResources, net: RoutedNet) -> float:
    """Max source->sink delay along the route tree."""
    memo: Dict[int, float] = {net.src: res.base[net.src]}

    def delay_to(nid: int) -> float:
        if nid in memo:
            return memo[nid]
        parent = net.tree[nid]
        d = delay_to(parent) + res.nodes[nid].delay
        k = res.nodes[nid].fan_in.index(res.nodes[parent])
        d += res.nodes[nid].edge_delay_in[k]
        memo[nid] = d
        return d

    return max((delay_to(s) for s in net.sinks), default=0.0)


def route_app(ic: Interconnect, packed: PackedGraph,
              placement: Dict[str, Tuple[int, int]],
              width: int = 16, max_iters: int = 40,
              res: Optional[RoutingResources] = None,
              seed: int = 0) -> RoutingResult:
    """Route a packed+placed application on the interconnect."""
    if res is None:
        res = RoutingResources(ic)
    track_width = ic.widths[-1]

    def port_of(inst_name: str, port: str) -> int:
        inst = packed.placeable[inst_name]
        x, y = placement[inst_name]
        if inst.kind == "io_in":
            pname = "io_out"
        elif inst.kind == "io_out":
            pname = "io_in"
        else:
            pname = _PORT_ALIAS.get(port, port)
        return res.port(x, y, pname, track_width)

    nets = []
    for net in packed.nets:
        if net.src[0] not in packed.placeable:
            continue
        src = port_of(net.src[0], net.src[1])
        sinks = [port_of(s, p) for s, p in net.sinks
                 if s in packed.placeable]
        if not sinks:
            continue
        nets.append((net.name, src, sinks))
    return route_nets(res, nets, max_iters=max_iters, seed=seed)
