"""Device-resident batched annealing placement (§3.4, Eq. 2).

The host annealer in :mod:`detailed_place` proposes moves in a Python
loop and round-trips to the device once per temperature step to score a
candidate batch — placement is the last host-serial stage of a cold PnR
evaluation now that routing and emulation are device-accelerated. This
module replaces that loop with **one jitted device program**:

* K independent annealing chains run as a single ``lax.scan`` over
  temperature steps with the chain axis vmapped; per-chain move
  proposal uses ``jax.random`` (seed-deterministic across processes).
* Moves are encoded as (instance, target-slot) pairs over a dense
  *legal-tile table* partitioned by tile class (PE tiles vs memory
  columns, IO ring excluded), so mem-column / IO-ring legality holds by
  construction — an illegal placement is unrepresentable.
* Each chain scores a small candidate batch per step and applies the
  cheapest Metropolis-passing candidate (the documented
  best-passing-candidate semantics, vectorized: every candidate draws
  its own uniform, the accepted one is the min-cost passer).
* Eq. 2 cost deltas are incremental: only the nets touching the moved
  instances re-reduce their pin bounding boxes; the overlap term reads
  a per-chain occupancy integral image. The full per-net reduction —
  used to seed the chain state — is the ``repro.kernels.hpwl`` Pallas
  kernel on padded ``(n_nets, K, 2)`` pin tables.
* Chains sit on a geometric temperature ladder and periodically attempt
  replica exchange between neighbours (parallel tempering), so hot
  chains feed escapes to cold ones; the best placement seen by any
  chain wins.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .packing import PackedGraph

#: candidate proposals per chain per temperature step
DEFAULT_CANDS = 4
#: temperature-ladder span: the hottest chain anneals this many times
#: hotter than the coldest (chain 0) at every step
DEFAULT_LADDER = 3.0
#: steps between replica-exchange attempts (even/odd neighbour pairs
#: alternate, so the whole ladder mixes)
DEFAULT_EXCHANGE_EVERY = 16


# ---------------------------------------------------------------------------
# Host-side table construction
# ---------------------------------------------------------------------------

def _net_members(packed: PackedGraph,
                 idx: Dict[str, int]) -> List[List[int]]:
    """Per-net placeable member instance indices (>=2 members only)."""
    out: List[List[int]] = []
    for net in packed.nets:
        members = [net.src[0]] + [s for s, _ in net.sinks]
        members = [idx[m] for m in members if m in idx]
        if len(members) >= 2:
            out.append(members)
    return out


def _legal_slot_tables(packed: PackedGraph,
                       placement: Dict[str, Tuple[int, int]],
                       movable: List[str],
                       width: int, height: int,
                       mem_columns: Sequence[int],
                       io_ring: bool):
    """The dense legal-tile tables that make moves legal by construction.

    Tiles are partitioned into classes — ``mem`` (memory columns, when
    any are declared) and ``pe`` (everything else) — minus the IO ring
    border (when enabled) and tiles pinned by immovable instances. Each
    movable instance draws move targets only from its own class range,
    mirroring :func:`global_place.legalize`'s ``legal_for`` rules."""
    mem_cols = set(int(c) for c in mem_columns)
    fixed_tiles = {placement[n] for n in placement if n not in set(movable)}
    tiles: Dict[str, List[Tuple[int, int]]] = {"pe": [], "mem": []}
    for x in range(width):
        for y in range(height):
            if io_ring and (x in (0, width - 1) or y in (0, height - 1)):
                continue
            if (x, y) in fixed_tiles:
                continue
            cls = "mem" if (mem_cols and x in mem_cols) else "pe"
            tiles[cls].append((x, y))

    slot_xy = np.array(tiles["pe"] + tiles["mem"], np.int32)
    ranges = {"pe": (0, len(tiles["pe"])),
              "mem": (len(tiles["pe"]), len(tiles["mem"]))}
    tile_slot = {tuple(t): s for s, t in enumerate(slot_xy.tolist())}

    inst_lo = np.zeros(len(movable), np.int32)
    inst_size = np.zeros(len(movable), np.int32)
    slot0 = np.zeros(len(movable), np.int32)
    for i, name in enumerate(movable):
        kind = packed.placeable[name].kind
        cls = "mem" if (kind == "mem" and mem_cols) else "pe"
        lo, size = ranges[cls]
        if size == 0:
            raise ValueError(f"no legal tiles for {name} (class {cls})")
        inst_lo[i], inst_size[i] = lo, size
        tile = tuple(placement[name])
        if tile not in tile_slot or not lo <= tile_slot[tile] < lo + size:
            raise ValueError(
                f"instance {name} at {tile} is outside its legal tile "
                f"class {cls!r} — batched placement needs a legal seed")
        slot0[i] = tile_slot[tile]
    return slot_xy, inst_lo, inst_size, slot0


def _eq2_terms(bboxes: jnp.ndarray, occ: jnp.ndarray,
               gamma, alpha) -> jnp.ndarray:
    """Per-net Eq. 2 terms from (n, 4) boxes + an occupancy grid."""
    ii = jnp.pad(jnp.cumsum(jnp.cumsum(occ, axis=0), axis=1),
                 ((1, 0), (1, 0)))
    x0, x1 = bboxes[:, 0], bboxes[:, 1]
    y0, y1 = bboxes[:, 2], bboxes[:, 3]
    overlap = (ii[x1 + 1, y1 + 1] - ii[x0, y1 + 1]
               - ii[x1 + 1, y0] + ii[x0, y0]).astype(jnp.float32)
    hpwl = ((x1 - x0) + (y1 - y0)).astype(jnp.float32)
    return jnp.maximum(hpwl - gamma * overlap, 1.0) ** alpha


def eq2_cost(packed: PackedGraph, placement: Dict[str, Tuple[int, int]],
             width: int, height: int,
             gamma: float = 0.3, alpha: float = 2.0) -> float:
    """The exact Eq. 2 cost of a placement (per-net boxes via the
    ``repro.kernels.hpwl`` Pallas kernel) — the common yardstick the
    host oracle and the batched chains are compared on."""
    inst_order = list(packed.placeable)
    idx = {n: i for i, n in enumerate(inst_order)}
    members = _net_members(packed, idx)
    if not members:
        return 0.0
    kp = max(len(m) for m in members)
    pins = np.zeros((len(members), kp, 2), np.int32)
    mask = np.zeros((len(members), kp), np.int32)
    for n, mem in enumerate(members):
        for j, gi in enumerate(mem):
            pins[n, j] = placement[inst_order[gi]]
            mask[n, j] = 1
    bboxes = ops.net_bboxes(jnp.asarray(pins), jnp.asarray(mask))
    occ = np.zeros((width, height), np.float32)
    for (x, y) in placement.values():
        occ[x, y] = 1.0
    terms = _eq2_terms(bboxes, jnp.asarray(occ),
                       jnp.float32(gamma), jnp.float32(alpha))
    return float(jnp.sum(terms))


# ---------------------------------------------------------------------------
# The device program
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("n_steps", "n_chains", "cands", "exchange_every"))
def _anneal(slot_xy, mov_gid, inst_lo, inst_size, net_pins, net_mask,
            mov_nets, pos0, occ0, slot0, owner0, bbox0,
            seed, gamma, alpha, t0, t_min, ladder,
            n_steps: int, n_chains: int, cands: int, exchange_every: int):
    """K parallel-tempering annealing chains as one scan-over-steps.

    All tables are device arrays; ``bbox0`` is ``(n_nets + 1, 4)`` (the
    trailing row is the scatter sink for padded affected-net slots).
    Returns ``(best_slot, best_cost)`` stacked over chains."""
    n_mov = slot0.shape[0]
    n_nets = bbox0.shape[0] - 1
    chain_ids = jnp.arange(n_chains)
    base_key = jax.random.PRNGKey(seed)
    decay = (t_min / t0) ** (1.0 / max(n_steps, 1))
    #: chain k anneals ladder**(k/(K-1)) hotter than chain 0
    ladder_f = ladder ** (chain_ids.astype(jnp.float32)
                          / max(n_chains - 1, 1))

    def terms_total(bbox, occ):
        return jnp.sum(_eq2_terms(bbox[:n_nets], occ, gamma, alpha))

    cost0 = terms_total(bbox0, occ0)

    def chain_step(slot, owner, pos, occ, bbox, cost, key, temp):
        kc, ks, ku = jax.random.split(key, 3)
        mi = jax.random.randint(kc, (cands,), 0, n_mov)
        draw = jax.random.randint(ks, (cands,), 0, jnp.int32(1 << 30))
        tgt = inst_lo[mi] + draw % inst_size[mi]
        u = jax.random.uniform(ku, (cands,))

        def eval_cand(i, t_slot):
            src = slot[i]
            j = owner[t_slot]                    # another movable, or -1
            valid = t_slot != src
            swap = j >= 0
            jc = jnp.maximum(j, 0)
            gi = mov_gid[i]
            gj = jnp.where(swap, mov_gid[jc], gi)
            xy_i = slot_xy[t_slot]
            xy_j = jnp.where(swap, slot_xy[src], xy_i)
            # occupancy moves only on a relocate (swap leaves it fixed)
            docc = jnp.where(swap, 0.0, 1.0)
            sxy = slot_xy[src]
            occ2 = occ.at[sxy[0], sxy[1]].add(-docc)
            occ2 = occ2.at[xy_i[0], xy_i[1]].add(docc)
            # incremental re-reduce: only nets touching the movers
            aff = jnp.concatenate(
                [mov_nets[i], jnp.where(swap, mov_nets[jc], -1)])
            live = aff >= 0
            affc = jnp.maximum(aff, 0)
            pidx = net_pins[affc]                # (2M, Kp)
            pxy = pos[pidx]                      # (2M, Kp, 2)
            pxy = jnp.where((pidx == gi)[..., None], xy_i[None, None],
                            pxy)
            pxy = jnp.where((swap & (pidx == gj))[..., None],
                            xy_j[None, None], pxy)
            m = net_mask[affc] > 0
            big = jnp.int32(1 << 20)
            px, py = pxy[..., 0], pxy[..., 1]
            nb = jnp.stack([
                jnp.min(jnp.where(m, px, big), axis=1),
                jnp.max(jnp.where(m, px, -big), axis=1),
                jnp.min(jnp.where(m, py, big), axis=1),
                jnp.max(jnp.where(m, py, -big), axis=1),
            ], axis=1)
            # padded slots scatter into the sink row n_nets; duplicate
            # net ids scatter identical boxes, so order is irrelevant
            row = jnp.where(live, affc, n_nets)
            bbox2 = bbox.at[row].set(nb)
            cost2 = terms_total(bbox2, occ2)
            # applied state (selected lazily by the accept step below)
            slot2 = slot.at[i].set(t_slot)
            slot2 = slot2.at[jnp.where(swap, jc, i)].set(
                jnp.where(swap, src, t_slot))
            owner2 = owner.at[src].set(jnp.where(swap, jc, -1))
            owner2 = owner2.at[t_slot].set(i)
            pos2 = pos.at[gi].set(xy_i)
            pos2 = pos2.at[jnp.where(swap, gj, gi)].set(
                jnp.where(swap, xy_j, xy_i))
            return cost2, valid, slot2, owner2, pos2, occ2, bbox2

        c2, valid, slot2, owner2, pos2, occ2, bbox2 = \
            jax.vmap(eval_cand)(mi, tgt)
        d = c2 - cost
        passed = valid & ((d <= 0)
                          | (u < jnp.exp(-d / jnp.maximum(temp, 1e-6))))
        # best-passing-candidate: cheapest candidate whose own
        # Metropolis draw passed (== walking candidates cheapest-first
        # and accepting the first passer)
        score = jnp.where(passed, c2, jnp.inf)
        b = jnp.argmin(score)
        take = score[b] < jnp.inf

        def pick(new, old):
            return jnp.where(take, new[b], old)

        return (pick(slot2, slot), pick(owner2, owner), pick(pos2, pos),
                pick(occ2, occ), pick(bbox2, bbox), pick(c2, cost))

    def exchange(t, costs, temps, key):
        """Neighbour replica-exchange permutation for this step (identity
        off-cadence). Standard PT acceptance between ladder neighbours:
        p = min(1, exp((E_a - E_b)(1/T_a - 1/T_b)))."""
        k_ids = jnp.arange(n_chains)
        ex_round = (t % exchange_every) == (exchange_every - 1)
        off = (t // exchange_every) % 2
        left = ((k_ids - off) % 2 == 0) & (k_ids + 1 < n_chains)
        partner_of_left = jnp.minimum(k_ids + 1, n_chains - 1)
        logp = ((costs - costs[partner_of_left])
                * (1.0 / temps - 1.0 / temps[partner_of_left]))
        u = jax.random.uniform(key, (n_chains,))
        acc_left = left & (jnp.log(jnp.maximum(u, 1e-30)) < logp)
        right = jnp.roll(acc_left, 1) & (k_ids > 0)
        perm = jnp.where(acc_left, k_ids + 1,
                         jnp.where(right, k_ids - 1, k_ids))
        return jnp.where(ex_round, perm, k_ids)

    def body(carry, t):
        slot, owner, pos, occ, bbox, cost, best_cost, best_slot = carry
        temps = (t0 * decay ** t) * ladder_f
        step_key = jax.random.fold_in(base_key, t)
        keys = jax.vmap(lambda c: jax.random.fold_in(step_key, c))(
            chain_ids)
        slot, owner, pos, occ, bbox, cost = jax.vmap(chain_step)(
            slot, owner, pos, occ, bbox, cost, keys, temps)
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        best_slot = jnp.where(better[:, None], slot, best_slot)
        perm = exchange(t, cost, temps,
                        jax.random.fold_in(step_key, n_chains))
        carry = tuple(x[perm] for x in
                      (slot, owner, pos, occ, bbox, cost,
                       best_cost, best_slot))
        return carry, None

    def tile(x):
        return jnp.broadcast_to(x, (n_chains,) + x.shape)

    carry0 = (tile(slot0), tile(owner0), tile(pos0), tile(occ0),
              tile(bbox0), jnp.full((n_chains,), cost0),
              jnp.full((n_chains,), cost0), tile(slot0))
    carry, _ = jax.lax.scan(body, carry0, jnp.arange(n_steps))
    _, _, _, _, _, _, best_cost, best_slot = carry
    return best_slot, best_cost


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------

def batched_place(packed: PackedGraph,
                  placement: Dict[str, Tuple[int, int]],
                  width: int, height: int,
                  mem_columns: Sequence[int] = (),
                  io_ring: bool = True,
                  gamma: float = 0.3, alpha: float = 2.0,
                  n_steps: int = 300, n_chains: int = 16,
                  cands: int = DEFAULT_CANDS,
                  t0: float = 2.0, t_min: float = 0.01,
                  seed: int = 0,
                  exchange_every: int = DEFAULT_EXCHANGE_EVERY,
                  ladder: float = DEFAULT_LADDER,
                  return_cost: bool = False):
    """Anneal the legalized placement on-device: K parallel-tempering
    chains, one jitted scan, best chain wins. Same contract as
    :func:`detailed_place.detailed_place` (only pe/mem instances move;
    legality is structural). Deterministic for a fixed ``seed``."""
    inst_order = list(packed.placeable)
    idx = {n: i for i, n in enumerate(inst_order)}
    members = _net_members(packed, idx)
    movable = [n for n in inst_order
               if packed.placeable[n].kind in ("pe", "mem")]
    if not members or not movable:
        return (dict(placement), 0.0) if return_cost else dict(placement)

    n_nets = len(members)
    kp = max(len(m) for m in members)
    net_pins = np.zeros((n_nets, kp), np.int32)
    net_mask = np.zeros((n_nets, kp), np.int32)
    for n, mem in enumerate(members):
        net_pins[n, :len(mem)] = mem
        net_mask[n, :len(mem)] = 1

    mov_gid = np.array([idx[n] for n in movable], np.int32)
    touch: Dict[int, List[int]] = {i: [] for i in range(len(movable))}
    mov_of_gid = {int(g): i for i, g in enumerate(mov_gid)}
    for n, mem in enumerate(members):
        for gi in set(mem):
            if gi in mov_of_gid:
                touch[mov_of_gid[gi]].append(n)
    m_max = max(1, max(len(v) for v in touch.values()))
    mov_nets = np.full((len(movable), m_max), -1, np.int32)
    for i, nets_i in touch.items():
        mov_nets[i, :len(nets_i)] = nets_i

    slot_xy, inst_lo, inst_size, slot0 = _legal_slot_tables(
        packed, placement, movable, width, height, mem_columns, io_ring)
    owner0 = np.full(len(slot_xy), -1, np.int32)
    owner0[slot0] = np.arange(len(movable), dtype=np.int32)

    pos0 = np.array([placement[n] for n in inst_order], np.int32)
    occ0 = np.zeros((width, height), np.float32)
    for (x, y) in placement.values():
        occ0[x, y] = 1.0

    # seed the chain state with the full per-net reduction — the Pallas
    # HPWL/bbox kernel on the padded (n_nets, K, 2) pin table
    pins0 = pos0[net_pins]
    bbox0 = np.asarray(ops.net_bboxes(jnp.asarray(pins0),
                                      jnp.asarray(net_mask)))
    bbox0 = np.concatenate([bbox0, np.zeros((1, 4), np.int32)])

    best_slot, best_cost = _anneal(
        jnp.asarray(slot_xy), jnp.asarray(mov_gid), jnp.asarray(inst_lo),
        jnp.asarray(inst_size), jnp.asarray(net_pins),
        jnp.asarray(net_mask), jnp.asarray(mov_nets), jnp.asarray(pos0),
        jnp.asarray(occ0), jnp.asarray(slot0), jnp.asarray(owner0),
        jnp.asarray(bbox0),
        jnp.int32(seed), jnp.float32(gamma), jnp.float32(alpha),
        jnp.float32(t0), jnp.float32(t_min), jnp.float32(ladder),
        n_steps=int(n_steps), n_chains=int(n_chains), cands=int(cands),
        exchange_every=int(exchange_every))
    best_slot = np.asarray(best_slot)
    best_cost = np.asarray(best_cost)
    win = int(np.argmin(best_cost))

    out = {n: (int(x), int(y)) for n, (x, y) in placement.items()}
    for i, name in enumerate(movable):
        x, y = slot_xy[best_slot[win, i]]
        out[name] = (int(x), int(y))
    if return_cost:
        return out, float(best_cost[win])
    return out
