"""End-to-end PnR driver (§3.4): pack → global place → legalize →
anneal → route → STA → bitstream, with the paper's α sweep ("sweeping
α from 1 to 20 and choosing the best result post-routing")."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


from repro.core.graph import Interconnect, Node
from .app import AppGraph
from .packing import PackedGraph, pack
from .global_place import assign_ios, global_place, legalize
from .detailed_place import detailed_place, resolve_place_strategy
from .route import (RoutingError, RoutingResources, RoutingResult, route_app)
from .timing import sta_critical_path


@dataclass
class PnRResult:
    success: bool
    placement: Dict[str, Tuple[int, int]] = field(default_factory=dict)
    #: the packed netlist the flow placed/routed (emulation binds to it)
    packed: Optional[PackedGraph] = None
    routing: Optional[RoutingResult] = None
    timing: Dict[str, float] = field(default_factory=dict)
    alpha: float = 1.0
    wirelength: int = 0
    route_iterations: int = 0
    seconds: float = 0.0
    error: str = ""
    #: router engine that produced the winning route ("python"/"minplus");
    #: with strategy "auto" this records the resolved pick per point
    route_strategy: str = ""
    #: placement engine that annealed the winning placement
    #: ("python" host SA / "batched" device chains); "auto" resolves
    #: once per point and the pick is recorded here
    place_strategy: str = ""
    #: routed-scope :class:`repro.core.analysis.AnalysisReport`, attached
    #: by ``CompiledFabric.place_and_route`` (None when run standalone)
    analysis: Optional[object] = None

    def route_edges(self) -> List[Tuple[Node, Node]]:
        assert self.routing is not None
        return self.routing.all_edges_nodes()


def place_and_route(ic: Interconnect, app: AppGraph,
                    alphas: Sequence[float] = (1.0, 2.0, 4.0),
                    gamma: float = 0.3,
                    sa_steps: int = 200, sa_batch: int = 32,
                    route_iters: int = 40,
                    split_fifo_ctrl_delay: float = 0.0,
                    seed: int = 0,
                    resources: Optional[RoutingResources] = None,
                    route_strategy: str = "python",
                    auto_min_tiles: Optional[int] = None,
                    place_strategy: str = "python") -> PnRResult:
    """Run the full three-stage PnR flow, sweeping α and keeping the best
    post-route critical path (paper §3.4).

    ``route_strategy`` selects the router engine (see
    ``repro.core.pnr.route``): ``"python"`` A* oracle, ``"minplus"``
    device-batched coarse lower bounds, or ``"auto"`` (tile-count switch,
    threshold overridable via ``auto_min_tiles`` /
    ``CANAL_AUTO_MIN_TILES``; the resolved engine is recorded on
    ``PnRResult.route_strategy``).

    ``place_strategy`` selects the annealing-placement engine (see
    ``repro.core.pnr.detailed_place``): ``"python"`` host SA oracle,
    ``"batched"`` device-resident parallel-tempering chains
    (``sa_batch`` chains x ``sa_steps`` steps), or ``"auto"``
    (tile-count switch at ``CANAL_PLACE_AUTO_MIN_TILES``; the resolved
    engine is recorded on ``PnRResult.place_strategy``)."""
    t0 = time.perf_counter()
    W = int(ic.params.get("width", ic.dims()[0]))
    H = int(ic.params.get("height", ic.dims()[1]))
    mem_cols = tuple(getattr(ic, "spec", None).mem_columns
                     if getattr(ic, "spec", None) else ())
    io_ring = bool(getattr(ic, "spec", None).io_ring
                   if getattr(ic, "spec", None) else True)

    packed = pack(app)
    fixed = assign_ios(packed, W, H)
    cont = global_place(packed, W, H, mem_columns=mem_cols, fixed=fixed,
                        seed=seed)
    base_pl = legalize(packed, cont, W, H, mem_columns=mem_cols,
                       io_ring=io_ring, fixed=fixed)
    if resources is None:
        resources = RoutingResources(ic)

    # resolve "auto" once per point so every alpha uses (and the result
    # records) one engine
    place_strat = resolve_place_strategy(W * H, place_strategy)

    best: Optional[PnRResult] = None
    last_err = ""
    for alpha in alphas:
        pl = detailed_place(packed, base_pl, W, H, mem_columns=mem_cols,
                            io_ring=io_ring, gamma=gamma, alpha=alpha,
                            n_steps=sa_steps, batch=sa_batch, seed=seed,
                            strategy=place_strat)
        try:
            routing = route_app(ic, packed, pl, max_iters=route_iters,
                                res=resources, seed=seed,
                                strategy=route_strategy,
                                auto_min_tiles=auto_min_tiles)
        except RoutingError as e:
            last_err = str(e)
            continue
        timing = sta_critical_path(
            packed, routing, pl,
            split_fifo_ctrl_delay=split_fifo_ctrl_delay)
        cand = PnRResult(
            success=True, placement=pl, packed=packed, routing=routing,
            timing=timing, alpha=alpha,
            wirelength=routing.total_wirelength(),
            route_iterations=routing.iterations,
            route_strategy=routing.strategy,
            place_strategy=place_strat)
        if best is None or (cand.timing["critical_path_ns"]
                            < best.timing["critical_path_ns"]):
            best = cand

    if best is None:
        return PnRResult(success=False, packed=packed,
                         error=last_err or "unroutable",
                         seconds=time.perf_counter() - t0)
    best.seconds = time.perf_counter() - t0
    return best
