"""Analytical global placement (§3.4, Eq. 1).

Minimizes Σ_net ( HPWL_estimate + MEM_potential ) where the HPWL estimate is
the quadratic (L2) star model — "In global placement, we use L2 distance to
approximate the HPWL to speed up the algorithm" — solved with the standard
conjugate gradient method (the paper cites APlace's CG approach). Memory
legalization is the usual anchor-iteration: each outer round adds springs
pulling MEM instances to their nearest legal column, then re-solves.

The quadratic solve runs in JAX (matvec + jax.scipy CG), so the placer
itself is a dense array program.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.scipy.sparse.linalg import cg as jax_cg

from .packing import PackedGraph


def _io_ring_positions(w: int, h: int) -> List[Tuple[int, int]]:
    """Clockwise ring coordinates, corners excluded (a corner tile with
    depopulated SB sides can have no legal fabric connection)."""
    ring = [(x, 0) for x in range(1, w - 1)]
    ring += [(w - 1, y) for y in range(1, h - 1)]
    ring += [(x, h - 1) for x in range(w - 2, 0, -1)]
    ring += [(0, y) for y in range(h - 2, 0, -1)]
    return ring


def assign_ios(packed: PackedGraph, w: int, h: int) -> Dict[str,
                                                            Tuple[int, int]]:
    """Spread IO instances evenly around the array border."""
    ios = [n for n, inst in packed.placeable.items()
           if inst.kind in ("io_in", "io_out")]
    ring = _io_ring_positions(w, h)
    if len(ios) > len(ring):
        raise ValueError("more IOs than border tiles")
    stride = max(1, len(ring) // max(len(ios), 1))
    return {name: ring[(i * stride) % len(ring)]
            for i, name in enumerate(ios)}


def global_place(packed: PackedGraph, width: int, height: int,
                 mem_columns: Sequence[int] = (),
                 fixed: Optional[Dict[str, Tuple[int, int]]] = None,
                 outer_iters: int = 4, cg_tol: float = 1e-5,
                 seed: int = 0) -> Dict[str, Tuple[float, float]]:
    """Continuous positions for every placeable instance (fixed IOs pinned).

    Returns name -> (x, y) float positions (pre-legalization).
    """
    if fixed is None:
        fixed = assign_ios(packed, width, height)

    movable = [n for n in packed.placeable if n not in fixed]
    m_idx = {n: i for i, n in enumerate(movable)}
    n_mov = len(movable)
    is_mem = np.array(
        [packed.placeable[n].kind == "mem" for n in movable], dtype=bool)

    if n_mov == 0:
        return {k: (float(x), float(y)) for k, (x, y) in fixed.items()}

    # ---- net pin tables ---------------------------------------------------
    pin_net: List[int] = []
    pin_mov: List[int] = []          # movable index or -1
    pin_fix: List[Tuple[float, float]] = []
    n_nets = 0
    for net in packed.nets:
        members = [net.src[0]] + [s for s, _ in net.sinks]
        members = [m for m in members if m in packed.placeable]
        if len(members) < 2:
            continue
        for mname in members:
            pin_net.append(n_nets)
            if mname in m_idx:
                pin_mov.append(m_idx[mname])
                pin_fix.append((0.0, 0.0))
            else:
                pin_mov.append(-1)
                fx, fy = fixed[mname]
                pin_fix.append((float(fx), float(fy)))
        n_nets += 1

    pin_net_a = jnp.asarray(np.array(pin_net, np.int32))
    pin_mov_a = jnp.asarray(np.array(pin_mov, np.int32))
    pin_fix_a = jnp.asarray(np.array(pin_fix, np.float32))
    net_size = jax.ops.segment_sum(jnp.ones_like(pin_net_a, jnp.float32),
                                   pin_net_a, num_segments=max(n_nets, 1))

    def pin_positions(x: jnp.ndarray) -> jnp.ndarray:
        """x: (n_mov, 2) -> (n_pins, 2)."""
        mov_pos = x[jnp.clip(pin_mov_a, 0, n_mov - 1)]
        return jnp.where((pin_mov_a >= 0)[:, None], mov_pos, pin_fix_a)

    def grad_quadratic(x: jnp.ndarray, anchor_w: jnp.ndarray,
                       anchor_p: jnp.ndarray) -> jnp.ndarray:
        """Gradient of Σ_net Σ_pins ||p − c_net||² + Σ anchors, wrt x."""
        p = pin_positions(x)
        c = (jax.ops.segment_sum(p, pin_net_a, num_segments=max(n_nets, 1))
             / jnp.maximum(net_size, 1.0)[:, None])
        resid = p - c[pin_net_a]
        g = jnp.zeros_like(x)
        g = g.at[jnp.clip(pin_mov_a, 0, n_mov - 1)].add(
            jnp.where((pin_mov_a >= 0)[:, None], resid, 0.0))
        g = g + anchor_w[:, None] * (x - anchor_p)
        return 2.0 * g

    # The cost is quadratic ⇒ grad is affine in x: solve A x = b with CG,
    # where A x = grad(x) − grad(0) and b = −grad(0).
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.uniform([width * .25, height * .25],
                    [width * .75, height * .75],
                    size=(n_mov, 2)).astype(np.float32))
    anchor_w = jnp.zeros((n_mov,), jnp.float32)
    anchor_p = jnp.zeros((n_mov, 2), jnp.float32)
    mem_cols = np.array(sorted(mem_columns), np.float32)

    for outer in range(outer_iters):
        g0 = grad_quadratic(jnp.zeros_like(x), anchor_w, anchor_p)

        def matvec(v):
            return (grad_quadratic(v.reshape(n_mov, 2), anchor_w, anchor_p)
                    - g0).reshape(-1)

        b = (-g0).reshape(-1)
        sol, _ = jax_cg(matvec, b, x0=x.reshape(-1), tol=cg_tol, maxiter=200)
        x = sol.reshape(n_mov, 2)
        x = jnp.clip(x, 0.0, jnp.asarray([width - 1.0, height - 1.0]))

        # MEM_potential: anchor memories to their nearest legal column
        if len(mem_cols) and is_mem.any():
            xx = np.asarray(x)
            tgt = xx.copy()
            col = mem_cols[np.argmin(
                np.abs(xx[:, :1] - mem_cols[None, :]), axis=1)]
            tgt[:, 0] = np.where(is_mem, col, xx[:, 0])
            w_new = np.where(is_mem, 0.5 * (outer + 1), 0.0) \
                .astype(np.float32)
            anchor_w = jnp.asarray(w_new)
            anchor_p = jnp.asarray(tgt.astype(np.float32))

    out = {k: (float(px), float(py)) for k, (px, py) in fixed.items()}
    xx = np.asarray(x)
    for name, i in m_idx.items():
        out[name] = (float(xx[i, 0]), float(xx[i, 1]))
    return out


def legalize(packed: PackedGraph, positions: Dict[str, Tuple[float, float]],
             width: int, height: int, mem_columns: Sequence[int] = (),
             io_ring: bool = True,
             fixed: Optional[Dict[str, Tuple[int, int]]] = None
             ) -> Dict[str, Tuple[int, int]]:
    """Snap continuous positions to distinct legal tiles (greedy nearest)."""
    mem_cols = set(mem_columns)
    occupied: Dict[Tuple[int, int], str] = {}
    out: Dict[str, Tuple[int, int]] = {}
    fixed = fixed or {}

    def legal_for(inst_kind: str, x: int, y: int) -> bool:
        border = x in (0, width - 1) or y in (0, height - 1)
        if inst_kind in ("io_in", "io_out"):
            return border if io_ring else True
        if io_ring and border:
            return False
        if inst_kind == "mem":
            return x in mem_cols if mem_cols else True
        return x not in mem_cols           # PEs keep off mem columns

    for name, pos in fixed.items():
        occupied[pos] = name
        out[name] = pos

    order = sorted((n for n in packed.placeable if n not in fixed),
                   key=lambda n: (packed.placeable[n].kind != "mem",
                                  positions[n]))
    for name in order:
        kind = packed.placeable[name].kind
        px, py = positions[name]
        best = None
        for r in range(width + height):
            cands = []
            for dx in range(-r, r + 1):
                for dy in (-r + abs(dx), r - abs(dx)):
                    x, y = int(round(px)) + dx, int(round(py)) + dy
                    if 0 <= x < width and 0 <= y < height \
                            and (x, y) not in occupied \
                            and legal_for(kind, x, y):
                        cands.append((abs(x - px) + abs(y - py), x, y))
            if cands:
                _, x, y = min(cands)
                best = (x, y)
                break
        if best is None:
            raise ValueError(f"cannot legalize {name} ({kind})")
        occupied[best] = name
        out[name] = best
    return out
