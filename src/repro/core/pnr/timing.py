"""Static timing analysis over a routed application (§3.4, Fig. 7).

The IR's edge weights carry wire/mux delays; cores carry intrinsic delays.
Registers (and register-mode FIFOs) cut timing paths. The application's
achievable clock period is the longest register-to-register (or IO-to-IO)
combinational path: interconnect segments from the routed nets plus core
traversal delays. Application *run time* = critical path × cycle count, the
metric behind Figs. 11/14/15.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


from repro.core.graph import NodeKind
from .packing import PackedGraph
from .route import RoutingResult, RoutingResources


def _net_segment_delays(res: RoutingResources, tree: Dict[int, int],
                        src: int, sinks: Sequence[int]
                        ) -> Dict[int, Tuple[float, int]]:
    """For each sink: (combinational delay of the longest register-free
    suffix reaching it, number of registers crossed on its path)."""
    out: Dict[int, Tuple[float, int]] = {}
    for sink in sinks:
        path = [sink]
        node = sink
        while node != src and node in tree:
            node = tree[node]
            path.append(node)
        path.reverse()
        d = res.nodes[path[0]].delay
        regs = 0
        for a, b in zip(path, path[1:]):
            nb = res.nodes[b]
            if nb.kind == NodeKind.REGISTER:
                regs += 1
                d = 0.0                      # path cut
            d += nb.delay + res.edge_delay_map[(a, b)]
        out[sink] = (d, regs)
    return out


def _sink_arrivals(packed: PackedGraph, result: RoutingResult,
                   core_delay: float = 0.8,
                   split_fifo_ctrl_delay: float = 0.0
                   ) -> List[Tuple[str, str, int, float]]:
    """Per-sink arrival times of every routed net, via the same
    relaxation :func:`sta_critical_path` gates on: entries are
    ``(net_name, sink_instance, sink_node_id, arrival_ns)`` where
    arrival is the combinational path delay into that sink (register
    stages cut the path; split-FIFO control chains add back)."""
    res = result.resources
    # arrival time at each instance output = max over input nets of
    # (arrival at net source + net comb delay) + core delay; registers in
    # the app (packed into PEs) cut paths. Iterate in topological-ish order
    # with relaxation (app graphs are small).
    inst_arrival: Dict[str, float] = {}
    net_by_name = {n.name: n for n in result.nets}
    app_nets = [n for n in packed.nets if n.name in net_by_name]

    arrivals: Dict[Tuple[str, str, int], float] = {}
    for _ in range(len(packed.placeable) + 2):
        changed = False
        for net in app_nets:
            rnet = net_by_name[net.name]
            src_arr = inst_arrival.get(net.src[0], 0.0)
            seg = _net_segment_delays(res, rnet.tree, rnet.src, rnet.sinks)
            for (sink_inst, _), sink_id in zip(net.sinks, rnet.sinks):
                d, regs = seg[sink_id]
                ctrl = regs * split_fifo_ctrl_delay
                arr_in = (src_arr if regs == 0 else 0.0) + d + ctrl
                arrivals[(net.name, sink_inst, sink_id)] = arr_in
                kind = packed.placeable.get(sink_inst)
                cd = core_delay if (kind and kind.kind == "pe") else 0.1
                a = arr_in + cd
                if a > inst_arrival.get(sink_inst, 0.0) + 1e-12:
                    inst_arrival[sink_inst] = a
                    changed = True
        if not changed:
            break
    return [(name, inst, nid, arr)
            for (name, inst, nid), arr in arrivals.items()]


def sta_critical_path(packed: PackedGraph, result: RoutingResult,
                      placement: Dict[str, Tuple[int, int]],
                      core_delay: float = 0.8,
                      split_fifo_ctrl_delay: float = 0.0
                      ) -> Dict[str, float]:
    """Longest combinational path through routed nets + cores.

    split_fifo_ctrl_delay models the paper's split-FIFO drawback: the FIFO
    control signals are not registered at tile boundaries, so chained
    control adds combinational delay proportional to registers crossed.

    Returns {"critical_path_ns", "max_net_delay_ns", "total_wirelength"}.
    """
    arrivals = _sink_arrivals(packed, result, core_delay,
                              split_fifo_ctrl_delay)
    crit = max((arr for _, _, _, arr in arrivals), default=0.0)
    max_net = max((n.delay for n in result.nets), default=0.0)
    return {
        "critical_path_ns": max(crit, max_net),
        "max_net_delay_ns": max_net,
        "total_wirelength": float(result.total_wirelength()),
    }


def sta_net_slacks(packed: PackedGraph, result: RoutingResult,
                   placement: Dict[str, Tuple[int, int]],
                   clock_ns: Optional[float] = None,
                   core_delay: float = 0.8,
                   split_fifo_ctrl_delay: float = 0.0,
                   bins: int = 8) -> Dict:
    """Full per-net slack table extending :func:`sta_critical_path`.

    Each routed net sink gets ``slack = period - arrival`` where the
    period is ``clock_ns`` when given, else the achieved critical path
    (so slack is the headroom to the design's own worst path). Returns::

        {"period_ns", "critical_path_ns", "min_slack_ns",
         "nets": [{"net", "sink", "arrival_ns", "slack_ns"}, ...],
         "histogram": [{"lo", "hi", "count"}, ...]}

    ``nets`` is sorted most-critical first; the histogram spans
    [min_slack, period] in ``bins`` equal buckets — the shape the
    ``sta-slack`` rule and the lint JSON artifact report."""
    arrivals = _sink_arrivals(packed, result, core_delay,
                              split_fifo_ctrl_delay)
    crit = max((arr for _, _, _, arr in arrivals), default=0.0)
    max_net = max((n.delay for n in result.nets), default=0.0)
    crit = max(crit, max_net)
    period = float(clock_ns) if clock_ns is not None else crit
    rows = sorted(({"net": name, "sink": inst,
                    "arrival_ns": arr, "slack_ns": period - arr}
                   for name, inst, _, arr in arrivals),
                  key=lambda r: (r["slack_ns"], r["net"], r["sink"]))
    min_slack = rows[0]["slack_ns"] if rows else period
    hist: List[Dict] = []
    if rows and bins > 0:
        lo, hi = min(min_slack, 0.0), max(period, min_slack)
        width = (hi - lo) / bins or 1.0
        counts = [0] * bins
        for r in rows:
            i = min(int((r["slack_ns"] - lo) / width), bins - 1)
            counts[max(i, 0)] += 1
        hist = [{"lo": lo + i * width, "hi": lo + (i + 1) * width,
                 "count": c} for i, c in enumerate(counts)]
    return {"period_ns": period, "critical_path_ns": crit,
            "min_slack_ns": min_slack, "nets": rows, "histogram": hist}
