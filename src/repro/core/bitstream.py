"""Bitstream generation (§3.3, Fig. 2 right).

Translates a routing result (a set of active IR edges plus core configs)
into addressed configuration words, mirroring garnet-style addressing:

    addr = x << 24 | y << 16 | feature_id << 8 | reg_index
    data = mux select value (or packed PE opcode/const)

and back — the decoder is used by the verification round-trip tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .graph import Node
from .lowering import FabricModule


@dataclass(frozen=True)
class ConfigWord:
    addr: int
    data: int

    @property
    def x(self) -> int:
        return (self.addr >> 24) & 0xFF

    @property
    def y(self) -> int:
        return (self.addr >> 16) & 0xFF

    @property
    def feature(self) -> int:
        return (self.addr >> 8) & 0xFF

    @property
    def reg(self) -> int:
        return self.addr & 0xFF


class BitstreamCodec:
    """Bidirectional mapping config-vector <-> addressed words for a lowered
    fabric. Feature ids are assigned per tile deterministically."""

    def __init__(self, fabric: FabricModule):
        self.fabric = fabric
        # deterministic feature numbering per tile
        feats: Dict[Tuple[int, int], List[str]] = {}
        for slot in fabric.config_slots:
            names = feats.setdefault((slot.x, slot.y), [])
            if slot.feature not in names:
                names.append(slot.feature)
        self.feature_ids: Dict[Tuple[int, int, str], int] = {}
        for (x, y), names in feats.items():
            for i, name in enumerate(sorted(names)):
                self.feature_ids[(x, y, name)] = i
        self._addr_to_slot: Dict[int, int] = {}
        for si, slot in enumerate(fabric.config_slots):
            addr = self._addr(slot.x, slot.y,
                              self.feature_ids[(slot.x, slot.y,
                                                slot.feature)],
                              slot.reg_index)
            if addr in self._addr_to_slot:
                raise ValueError(f"bitstream address collision at {addr:#x}")
            self._addr_to_slot[addr] = si

    @staticmethod
    def _addr(x: int, y: int, feature: int, reg: int) -> int:
        if not (0 <= x < 256 and 0 <= y < 256 and 0 <= feature < 256
                and 0 <= reg < 256):
            raise ValueError("address field overflow")
        return (x << 24) | (y << 16) | (feature << 8) | reg

    # ---------------------------------------------------------------- encode
    def encode(self, config: np.ndarray,
               skip_zeros: bool = True) -> List[ConfigWord]:
        words: List[ConfigWord] = []
        for si, slot in enumerate(self.fabric.config_slots):
            val = int(config[si])
            if skip_zeros and val == 0:
                continue
            feature = self.feature_ids[(slot.x, slot.y, slot.feature)]
            words.append(ConfigWord(
                self._addr(slot.x, slot.y, feature, slot.reg_index), val))
        return words

    # ---------------------------------------------------------------- decode
    def decode(self, words: Sequence[ConfigWord]) -> np.ndarray:
        config = np.zeros(self.fabric.num_config, dtype=np.int32)
        for w in words:
            si = self._addr_to_slot.get(w.addr)
            if si is None:
                raise ValueError(f"unknown config address {w.addr:#x}")
            slot = self.fabric.config_slots[si]
            if not (0 <= w.data < max(2, slot.fanin)):
                raise ValueError(
                    f"select {w.data} out of range for fan-in {slot.fanin}")
            config[si] = w.data
        return config

    # ------------------------------------------------------------- route API
    def words_for_route(self, edges: Sequence[Tuple[Node, Node]]
                        ) -> List[ConfigWord]:
        config = self.fabric.route_to_config(edges)
        return self.encode(config)


def serialize(words: Sequence[ConfigWord]) -> np.ndarray:
    """Pack into the on-the-wire (n, 2) uint32 array format."""
    return np.array([[w.addr, w.data] for w in words], dtype=np.uint32) \
        .reshape(-1, 2)


def deserialize(arr: np.ndarray) -> List[ConfigWord]:
    return [ConfigWord(int(a), int(d)) for a, d in arr.reshape(-1, 2)]
