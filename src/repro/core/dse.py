"""Design-space exploration harness (§4.2).

One function per DSE axis from the paper: switch-box topology, number of
routing tracks, and SB/CB core-port connections — plus the FIFO study of
§4.1. Each returns a list of records consumed by the figure benchmarks and
the tests.

All sweeps run on a shared :class:`SweepExecutor`, the bulk-evaluation
engine behind the paper's "fast design space exploration" claim: it caches
``RoutingResources``/``FabricModule`` per interconnect, evaluates
independent design points concurrently, and emulates every routed app of a
design point as one batched ``FabricModule.run_batch`` scan — the fused
batched Pallas kernel (PE cores evaluated in-kernel, per-app depth
masking) when ``use_pallas=True``, sharded across devices when more than
one is visible.

Design points are :class:`repro.core.spec.InterconnectSpec` objects (legacy
kwargs dicts are canonicalized into specs on entry), and every executor
cache — interconnect, routing resources, lowered fabric — is keyed on
``spec.hardware_digest()``: a serialization-stable content address of the
hardware (execution knobs excluded, so e.g. router-strategy comparisons
share compiled artifacts), instead of the old raw-kwargs tuples that broke
on callables and nested values; records carry the full ``spec.digest()``.
The ``sweep_*`` functions are declarative grids (``spec_grid``) over the
one generic driver, :meth:`SweepExecutor.run_points`.

Host PnR and device emulation are *pipelined*: with
``pipeline_emulation=True`` (default) a design point's emulation batch is
dispatched asynchronously to a per-device emulation queue the moment its
routes are ready, so the router works on the next point while the fabric
of the previous one is still sweeping on device; the emulation futures
are joined before records are returned/persisted.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .area import connection_box_area, switch_box_area
from .pnr import place_and_route
from .pnr.app import BENCH_APPS
from .spec import (InterconnectSpec, SwitchBoxType, spec_from_kwargs,
                   spec_grid)
from .store import STORE_ENV, ResultStore, record_metrics

def _as_spec(point) -> InterconnectSpec:
    """Canonicalize a design point: an InterconnectSpec passes through, a
    legacy kwargs dict is converted (rejecting non-serializable values
    such as callables with an actionable error)."""
    if isinstance(point, InterconnectSpec):
        return point
    if isinstance(point, dict):
        return spec_from_kwargs(**point)
    raise TypeError(
        f"design point must be an InterconnectSpec or a kwargs dict, "
        f"got {type(point).__name__}")


#: sentinel distinguishing "caller never passed it" from any real value
_UNSET = object()

#: PnR knobs folded into :class:`InterconnectSpec` (PR 5) with the
#: executor-level defaults they inherit while the spec leaves them unset.
#: Passing them to ``SweepExecutor.__init__`` still works but is
#: deprecated in favour of the spec field of the same name.
_FOLDED_KNOB_DEFAULTS: Dict[str, Any] = {
    "sa_steps": 60, "sa_batch": 16, "alphas": (2.0,),
    "split_fifo_ctrl_delay": 0.0, "seed": 0, "reg_penalty": 4.0,
}


class SweepExecutor:
    """Reusable bulk design-point evaluator.

    One executor serves many sweeps: per-interconnect caches are shared
    across design points (``RoutingResources`` for the router,
    ``FabricModule`` for emulation), independent points run concurrently on
    a thread pool (JAX releases the GIL during device compute), and all
    routed apps of a point are emulated as a single batch. Records
    accumulate on the executor and can be persisted as JSON for
    ``benchmarks/run.py``.
    """

    def __init__(self, apps: Optional[Dict[str, Callable]] = None,
                 sa_steps: int = _UNSET, sa_batch: int = _UNSET,
                 alphas: Sequence[float] = _UNSET,
                 split_fifo_ctrl_delay: float = _UNSET,
                 max_workers: Optional[int] = None,
                 emulate_cycles: int = 0, use_pallas: bool = True,
                 shard: Optional[bool] = None, seed: int = _UNSET,
                 route_strategy: str = "auto",
                 place_strategy: str = "auto",
                 reg_penalty: float = _UNSET,
                 pipeline_emulation: bool = True,
                 io_chunk: Optional[int] = None,
                 store: Any = None):
        self.apps = apps or BENCH_APPS
        self.sa_steps = self._folded_knob("sa_steps", sa_steps)
        self.sa_batch = self._folded_knob("sa_batch", sa_batch)
        self.alphas = tuple(self._folded_knob("alphas", alphas))
        self.split_fifo_ctrl_delay = self._folded_knob(
            "split_fifo_ctrl_delay", split_fifo_ctrl_delay)
        self.max_workers = max_workers
        self.emulate_cycles = emulate_cycles
        self.use_pallas = use_pallas
        self.shard = shard
        self.seed = self._folded_knob("seed", seed)
        #: router engine (repro.core.pnr.route): "auto" routes big fabrics
        #: with the device-batched min-plus lower bounds
        self.route_strategy = route_strategy
        #: placement engine (repro.core.pnr.detailed_place): "auto" anneals
        #: big fabrics with the device-resident parallel-tempering chains
        self.place_strategy = place_strategy
        self.reg_penalty = self._folded_knob("reg_penalty", reg_penalty)
        self.pipeline_emulation = pipeline_emulation
        #: ext-IO streaming chunk for long stimulus traces (HBM-gridded
        #: fused kernel); None keeps the per-cycle scan
        self.io_chunk = io_chunk
        #: persistent spec-addressed result store: a ResultStore, a root
        #: path, False (disable even if the env names a store), or None —
        #: attach the CANAL_RESULT_STORE store when the env var is set
        self.store = self._open_store(store)
        self._lock = threading.Lock()
        self._ic_cache: Dict[Tuple, Any] = {}
        self._res_cache: Dict[Tuple, Any] = {}
        self._fab_cache: Dict[Tuple, Any] = {}
        self._inflight: Dict[str, Future] = {}
        self._emu_pool: Optional[ThreadPoolExecutor] = None
        self._emu_devices: List[Any] = []
        self._emu_rr = 0
        self._active_runs = 0
        self._pending: List[Future] = []
        self.records: List[Dict] = []
        #: observability counters for the store-backed execution path
        self.store_hits = 0      # records served from the store
        self.store_misses = 0    # store consulted, nothing usable
        self.coalesced = 0       # requests piggybacked on an in-flight one
        self.pnr_computations = 0  # design points actually placed+routed
        #: design points rejected by the static pre-screen (PnR skipped);
        #: one per *computed* rejection — store hits on a rejected record
        #: count as store_hits, not here
        self.analysis_rejections = 0
        #: stored records refused because their analysis verdict was
        #: produced by a different rule set (see :meth:`record_usable`);
        #: each one re-analyzes (and re-routes) instead of serving stale
        self.stale_rule_set = 0
        self._analysis_cache: Dict[Tuple, Any] = {}

    @staticmethod
    def _folded_knob(name: str, value):
        """Resolve a deprecated ``__init__`` PnR knob: unset -> the
        executor default; explicitly passed -> deprecation pointing at
        the spec field that replaced it (the value still applies, as the
        default for specs that leave the field unset)."""
        if value is _UNSET:
            return _FOLDED_KNOB_DEFAULTS[name]
        warnings.warn(
            f"SweepExecutor({name}=...) is deprecated: set the spec "
            f"field '{name}' on the design point instead — "
            f"InterconnectSpec(..., {name}=...). The executor value is "
            f"only a default for specs that leave '{name}' unset.",
            DeprecationWarning, stacklevel=3)
        return value

    @staticmethod
    def _open_store(store) -> Optional[ResultStore]:
        if store is False:
            return None
        if store is None:
            root = os.environ.get(STORE_ENV)
            return ResultStore(root) if root else None
        if isinstance(store, ResultStore):
            return store
        return ResultStore(str(store))

    # ------------------------------------------------------------- caches
    @staticmethod
    def _key(point) -> Tuple:
        """Canonical cache key for a design point (a spec, or a kwargs
        dict canonicalized into one by :func:`_as_spec`).

        Keys on ``spec.hardware_digest()`` — stable across processes,
        key orderings and value spellings, and shared across points that
        differ only in execution knobs (route strategy etc.), since the
        cached artifacts (IR, routing resources, lowered fabric) depend
        only on the hardware. Callables and unknown kwargs are rejected
        with an actionable error instead of the old silent ``str(fn)``
        key (whose embedded ``0x...`` id changed every run) or a raw
        ``TypeError``."""
        return ("spec", _as_spec(point).hardware_digest())

    def interconnect(self, spec=None, **ic_kwargs):
        """The per-executor interconnect cache, keyed on the design
        point's ``spec.hardware_digest()``. Accepts a spec positionally
        or legacy generator kwargs.

        The cached entry is compiled from ``spec.hardware_spec()`` —
        execution knobs cleared — because it is shared across every
        knob variant of the same hardware: the IR's own stamped identity
        (``ic.params["spec_digest"]``, ``ic.spec``) must describe what
        all of them have in common, not whichever variant got compiled
        first."""
        if spec is not None and ic_kwargs:
            raise TypeError("pass either a spec or kwargs, not both")
        spec = _as_spec(spec if spec is not None else ic_kwargs)
        key = self._key(spec)
        with self._lock:
            ic = self._ic_cache.get(key)
        if ic is None:
            from .passes import PassManager
            ic = PassManager().run(spec.hardware_spec())
            with self._lock:
                ic = self._ic_cache.setdefault(key, ic)
        return ic

    def analysis_report(self, spec, ic=None):
        """Static-analysis report for a design point, cached per
        hardware digest (analysis reads only the hardware IR, so every
        execution-knob variant shares one verdict). This is the DSE
        pre-screen: ``_compute_point`` consults it before spending a PnR
        run on a statically-invalid fabric."""
        from .analysis import analyze
        spec = _as_spec(spec)
        key = self._key(spec)
        with self._lock:
            report = self._analysis_cache.get(key)
        if report is None:
            if ic is None:
                ic = self.interconnect(spec)
            report = analyze(ic, spec=spec.hardware_spec())
            with self._lock:
                report = self._analysis_cache.setdefault(key, report)
        return report

    def resources(self, ic, key: Tuple,
                  reg_penalty: Optional[float] = None):
        """Shared ``RoutingResources`` (adjacency, base costs, coarse
        graph), keyed on ``(interconnect, reg_penalty)`` — a penalty
        change must not hand back arrays priced for a different one (the
        old per-interconnect key silently would have)."""
        from .pnr.route import RoutingResources
        rp = self.reg_penalty if reg_penalty is None else reg_penalty
        ckey = (key, float(rp))
        with self._lock:
            res = self._res_cache.get(ckey)
        if res is None:
            res = RoutingResources(ic, reg_penalty=rp)
            with self._lock:
                res = self._res_cache.setdefault(ckey, res)
        return res

    def fabric(self, ic, key: Tuple):
        from .lowering import compile_interconnect
        with self._lock:
            fab = self._fab_cache.get(key)
        if fab is None:
            fab = compile_interconnect(ic, use_pallas=self.use_pallas)
            with self._lock:
                fab = self._fab_cache.setdefault(key, fab)
        return fab

    # ----------------------------------------------------- emulation queue
    def _emu_queue(self) -> Tuple[ThreadPoolExecutor, Any]:
        """Lazily build the per-device emulation queue and pick the next
        device round-robin. With batch-axis sharding active a single
        queue feeds ``run_batch`` (which already spans every device);
        otherwise each device gets its own dispatch thread and points are
        distributed across them."""
        import jax

        with self._lock:
            if self._emu_pool is None:
                devs = jax.devices()
                use_shard = ((len(devs) > 1) if self.shard is None
                             else self.shard)
                self._emu_devices = ([None] if use_shard and len(devs) > 1
                                     else list(devs))
                self._emu_pool = ThreadPoolExecutor(
                    max_workers=len(self._emu_devices),
                    thread_name_prefix="dse-emu")
            dev = self._emu_devices[self._emu_rr % len(self._emu_devices)]
            self._emu_rr += 1
        return self._emu_pool, dev

    def _submit_emulation(self, fab, routed: List[Tuple[str, Any, Any]],
                          out: Dict[str, Dict],
                          io_chunk: Optional[int] = None,
                          on_done: Optional[Callable[[], None]] = None,
                          pending: Optional[List[Future]] = None
                          ) -> Future:
        """Dispatch one design point's emulation batch asynchronously; the
        returned future merges the report into ``out`` when done (then
        runs ``on_done`` — the store write-back hook, so a record is only
        persisted once complete). Router threads keep running while the
        device sweeps. The future is registered on the global pending
        list (join-all via :meth:`join_pending`/:meth:`save_json`) and,
        when ``pending`` is given, on that per-run list too — so a sweep
        joins exactly its own batches even when several sweeps share the
        executor."""
        pool, dev = self._emu_queue()

        def work():
            emu = self._emulate_batch(fab, routed, device=dev,
                                      io_chunk=io_chunk)
            for name, info in emu.items():
                out[name]["emulation"] = info
            if on_done is not None:
                on_done()

        fut = pool.submit(work)
        with self._lock:
            self._pending.append(fut)
            if pending is not None:
                pending.append(fut)
        return fut

    def join_pending(self, pending: Optional[List[Future]] = None) -> None:
        """Block until dispatched emulation batches have merged their
        reports (re-raising the first worker error), then release the
        queue threads — the pool is rebuilt lazily on the next dispatch,
        so repeated sweeps don't accumulate idle workers.

        With ``pending`` (the per-run list a ``run_points`` call threaded
        through its dispatches) only *that run's* futures are joined —
        a concurrent sweep on the same executor keeps ownership of its
        own batches, and its records can never be returned with their
        emulation still in flight. Joined futures are also retired from
        the global list. Without ``pending`` this is a join-*all*
        barrier over every outstanding future (the ``save_json`` /
        close-style drain).

        The pool is only torn down while no ``run_points`` call is
        active: a concurrent sweep must never have its dispatch land on
        a pool another sweep just shut down."""
        source = self._pending if pending is None else pending
        try:
            while True:
                with self._lock:
                    if not source:
                        break
                    fut = source.pop()
                try:
                    fut.result()
                finally:
                    if pending is not None:
                        with self._lock:
                            try:
                                self._pending.remove(fut)
                            except ValueError:
                                pass
        finally:
            with self._lock:
                idle = self._active_runs == 0
                pool = self._emu_pool if idle else None
                if idle:
                    self._emu_pool = None
            if pool is not None:
                pool.shutdown(wait=True)

    # ----------------------------------------------------- point execution
    def _emulate_batch(self, fab, routed: List[Tuple[str, Any, Any]],
                       device: Any = None,
                       io_chunk: Optional[int] = None) -> Dict[str, Dict]:
        """Emulate all routed apps of one design point as a single batch.

        ``routed``: (name, packed, PnRResult) triples on ``fab``. Drives a
        common counter stimulus on every app input and records the output
        checksum — the bulk validation pass of the batched DSE engine.
        ``device`` pins the batch to one accelerator (the per-device
        emulation queues of the async pipeline); None keeps the default
        placement (sharded across devices when enabled).
        """
        import numpy as np
        from repro.fabric import AppEmulator, run_apps_batch

        if io_chunk is None:
            io_chunk = self.io_chunk
        emulators, inputs, names = [], [], []
        T = self.emulate_cycles
        for name, packed, result in routed:
            emu = AppEmulator.from_pnr(fab, packed, result)
            ins = {}
            for inst_name, inst in packed.placeable.items():
                if inst.kind == "io_in":
                    coord = result.placement[inst_name]
                    ins[coord] = np.arange(1, T + 1, dtype=np.int32)
            emulators.append(emu)
            inputs.append(ins)
            names.append(name)
        if device is not None:
            import jax
            with jax.default_device(device):
                outs = run_apps_batch(emulators, inputs, T, shard=False,
                                      io_chunk=io_chunk)
        else:
            outs = run_apps_batch(emulators, inputs, T, shard=self.shard,
                                  io_chunk=io_chunk)
        report: Dict[str, Dict] = {}
        for name, emu, out in zip(names, emulators, outs):
            checksum = int(sum(int(np.asarray(v, np.int64).sum())
                               for v in out.values()) & 0xFFFFFFFF)
            report[name] = {"depth": emu.depth, "cycles": T,
                            "out_checksum": checksum}
        return report

    # -------------------------------------------------- store-backed flow
    def resolve(self, point) -> InterconnectSpec:
        """Pin a design point for execution: fill every PnR knob the spec
        leaves unset with this executor's default. The resolved spec's
        ``digest()`` fully determines the resulting record — it is the
        address in the persistent :class:`ResultStore` (its
        ``hardware_digest()`` is unchanged, so compiled-artifact caches
        still pool across knob variants)."""
        return _as_spec(point).with_execution_defaults(
            route_strategy=self.route_strategy,
            place_strategy=self.place_strategy,
            reg_penalty=self.reg_penalty, alphas=self.alphas,
            sa_steps=self.sa_steps, sa_batch=self.sa_batch,
            seed=self.seed,
            split_fifo_ctrl_delay=self.split_fifo_ctrl_delay)

    def record_usable(self, rec: Dict) -> bool:
        """Whether a stored record covers this executor's workload: a
        *superset* of this executor's app set (``ResultStore.put`` merges
        app maps, so a shared store accumulates the union — the lookup
        serves a filtered view matching ``self.apps``), and at least the
        requested emulation per app — an app emulated for ``>=`` the
        requested cycles is covered (its ``emulation`` entry then
        reflects the longer stored run), so executors with differing
        ``emulate_cycles`` sharing one store converge on the deepest
        record instead of thrashing overwrites. Merged records stamp
        ``emulate_cycles`` per app entry; unmerged ones fall back to the
        record-level field, and an app with no cycle claim at all cannot
        serve an emulating executor. The single definition of a store
        *hit* — the serving layer delegates here.

        App identity is *by name*: the store trusts that one app name
        denotes one workload. Distinct workloads registered under the
        same name against a shared store would silently serve each
        other's records — give them distinct names (or stores)."""
        apps = rec.get("apps")
        if not isinstance(apps, dict) or not set(self.apps) <= set(apps):
            return False
        # analysis verdicts are only as good as the rule set that
        # produced them: a record stamped by an older (or no) rule set
        # must re-analyze, not serve a stale clean/rejected verdict.
        # Records with no analysis dict at all predate the analyzer and
        # carry no verdict to go stale.
        analysis = rec.get("analysis")
        if isinstance(analysis, dict):
            from .analysis import rule_set_version
            if analysis.get("rule_set") != rule_set_version():
                with self._lock:
                    self.stale_rule_set += 1
                return False
        if self.emulate_cycles == 0:
            return True
        rec_cycles = rec.get("emulate_cycles")
        for name in self.apps:
            entry = apps[name]
            stored = entry.get("emulate_cycles", rec_cycles) \
                if isinstance(entry, dict) else rec_cycles
            if not (isinstance(stored, int)
                    and stored >= self.emulate_cycles):
                return False
        return True

    def _store_lookup(self, digest: str) -> Optional[Dict]:
        """Consult the store; unusable records (see :meth:`record_usable`)
        are misses and get recomputed + merged in. A usable record whose
        merged app map is a *strict* superset of this executor's apps is
        served as a filtered view (only ``self.apps`` entries, metrics
        recomputed over that view) so sweep consumers see the shape they
        asked for."""
        if self.store is None:
            return None
        rec = self.store.get(digest)
        usable = rec is not None and self.record_usable(rec)
        with self._lock:
            if usable:
                self.store_hits += 1
            else:
                self.store_misses += 1
        if not usable:
            return None
        if set(rec["apps"]) != set(self.apps):
            rec = dict(rec, apps={name: rec["apps"][name]
                                  for name in self.apps})
            rec["metrics"] = record_metrics(rec)
        return rec

    def probe(self, digest: str) -> Optional[Dict]:
        """Public single store probe for a resolved digest: the usable
        record, or None (counted as exactly one store hit or miss). The
        serving layer's cold-point path probes here once and threads the
        verdict into ``run_points(..., assume_cold=True)`` — each cold
        point hits the store exactly once instead of probing again
        inside ``run_point``."""
        return self._store_lookup(digest)

    def _store_put(self, spec: InterconnectSpec, rec: Dict) -> None:
        if self.store is not None:
            self.store.put(spec, rec)

    def run_point(self, point,
                  extra: Optional[Dict] = None,
                  defer_emulation: bool = False,
                  pending: Optional[List[Future]] = None,
                  assume_cold: bool = False) -> Dict:
        """One design point -> one sweep record, store-backed.

        ``point`` is an :class:`InterconnectSpec` (or a legacy kwargs
        dict, canonicalized into one); unset spec knobs resolve against
        the executor defaults (:meth:`resolve`). The resolved digest is
        consulted in the persistent store first (a hit skips PnR and
        emulation entirely); concurrent requests for the same digest
        coalesce onto one in-flight computation; completed records are
        written back to the store.

        ``defer_emulation`` dispatches the emulation batch to the async
        per-device queue instead of running it inline; the record's
        ``emulation`` entries appear once the future lands, and the
        store write-back rides on that future. ``pending`` is the
        caller's per-run future list: the dispatched batch — or, for a
        coalesced request, the leader's batch — is registered there so
        ``join_pending(pending)`` waits on exactly the futures this
        run's records depend on (callers without a list join-all via
        bare :meth:`join_pending`).

        ``assume_cold=True`` skips the leader's store probe: the caller
        asserts it already probed this point's digest (via
        :meth:`probe`) and missed — the single-probe contract of the
        serving layer. Coalescing still applies, so a concurrent
        same-digest computation is joined, not repeated."""
        # count as an active run for the whole body: the emulation-queue
        # teardown in join_pending must not shut down a pool this call
        # is about to dispatch on — direct deferred run_point calls need
        # the same protection run_points gets
        with self._lock:
            self._active_runs += 1
        try:
            return self._run_point(point, extra, defer_emulation, pending,
                                   assume_cold)
        finally:
            with self._lock:
                self._active_runs -= 1

    def _run_point(self, point, extra: Optional[Dict],
                   defer_emulation: bool,
                   pending: Optional[List[Future]],
                   assume_cold: bool = False) -> Dict:
        spec = self.resolve(point)
        digest = spec.digest()
        with self._lock:
            leader = digest not in self._inflight
            if leader:
                fut = self._inflight[digest] = Future()
            else:
                fut = self._inflight[digest]
        if not leader:
            # in-flight futures resolve to (record, emulation-future):
            # a follower's record may still be awaiting the leader's
            # deferred emulation merge, so the follower must adopt that
            # future into its own run's pending list
            rec, emu_fut = fut.result()
            with self._lock:
                self.coalesced += 1
                if (emu_fut is not None and pending is not None
                        and emu_fut not in pending):
                    pending.append(emu_fut)
            return self._finish_record(rec, extra)
        try:
            emu_fut = None
            rec = None if assume_cold else self._store_lookup(digest)
            if rec is None:
                rec, emu_fut = self._compute_point(
                    spec, digest, defer_emulation, pending)
            fut.set_result((rec, emu_fut))
        except BaseException as e:
            fut.set_exception(e)
            with self._lock:
                self._inflight.pop(digest, None)
            raise
        if emu_fut is None:
            with self._lock:
                self._inflight.pop(digest, None)
        else:
            # keep the in-flight entry alive until the deferred emulation
            # has merged and the store write-back has landed: a same-digest
            # request arriving in that tail coalesces onto this record
            # instead of missing the store and redoing PnR + emulation
            def _retire(_done, d=digest, f=fut):
                with self._lock:
                    if self._inflight.get(d) is f:
                        del self._inflight[d]
            emu_fut.add_done_callback(_retire)
        return self._finish_record(rec, extra)

    @staticmethod
    def _finish_record(rec: Dict, extra: Optional[Dict]) -> Dict:
        """Per-caller view of a (possibly shared) record: sweep labels
        (``extra``) merge into a shallow copy, so one stored record can
        serve grids that label it differently. Nested app dicts stay
        shared — a deferred emulation merge lands in every view."""
        out = dict(extra or {})
        out.update(rec)
        return out

    def _compute_point(self, spec: InterconnectSpec, digest: str,
                       defer_emulation: bool,
                       pending: Optional[List[Future]] = None
                       ) -> Tuple[Dict, Optional[Future]]:
        """The actual PnR + emulation work for a store miss. All PnR
        knobs come off the resolved ``spec`` — the digest is the whole
        story of how this record was produced. Returns the record plus
        the deferred emulation future (None when emulation ran inline
        or there was nothing to emulate) so coalesced followers can wait
        on it too."""
        t0 = time.perf_counter()
        ic = self.interconnect(spec)
        key = self._key(spec)
        # static pre-screen: a fabric the analyzer rejects gets a record
        # (the verdict persists — re-sweeps hit the store, not PnR) but
        # no PnR/emulation minutes. Free pruning for machine-generated
        # spec streams, where malformed points are routine.
        from .analysis import rule_set_version
        report = self.analysis_report(spec, ic)
        analysis = report.to_dict(max_diagnostics=16)
        # verdict provenance: which rule set judged this record (see
        # record_usable — a stamp mismatch makes the record unusable)
        analysis["rule_set"] = rule_set_version()
        if not report.ok():
            with self._lock:
                self.analysis_rejections += 1
            msg = ("static analysis rejected the fabric: "
                   + ", ".join(sorted({d.rule for d in report.errors})))
            out = {name: {"success": False,
                          "skipped": "static-analysis",
                          "critical_path_ns": float("inf"),
                          "wirelength": 0, "route_iterations": 0,
                          "seconds": 0.0, "error": msg,
                          "route_strategy": None,
                          "place_strategy": None}
                   for name in self.apps}
            rec = {"spec_digest": digest,
                   "hardware_digest": spec.hardware_digest(),
                   "apps": out, "analysis": analysis,
                   "sb_area": switch_box_area(ic),
                   "cb_area": connection_box_area(ic),
                   "emulate_cycles": self.emulate_cycles,
                   "gen_pnr_seconds": time.perf_counter() - t0}
            rec["metrics"] = record_metrics(rec)
            self._store_put(spec, rec)
            return rec, None
        with self._lock:
            self.pnr_computations += 1
        res = self.resources(ic, key, reg_penalty=spec.reg_penalty)
        out: Dict[str, Dict] = {}
        routed: List[Tuple[str, Any, Any]] = []
        for name, mk in self.apps.items():
            app = mk()
            r = place_and_route(
                ic, app, alphas=spec.alphas, sa_steps=spec.sa_steps,
                sa_batch=spec.sa_batch, resources=res, seed=spec.seed,
                split_fifo_ctrl_delay=spec.split_fifo_ctrl_delay,
                route_strategy=spec.route_strategy,
                auto_min_tiles=spec.auto_min_tiles,
                place_strategy=spec.place_strategy)
            out[name] = {
                "success": r.success,
                "critical_path_ns": r.timing.get("critical_path_ns",
                                                 float("inf")),
                "wirelength": r.wirelength,
                "route_iterations": r.route_iterations,
                "seconds": r.seconds,
                "error": r.error,
                # resolved engines ("auto" calibration data, ROADMAP item)
                "route_strategy": r.route_strategy,
                "place_strategy": r.place_strategy,
            }
            if r.success:
                # routed-scope verdict + static metrics persist per app
                # (inside the app entry, so they survive store merges —
                # merge_records unions apps and recomputes record-level
                # metrics from the merged population)
                from .analysis import analyze as run_rules
                from .analysis import routed_static_metrics
                routed_rep = run_rules(ic, spec=spec.hardware_spec(),
                                       scope="routed", pnr=r)
                out[name]["routed_analysis"] = routed_rep.to_dict(
                    max_diagnostics=4)
                out[name].update(routed_static_metrics(
                    r.packed, r.routing, r.placement))
            if r.success and self.emulate_cycles:
                routed.append((name, r.packed, r))
        rec: Dict = {"spec_digest": digest,
                     "hardware_digest": spec.hardware_digest(),
                     "apps": out,
                     "analysis": analysis,
                     "sb_area": switch_box_area(ic),
                     "cb_area": connection_box_area(ic),
                     "emulate_cycles": self.emulate_cycles}
        if routed and not defer_emulation:
            fab = self.fabric(ic, key)
            emu = self._emulate_batch(
                fab, routed, io_chunk=spec.emulate_io_chunk or self.io_chunk)
            for name, info in emu.items():
                out[name]["emulation"] = info
        # wall time includes interconnect generation (cache misses pay it,
        # cache hits legitimately report the shared-cache speedup); with
        # deferred emulation it covers host PnR only — emulation overlaps
        rec["gen_pnr_seconds"] = time.perf_counter() - t0
        # frontier-relevant scalars (area / critical path / routability)
        # persist on the record so search and serving consumers never
        # re-derive them from the app map
        rec["metrics"] = record_metrics(rec)
        emu_fut = None
        if routed and defer_emulation:
            # persist only once the emulation report has merged — the
            # store must never serve a half-built record
            emu_fut = self._submit_emulation(
                self.fabric(ic, key), routed, out,
                io_chunk=spec.emulate_io_chunk or self.io_chunk,
                on_done=lambda: self._store_put(spec, rec),
                pending=pending)
        else:
            self._store_put(spec, rec)
        return rec, emu_fut

    def run_points(self, points: Sequence[Tuple[Any, Dict]],
                   record: bool = True,
                   assume_cold: bool = False) -> List[Dict]:
        """The generic sweep driver: evaluate ``(point, extra)`` design
        points — points are :class:`InterconnectSpec` objects (see
        :func:`repro.core.spec.spec_grid` for declarative grids) or
        legacy kwargs dicts — concurrently when the pool has more than
        one worker. Order of records matches ``points``.

        With ``pipeline_emulation`` the device emulation of point k runs
        under the host PnR of point k+1 (async dispatch); every emulation
        future *this run* dispatched (or coalesced onto) is joined before
        the records are returned — ownership is per run, so concurrent
        ``run_points`` calls on one executor never steal each other's
        joins or return records with emulation still in flight.

        ``record=False`` skips the ``self.records`` accumulator (the
        :meth:`save_json` batch workflow) — long-lived callers like the
        serving layer would otherwise grow it without bound.
        ``assume_cold=True`` is the serving layer's single-probe path:
        the caller already probed every point's digest and missed, so
        leaders skip the redundant second probe (see :meth:`run_point`).
        """
        workers = self.max_workers
        if workers is None:
            workers = min(len(points), os.cpu_count() or 1, 4)
        defer = self.pipeline_emulation and self.emulate_cycles > 0
        pending: List[Future] = []
        with self._lock:
            self._active_runs += 1
        try:
            if workers <= 1 or len(points) <= 1:
                recs = [self.run_point(kw, extra, defer_emulation=defer,
                                       pending=pending,
                                       assume_cold=assume_cold)
                        for kw, extra in points]
            else:
                with ThreadPoolExecutor(max_workers=workers) as pool:
                    futs = [pool.submit(self.run_point, kw, extra, defer,
                                        pending, assume_cold)
                            for kw, extra in points]
                    recs = [f.result() for f in futs]
        finally:
            with self._lock:
                self._active_runs -= 1
            self.join_pending(pending)
        if record:
            self.records.extend(recs)
        return recs

    def run_specs(self, specs: Sequence[Any], record: bool = False,
                  assume_cold: bool = False) -> List[Dict]:
        """Batch-evaluate bare specs (no per-point ``extra`` labels) —
        the search driver's hook: one :meth:`run_points` call per
        candidate batch, store-memoized, ``record=False`` by default so
        adaptive query streams don't grow the accumulator."""
        return self.run_points([(s, {}) for s in specs], record=record,
                               assume_cold=assume_cold)

    def stats(self) -> Dict[str, int]:
        """Snapshot of the store/compute observability counters."""
        with self._lock:
            return {"store_hits": self.store_hits,
                    "store_misses": self.store_misses,
                    "coalesced": self.coalesced,
                    "pnr_computations": self.pnr_computations,
                    "analysis_rejections": self.analysis_rejections,
                    "stale_rule_set": self.stale_rule_set}

    @staticmethod
    def _record_key(rec: Dict) -> Tuple:
        """Dedup identity of a sweep record: the resolved spec digest
        (which pins every PnR knob — α sweep included) plus the app set.
        Records predating the digest field fall back to object identity
        so nothing is silently merged."""
        digest = rec.get("spec_digest")
        if digest is None:
            return ("id", id(rec))
        return (digest, tuple(sorted(rec.get("apps", {}))))

    def dedup_records(self) -> List[Dict]:
        """Accumulated records with repeats collapsed: repeated
        ``sweep_*`` calls on one executor re-deliver the same design
        point (now often straight from the store); only the newest record
        per ``(spec_digest, apps)`` survives, at its first position."""
        out: List[Dict] = []
        pos: Dict[Tuple, int] = {}
        for rec in self.records:
            k = self._record_key(rec)
            if k in pos:
                out[pos[k]] = rec
            else:
                pos[k] = len(out)
                out.append(rec)
        return out

    def save_json(self, path: str) -> str:
        """Persist accumulated records (consumed by benchmarks/run.py),
        deduplicated (:meth:`dedup_records` — repeated sweeps no longer
        re-persist overlapping records). Joins any still-pending
        emulation futures first."""
        self.join_pending()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.dedup_records(), f, indent=2, default=str)
        return path


def _executor_for(executor: Optional[SweepExecutor],
                  apps: Optional[Dict[str, Callable]],
                  sa_steps: Optional[int]) -> SweepExecutor:
    """Shared-executor plumbing for the sweep functions: a passed executor
    carries its own apps/sa_steps, so per-call overrides would be silently
    dropped — reject the ambiguous combination instead."""
    if executor is not None:
        if apps is not None or sa_steps is not None:
            raise ValueError(
                "pass apps/sa_steps on the SweepExecutor, not alongside it")
        return executor
    if sa_steps is None:
        return SweepExecutor(apps=apps)
    # sweep-function convenience path: route the legacy sa_steps override
    # through the executor default without the __init__ deprecation (the
    # per-call knob is this helper's documented contract; direct __init__
    # use warns). Assigning the resolved default directly avoids
    # catch_warnings(), which mutates process-global filter state and is
    # not thread-safe under the serving pool.
    ex = SweepExecutor(apps=apps)
    ex.sa_steps = sa_steps
    return ex


def fifo_area_study(num_tracks: int = 5, track_width: int = 16
                    ) -> List[Dict]:
    """§4.1 / Fig. 8: static baseline vs full-FIFO vs split-FIFO SB area."""
    from .passes import PassManager
    ic = PassManager().run(InterconnectSpec(
        width=8, height=8, num_tracks=num_tracks, track_width=track_width,
        sb_type=SwitchBoxType.WILTON, reg_density=1.0))
    base = switch_box_area(ic)
    recs = [{"design": "static_baseline", "sb_area": base, "overhead": 0.0}]
    for mode in ("full", "split"):
        a = switch_box_area(ic, rv=mode)
        recs.append({"design": f"fifo_{mode}", "sb_area": a,
                     "overhead": a / base - 1.0})
    return recs


def sweep_num_tracks(tracks: Sequence[int] = (2, 3, 4, 5, 6),
                     apps: Optional[Dict[str, Callable]] = None,
                     width: int = 8, height: int = 8,
                     sa_steps: Optional[int] = None, track_fc: float = 1.0,
                     executor: Optional[SweepExecutor] = None
                     ) -> List[Dict]:
    """§4.2.1 / Figs. 10–11: SB/CB area and application runtime vs tracks.

    Declarative form: one base spec, a ``num_tracks`` axis, the generic
    :meth:`SweepExecutor.run_points` driver."""
    ex = _executor_for(executor, apps, sa_steps)
    base = InterconnectSpec(width=width, height=height, io_ring=True,
                            sb_type=SwitchBoxType.WILTON, reg_density=1.0,
                            cb_track_fc=track_fc, sb_track_fc=track_fc)
    return ex.run_points(spec_grid(base, {"num_tracks": tuple(tracks)}))


def sweep_sb_topology(topologies: Sequence[SwitchBoxType] = (
        SwitchBoxType.WILTON, SwitchBoxType.DISJOINT, SwitchBoxType.IMRAN),
        apps: Optional[Dict[str, Callable]] = None,
        num_tracks: int = 4, width: int = 8, height: int = 8,
        sa_steps: Optional[int] = None, track_fc: float = 0.5,
        executor: Optional[SweepExecutor] = None) -> List[Dict]:
    """§4.2.1 / Fig. 9: topology routability (Wilton routes, Disjoint
    fails). track_fc < 1 reflects depopulated core-port track connections:
    a route is then pinned to its starting track *class*, which Disjoint
    can never leave (its fatal restriction) while Wilton re-permutes
    tracks at every turn."""
    ex = _executor_for(executor, apps, sa_steps)
    base = InterconnectSpec(width=width, height=height,
                            num_tracks=num_tracks, io_ring=True,
                            reg_density=1.0,
                            cb_track_fc=track_fc, sb_track_fc=track_fc)
    recs = ex.run_points(spec_grid(
        base, {"sb_type": tuple(topologies)},
        label=lambda s: {"topology": s.sb_type.value}))
    for rec in recs:
        rec["n_routed"] = sum(1 for r in rec["apps"].values()
                              if r["success"])
        rec["n_apps"] = len(rec["apps"])
    return recs


def sweep_port_connections(kind: str,
                           sides: Sequence[int] = (4, 3, 2),
                           apps: Optional[Dict[str, Callable]] = None,
                           num_tracks: int = 5, width: int = 8,
                           height: int = 8, sa_steps: Optional[int] = None,
                           executor: Optional[SweepExecutor] = None
                           ) -> List[Dict]:
    """§4.2.2 / Figs. 12–15: depopulate SB (core-output) or CB (core-input)
    side connections and measure area + runtime."""
    if kind not in ("sb", "cb"):
        raise ValueError("kind must be 'sb' or 'cb'")
    ex = _executor_for(executor, apps, sa_steps)
    base = InterconnectSpec(width=width, height=height,
                            num_tracks=num_tracks, io_ring=True,
                            sb_type=SwitchBoxType.WILTON, reg_density=1.0)
    axis = f"{kind}_sides"
    return ex.run_points(spec_grid(
        base, {axis: tuple(sides)},
        label=lambda s: {"kind": kind, "sides": getattr(s, axis)}))


def generation_speed(sizes: Sequence[int] = (4, 8, 16, 32)) -> List[Dict]:
    """Abstract claim: "fast design space exploration" — IR generation +
    lowering speed vs array size."""
    from .lowering import compile_interconnect
    from .passes import PassManager
    recs = []
    for s in sizes:
        t0 = time.perf_counter()
        ic = PassManager().run(InterconnectSpec(width=s, height=s,
                                                num_tracks=5,
                                                reg_density=1.0))
        t1 = time.perf_counter()
        fab = compile_interconnect(ic)
        t2 = time.perf_counter()
        recs.append({"size": s, "nodes": fab.arrays.num_nodes,
                     "gen_seconds": t1 - t0, "lower_seconds": t2 - t1})
    return recs


def batched_vs_serial_emulation(width: int = 6, height: int = 6,
                                num_tracks: int = 4, batch: int = 8,
                                cycles: int = 16, use_pallas: bool = True,
                                seed: int = 0) -> Dict:
    """Micro-DSE: emulate B random fabric configurations serially
    (``run`` per config) vs as one batch (``run_batch``). Returns wall
    clocks and asserts bit-identical observations — the engine behind
    ``benchmarks/dse_speed.py``'s batched-vs-serial comparison."""
    import numpy as np
    import jax.numpy as jnp

    fab, cfgs, ext, depths = _random_fabric_workload(
        width, height, num_tracks, batch, cycles, use_pallas, seed)
    depth = int(depths.max())

    # warm both paths once so neither timed region is dominated by one-off
    # JIT/Pallas compilation (the comparison is dispatch cost, not compile)
    fab.run(jnp.asarray(cfgs[0]), jnp.asarray(ext[0, :2]), depth=depth)
    fab.run_batch(jnp.asarray(cfgs), jnp.asarray(ext[:, :2]), depth=depth)

    t0 = time.perf_counter()
    serial = np.stack([
        np.asarray(fab.run(jnp.asarray(cfgs[b]), jnp.asarray(ext[b]),
                           depth=depth))
        for b in range(batch)])
    serial_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    batched = np.asarray(fab.run_batch(jnp.asarray(cfgs),
                                       jnp.asarray(ext), depth=depth))
    batched_s = time.perf_counter() - t0

    if not np.array_equal(serial, batched):
        raise AssertionError("batched emulation diverged from serial")
    return {"batch": batch, "cycles": cycles, "nodes": fab.arrays.num_nodes,
            "depth": depth, "use_pallas": use_pallas,
            "serial_seconds": serial_s, "batched_seconds": batched_s,
            "speedup": serial_s / max(batched_s, 1e-9)}


def _random_fabric_workload(width: int, height: int, num_tracks: int,
                            batch: int, cycles: int, use_pallas: bool,
                            seed: int):
    """Shared fixture for the engine benchmarks: a compiled fabric plus
    random configs / IO streams / per-config depths."""
    import numpy as np
    from .lowering import compile_interconnect
    from .passes import PassManager

    ic = PassManager().run(InterconnectSpec(
        width=width, height=height, num_tracks=num_tracks, io_ring=True,
        sb_type=SwitchBoxType.WILTON, reg_density=1.0))
    fab = compile_interconnect(ic, use_pallas=use_pallas)
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 4, (batch, fab.num_config)).astype(np.int32)
    ext = rng.integers(0, 256, (batch, cycles, fab.num_io)).astype(np.int32)
    depths = np.array([fab.combinational_depth(c) for c in cfgs], np.int32)
    return fab, cfgs, ext, depths


def _timed_min(fn, repeats: int) -> Tuple[Any, float]:
    """Best-of-N wall clock: the min is far less sensitive to scheduler
    noise on shared runners than a single shot."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def fused_vs_unfused_emulation(width: int = 6, height: int = 6,
                               num_tracks: int = 4, batch: int = 8,
                               cycles: int = 16, use_pallas: bool = True,
                               seed: int = 0, repeats: int = 3) -> Dict:
    """The fused batched engine (whole fixpoint + PE eval in one kernel
    call per cycle) vs the sweep-at-a-time PR-1 baseline (one batched
    gather kernel launch per sweep, Python-level PE evaluation between
    launches). Same workload, per-config depths, bit-identical outputs
    asserted — the measured margin is pure fusion."""
    import numpy as np
    import jax.numpy as jnp

    fab, cfgs, ext, depths = _random_fabric_workload(
        width, height, num_tracks, batch, cycles, use_pallas, seed)
    cj, ej = jnp.asarray(cfgs), jnp.asarray(ext)

    # warm both engines on the full shapes so the timed regions compare
    # execution, not tracing/compilation
    fab.run_batch(cj, ej, depth=depths, fused=False, shard=False)
    fab.run_batch(cj, ej, depth=depths, fused=True, shard=False)

    unfused, unfused_s = _timed_min(
        lambda: np.asarray(fab.run_batch(cj, ej, depth=depths,
                                         fused=False, shard=False)),
        repeats)
    fused, fused_s = _timed_min(
        lambda: np.asarray(fab.run_batch(cj, ej, depth=depths,
                                         fused=True, shard=False)),
        repeats)
    if not np.array_equal(unfused, fused):
        raise AssertionError("fused engine diverged from unfused baseline")
    return {"batch": batch, "cycles": cycles,
            "nodes": fab.arrays.num_nodes, "use_pallas": use_pallas,
            "max_depth": int(depths.max()), "min_depth": int(depths.min()),
            "unfused_seconds": unfused_s, "fused_seconds": fused_s,
            "speedup": unfused_s / max(fused_s, 1e-9)}


def sharded_vs_single_emulation(width: int = 5, height: int = 5,
                                num_tracks: int = 3, batch: int = 8,
                                cycles: int = 8, use_pallas: bool = True,
                                seed: int = 0, repeats: int = 3) -> Dict:
    """``run_batch`` with the batch axis shard_map'ed across every visible
    device vs the same workload on one device. Bit-identical outputs
    asserted. With a single visible device the sharded call takes the
    local fallback, so the record degenerates to a no-regression check;
    run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (or
    on a real multi-chip topology) to see the split."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    fab, cfgs, ext, depths = _random_fabric_workload(
        width, height, num_tracks, batch, cycles, use_pallas, seed)
    cj, ej = jnp.asarray(cfgs), jnp.asarray(ext)

    fab.run_batch(cj, ej, depth=depths, shard=False)
    fab.run_batch(cj, ej, depth=depths, shard=True)

    single, single_s = _timed_min(
        lambda: np.asarray(fab.run_batch(cj, ej, depth=depths,
                                         shard=False)), repeats)
    sharded, sharded_s = _timed_min(
        lambda: np.asarray(fab.run_batch(cj, ej, depth=depths,
                                         shard=True)), repeats)
    if not np.array_equal(single, sharded):
        raise AssertionError("sharded emulation diverged from single-device")
    return {"batch": batch, "cycles": cycles,
            "nodes": fab.arrays.num_nodes, "use_pallas": use_pallas,
            "devices": len(jax.devices()),
            "single_seconds": single_s, "sharded_seconds": sharded_s,
            "speedup": single_s / max(sharded_s, 1e-9)}


def sharded_emulation_probe(devices: int = 4, width: int = 4,
                            height: int = 4, num_tracks: int = 2,
                            batch: int = 8, cycles: int = 6,
                            timeout: float = 600.0) -> Dict:
    """Run :func:`sharded_vs_single_emulation` in a subprocess with
    ``devices`` forced host platform devices (XLA must see the flag before
    backend init, which in this process has already happened). Returns the
    child's record, or ``{"error": ...}`` when the probe cannot run."""
    import subprocess
    import sys

    # src root from this module's path (repro may be a namespace package,
    # whose __file__ is None)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    code = (
        "import json\n"
        "from repro.core.dse import sharded_vs_single_emulation\n"
        f"rec = sharded_vs_single_emulation(width={width}, "
        f"height={height}, num_tracks={num_tracks}, batch={batch}, "
        f"cycles={cycles}, use_pallas=False)\n"
        "print('PROBE_JSON:' + json.dumps(rec))\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    try:
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True,
                             timeout=timeout)
    except (OSError, subprocess.TimeoutExpired) as e:
        return {"error": str(e)}
    for line in out.stdout.splitlines():
        if line.startswith("PROBE_JSON:"):
            return json.loads(line[len("PROBE_JSON:"):])
    return {"error": f"probe exited {out.returncode}: "
                     f"{out.stderr.strip()[-500:]}"}
