"""Design-space exploration harness (§4.2).

One function per DSE axis from the paper: switch-box topology, number of
routing tracks, and SB/CB core-port connections — plus the FIFO study of
§4.1. Each returns a list of records consumed by the figure benchmarks and
the tests.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence

from .area import connection_box_area, switch_box_area
from .edsl import SwitchBoxType, create_uniform_interconnect
from .pnr import place_and_route
from .pnr.app import BENCH_APPS


def _run_apps(ic, apps: Dict[str, Callable], sa_steps: int = 60,
              sa_batch: int = 16, alphas=(2.0,),
              split_fifo_ctrl_delay: float = 0.0) -> Dict[str, Dict]:
    out: Dict[str, Dict] = {}
    from .pnr.route import RoutingResources
    res = RoutingResources(ic)
    for name, mk in apps.items():
        r = place_and_route(ic, mk(), alphas=alphas, sa_steps=sa_steps,
                            sa_batch=sa_batch, resources=res,
                            split_fifo_ctrl_delay=split_fifo_ctrl_delay)
        out[name] = {
            "success": r.success,
            "critical_path_ns": r.timing.get("critical_path_ns", float("inf")),
            "wirelength": r.wirelength,
            "route_iterations": r.route_iterations,
            "seconds": r.seconds,
            "error": r.error,
        }
    return out


def fifo_area_study(num_tracks: int = 5, track_width: int = 16
                    ) -> List[Dict]:
    """§4.1 / Fig. 8: static baseline vs full-FIFO vs split-FIFO SB area."""
    ic = create_uniform_interconnect(width=8, height=8,
                                     num_tracks=num_tracks,
                                     track_width=track_width,
                                     sb_type=SwitchBoxType.WILTON,
                                     reg_density=1.0)
    base = switch_box_area(ic)
    recs = [{"design": "static_baseline", "sb_area": base, "overhead": 0.0}]
    for mode in ("full", "split"):
        a = switch_box_area(ic, rv=mode)
        recs.append({"design": f"fifo_{mode}", "sb_area": a,
                     "overhead": a / base - 1.0})
    return recs


def sweep_num_tracks(tracks: Sequence[int] = (2, 3, 4, 5, 6),
                     apps: Optional[Dict[str, Callable]] = None,
                     width: int = 8, height: int = 8,
                     sa_steps: int = 60, track_fc: float = 1.0
                     ) -> List[Dict]:
    """§4.2.1 / Figs. 10–11: SB/CB area and application runtime vs tracks."""
    apps = apps or BENCH_APPS
    recs = []
    for t in tracks:
        ic = create_uniform_interconnect(width=width, height=height,
                                         num_tracks=t, io_ring=True,
                                         sb_type=SwitchBoxType.WILTON,
                                         reg_density=1.0,
                                         cb_track_fc=track_fc,
                                         sb_track_fc=track_fc)
        t0 = time.perf_counter()
        results = _run_apps(ic, apps, sa_steps=sa_steps)
        recs.append({
            "num_tracks": t,
            "sb_area": switch_box_area(ic),
            "cb_area": connection_box_area(ic),
            "apps": results,
            "gen_pnr_seconds": time.perf_counter() - t0,
        })
    return recs


def sweep_sb_topology(topologies: Sequence[SwitchBoxType] = (
        SwitchBoxType.WILTON, SwitchBoxType.DISJOINT, SwitchBoxType.IMRAN),
        apps: Optional[Dict[str, Callable]] = None,
        num_tracks: int = 4, width: int = 8, height: int = 8,
        sa_steps: int = 60, track_fc: float = 0.5) -> List[Dict]:
    """§4.2.1 / Fig. 9: topology routability (Wilton routes, Disjoint
    fails). track_fc < 1 reflects depopulated core-port track connections:
    a route is then pinned to its starting track *class*, which Disjoint
    can never leave (its fatal restriction) while Wilton re-permutes
    tracks at every turn."""
    apps = apps or BENCH_APPS
    recs = []
    for topo in topologies:
        ic = create_uniform_interconnect(width=width, height=height,
                                         num_tracks=num_tracks, io_ring=True,
                                         sb_type=topo, reg_density=1.0,
                                         cb_track_fc=track_fc,
                                         sb_track_fc=track_fc)
        results = _run_apps(ic, apps, sa_steps=sa_steps)
        n_ok = sum(1 for r in results.values() if r["success"])
        recs.append({
            "topology": topo.value,
            "sb_area": switch_box_area(ic),
            "apps": results,
            "n_routed": n_ok,
            "n_apps": len(results),
        })
    return recs


def sweep_port_connections(kind: str,
                           sides: Sequence[int] = (4, 3, 2),
                           apps: Optional[Dict[str, Callable]] = None,
                           num_tracks: int = 5, width: int = 8,
                           height: int = 8, sa_steps: int = 60
                           ) -> List[Dict]:
    """§4.2.2 / Figs. 12–15: depopulate SB (core-output) or CB (core-input)
    side connections and measure area + runtime."""
    if kind not in ("sb", "cb"):
        raise ValueError("kind must be 'sb' or 'cb'")
    apps = apps or BENCH_APPS
    recs = []
    for n_sides in sides:
        kw = {"sb_sides": n_sides} if kind == "sb" else {"cb_sides": n_sides}
        ic = create_uniform_interconnect(width=width, height=height,
                                         num_tracks=num_tracks, io_ring=True,
                                         sb_type=SwitchBoxType.WILTON,
                                         reg_density=1.0, **kw)
        results = _run_apps(ic, apps, sa_steps=sa_steps)
        recs.append({
            "kind": kind,
            "sides": n_sides,
            "sb_area": switch_box_area(ic),
            "cb_area": connection_box_area(ic),
            "apps": results,
        })
    return recs


def generation_speed(sizes: Sequence[int] = (4, 8, 16, 32)) -> List[Dict]:
    """Abstract claim: "fast design space exploration" — IR generation +
    lowering speed vs array size."""
    from .lowering import compile_interconnect
    recs = []
    for s in sizes:
        t0 = time.perf_counter()
        ic = create_uniform_interconnect(width=s, height=s, num_tracks=5,
                                         reg_density=1.0)
        t1 = time.perf_counter()
        fab = compile_interconnect(ic)
        t2 = time.perf_counter()
        recs.append({"size": s, "nodes": fab.arrays.num_nodes,
                     "gen_seconds": t1 - t0, "lower_seconds": t2 - t1})
    return recs
