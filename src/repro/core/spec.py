"""The declarative design-point description: a frozen ``InterconnectSpec``.

This is the single canonical input of the Canal compiler front door
(``canal.compile``): everything that defines an interconnect design point
— array size, switch-box topology, tracks/width/layers, pipeline register
density, core-port connections, ready-valid mode, and route/emulation
knobs — lives in one frozen, hashable, JSON-round-trippable dataclass.

Why frozen + serializable: design-space sweeps live or die on a canonical
design-point key. ``spec.digest()`` (sha256 over the canonical JSON form)
keys every cache in :mod:`repro.core.dse` — interconnects,
``RoutingResources``, ``FabricModule`` — and is stable across process
restarts and dict key orderings, unlike the old raw-kwargs tuples (which
broke on callables and nested values and embedded ``repr`` ids).

The spec is *data only*. Turning it into an IR graph is the job of the
pass pipeline in :mod:`repro.core.passes`; escape hatches that cannot be
serialized (custom ``core_fn`` callables, hand-built graphs) stay on the
compile call, not on the spec.
"""
from __future__ import annotations

import enum
import hashlib
import itertools
import json
from dataclasses import dataclass, field, fields, replace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .graph import Side


class SwitchBoxType(enum.Enum):
    DISJOINT = "disjoint"
    WILTON = "wilton"
    IMRAN = "imran"


# Reduction order for the port-connection DSE (Fig. 12): 4 sides, then drop
# EAST, then drop SOUTH.
SIDE_REDUCTION_ORDER: Tuple[Side, ...] = (Side.NORTH, Side.WEST, Side.SOUTH,
                                          Side.EAST)


def sides_for(n: int) -> Tuple[Side, ...]:
    """First n sides in the paper's reduction order (Fig. 12)."""
    if not 1 <= n <= 4:
        raise ValueError("side count must be in 1..4")
    return SIDE_REDUCTION_ORDER[:n]


_ROUTE_STRATEGIES = (None, "python", "minplus", "auto")
_PLACE_STRATEGIES = (None, "python", "batched", "auto")


@dataclass(frozen=True)
class InterconnectSpec:
    """A complete, immutable description of one interconnect design point.

    Hashable (usable as a dict key), JSON-round-trippable
    (``from_json(spec.to_json()) == spec``) and digestible
    (``spec.digest()`` is stable across processes and key orderings).
    Derive variants with :func:`dataclasses.replace` or :func:`spec_grid`.
    """

    width: int = 8                  # array width in tiles
    height: int = 8                 # array height in tiles
    track_width: int = 16           # routing track bit width
    num_tracks: int = 5             # tracks per side
    sb_type: SwitchBoxType = SwitchBoxType.WILTON
    reg_density: float = 1.0        # fraction of tracks with pipeline regs
    cb_sides: int = 4               # sides feeding CBs (core inputs)
    sb_sides: int = 4               # sides fed by core outputs
    cb_track_fc: float = 1.0        # fraction of tracks a CB connects to
    sb_track_fc: float = 1.0        # fraction of tracks a core output drives
    mem_columns: Tuple[int, ...] = ()
    io_ring: bool = False
    pe_inputs: int = 4
    pe_outputs: int = 2
    wire_delay: float = 0.12        # ns per inter-tile hop
    mux_delay: float = 0.06         # ns per SB mux
    cb_delay: float = 0.05          # ns through CB mux
    #: additional routing layers as ((bit_width, num_tracks), ...) pairs;
    #: a plain {width: tracks} dict is accepted and canonicalized
    extra_layers: Tuple[Tuple[int, int], ...] = ()
    # ready-valid support (hybrid interconnect, §3.3)
    ready_valid: bool = False
    fifo_depth: int = 2
    split_fifo: bool = False
    # route/emulation knobs (consumed by PnR and the DSE executor, not by
    # IR construction)
    route_strategy: Optional[str] = None   # None = caller default
    #: "auto" strategy threshold override (tiles); None = env/module default
    auto_min_tiles: Optional[int] = None
    #: ext-IO streaming chunk for batched emulation; None = caller default
    emulate_io_chunk: Optional[int] = None
    # PnR knobs folded from SweepExecutor (PR 5): a design point now fully
    # describes *how* it is placed and routed, so its digest addresses the
    # persistent result store. None = caller/executor default. All are
    # digest-optional (see DIGEST_OPTIONAL): while unset they are omitted
    # from the canonical JSON, keeping pre-existing digests stable.
    reg_penalty: Optional[float] = None        # router register-hop penalty
    alphas: Optional[Tuple[float, ...]] = None  # placement α sweep (§3.4)
    sa_steps: Optional[int] = None             # annealing steps
    sa_batch: Optional[int] = None             # annealing batch
    seed: Optional[int] = None                 # place/route RNG seed
    split_fifo_ctrl_delay: Optional[float] = None  # split-FIFO ctrl ns
    #: placement engine: "python" host SA / "batched" device chains /
    #: "auto" (tile-count switch); None = caller default
    place_strategy: Optional[str] = None

    def __post_init__(self):
        # canonicalize before freezing semantics: str -> enum, dict/list ->
        # sorted tuples, so equal design points compare and hash equal
        if isinstance(self.sb_type, str):
            object.__setattr__(self, "sb_type", SwitchBoxType(self.sb_type))
        if isinstance(self.extra_layers, dict):
            object.__setattr__(self, "extra_layers", tuple(
                sorted((int(w), int(t))
                       for w, t in self.extra_layers.items())))
        else:
            object.__setattr__(self, "extra_layers", tuple(
                (int(w), int(t)) for w, t in self.extra_layers))
        object.__setattr__(self, "mem_columns",
                           tuple(int(c) for c in self.mem_columns))
        if self.width < 1 or self.height < 1:
            raise ValueError("array dims must be >= 1 tile")
        if self.num_tracks < 1:
            raise ValueError("num_tracks must be >= 1")
        if not 0.0 <= self.reg_density <= 1.0:
            raise ValueError("reg_density must be in [0, 1]")
        for name in ("cb_sides", "sb_sides"):
            if not 1 <= getattr(self, name) <= 4:
                raise ValueError(f"{name} must be in 1..4")
        if self.route_strategy not in _ROUTE_STRATEGIES:
            raise ValueError(
                f"route_strategy must be one of {_ROUTE_STRATEGIES}, "
                f"got {self.route_strategy!r}")
        if self.place_strategy not in _PLACE_STRATEGIES:
            raise ValueError(
                f"place_strategy must be one of {_PLACE_STRATEGIES}, "
                f"got {self.place_strategy!r}")
        if self.alphas is not None:
            object.__setattr__(self, "alphas",
                               tuple(float(a) for a in self.alphas))
            if not self.alphas:
                raise ValueError("alphas must be non-empty when set")
        for name in ("reg_penalty", "split_fifo_ctrl_delay"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, float(v))
        for name in ("sa_steps", "sa_batch", "seed"):
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(self, name, int(v))
        if self.sa_steps is not None and self.sa_steps < 0:
            raise ValueError("sa_steps must be >= 0")
        if self.sa_batch is not None and self.sa_batch < 1:
            raise ValueError("sa_batch must be >= 1")

    # -- derived views --------------------------------------------------------
    def sb_connection_sides(self) -> Tuple[Side, ...]:
        return sides_for(self.sb_sides)

    def cb_connection_sides(self) -> Tuple[Side, ...]:
        return sides_for(self.cb_sides)

    def layers(self) -> Dict[int, int]:
        """bit_width -> num_tracks for every routing layer."""
        out = {self.track_width: self.num_tracks}
        out.update(dict(self.extra_layers))
        return out

    def n_tiles(self) -> int:
        return self.width * self.height

    # -- serialization --------------------------------------------------------
    def to_dict(self) -> Dict[str, object]:
        """JSON-safe field map (enums to values, tuples to lists)."""
        out: Dict[str, object] = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if isinstance(v, enum.Enum):
                v = v.value
            elif isinstance(v, tuple):
                v = [list(e) if isinstance(e, tuple) else e for e in v]
            out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "InterconnectSpec":
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise TypeError(
                f"unknown InterconnectSpec fields {unknown}; "
                f"valid fields: {sorted(known)}")
        return cls(**d)  # type: ignore[arg-type]

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "InterconnectSpec":
        return cls.from_dict(json.loads(s))

    #: fields added after the digest schema was frozen (PR 4): they are
    #: omitted from the canonical JSON while they hold their default, so
    #: growing the spec never drifts the digests of pre-existing design
    #: points (the committed golden fixtures included). Append-only.
    DIGEST_OPTIONAL = ("reg_penalty", "alphas", "sa_steps", "sa_batch",
                       "seed", "split_fifo_ctrl_delay", "place_strategy")

    def canonical_dict(self) -> Dict[str, object]:
        """The digest's view of the spec: :meth:`to_dict` minus any
        ``DIGEST_OPTIONAL`` field still at its default (forward-compatible
        digest schema — new knobs only show up once actually set)."""
        defaults = {f.name: f.default for f in fields(self)}
        d = self.to_dict()
        for name in self.DIGEST_OPTIONAL:
            if getattr(self, name) == defaults[name]:
                d.pop(name, None)
        return d

    def canonical_json(self) -> str:
        return json.dumps(self.canonical_dict(), sort_keys=True,
                          separators=(",", ":"))

    def digest(self) -> str:
        """Stable content address of this design point: sha256 over the
        canonical (sorted-keys, no-whitespace) JSON form. Key-order and
        process independent — the cache key for every spec-addressed
        store (DSE records, golden fixtures, served results)."""
        return hashlib.sha256(self.canonical_json().encode()).hexdigest()

    #: fields that tune *how* a point is evaluated, not what hardware it
    #: is — excluded from hardware_digest() so IR/resources/fabric caches
    #: are shared across e.g. router-strategy comparisons
    EXECUTION_KNOBS = ("route_strategy", "auto_min_tiles",
                       "emulate_io_chunk", "reg_penalty", "alphas",
                       "sa_steps", "sa_batch", "seed",
                       "split_fifo_ctrl_delay", "place_strategy")

    def hardware_spec(self) -> "InterconnectSpec":
        """This spec with the execution knobs cleared: two points that
        compile to identical hardware compare equal."""
        return replace(self, **{k: None for k in self.EXECUTION_KNOBS})

    def hardware_digest(self) -> str:
        """Content address of the *hardware* this spec compiles to
        (execution knobs excluded) — the key for compiled-artifact
        caches. Equals ``digest()`` when no execution knob is set."""
        return self.hardware_spec().digest()

    def replace(self, **overrides) -> "InterconnectSpec":
        """Functional update (the spec itself is frozen)."""
        return replace(self, **overrides)

    def with_execution_defaults(self, **defaults) -> "InterconnectSpec":
        """Fill *unset* (None) execution knobs from ``defaults`` and
        return the resolved spec. This is how the DSE executor pins a
        design point before addressing the persistent result store: the
        resolved digest then fully determines the stored record instead
        of leaking executor state. Knobs the spec already sets win;
        ``None`` defaults are skipped; non-knob names are rejected."""
        unknown = sorted(set(defaults) - set(self.EXECUTION_KNOBS))
        if unknown:
            raise TypeError(f"not execution knobs: {unknown}; "
                            f"knobs: {sorted(self.EXECUTION_KNOBS)}")
        updates = {k: v for k, v in defaults.items()
                   if v is not None and getattr(self, k) is None}
        return replace(self, **updates) if updates else self


def spec_from_kwargs(**kwargs) -> InterconnectSpec:
    """Canonicalize legacy ``create_uniform_interconnect`` keyword
    arguments into an :class:`InterconnectSpec`.

    Rejects non-spec arguments with an actionable error instead of a raw
    ``TypeError`` deep inside caching code: callables (e.g. ``core_fn``)
    are not serializable design-point data and must be passed to the
    compile call instead."""
    for k, v in kwargs.items():
        if callable(v) and not isinstance(v, type):
            raise TypeError(
                f"kwarg {k!r} is a callable and cannot be part of a "
                "design-point spec (it is not serializable/cacheable); "
                "pass it to PassManager.compile(..., core_fn=...) instead")
    return InterconnectSpec.from_dict(dict(kwargs))


def _json_safe(v: object) -> object:
    if isinstance(v, enum.Enum):
        return v.value
    if isinstance(v, tuple):
        return list(v)
    return v


def spec_axes(base: InterconnectSpec,
              axes: Dict[str, Sequence]) -> Dict[str, Tuple]:
    """Canonicalize search/sweep axes over ``base``: every key must be a
    spec field, and every value must produce a constructible spec (bad
    values fail here, with the axis named, instead of deep inside a
    sweep). Values are canonicalized through the spec's own coercion
    (``"wilton"`` -> ``SwitchBoxType.WILTON``, lists -> tuples) and
    deduplicated order-preserving — the axis order is the neighborhood
    order the greedy selector walks."""
    names = {f.name for f in fields(InterconnectSpec)}
    out: Dict[str, Tuple] = {}
    for name, values in axes.items():
        if name not in names:
            raise TypeError(f"unknown spec axis {name!r}; "
                            f"valid fields: {sorted(names)}")
        vals: List = []
        for v in values:
            try:
                canon = getattr(replace(base, **{name: v}), name)
            except (TypeError, ValueError) as e:
                raise ValueError(
                    f"axis {name!r}: value {v!r} does not produce a "
                    f"valid spec: {e}") from e
            if canon not in vals:
                vals.append(canon)
        if not vals:
            raise ValueError(f"axis {name!r} has no values")
        out[name] = tuple(vals)
    return out


def mutate_spec(spec: InterconnectSpec, axes: Dict[str, Sequence],
                rng) -> InterconnectSpec:
    """Single-axis local mutation: pick one axis (uniformly among those
    with an alternative to the spec's current value) and move it to a
    different allowed value. The mutation primitive behind the greedy
    and evolutionary DSE selectors; returns ``spec`` unchanged when no
    axis offers an alternative (a one-point space)."""
    movable = [n for n in axes
               if any(v != getattr(spec, n) for v in axes[n])]
    if not movable:
        return spec
    name = rng.choice(movable)
    choices = [v for v in axes[name] if v != getattr(spec, name)]
    return replace(spec, **{name: rng.choice(choices)})


def neighbor_specs(spec: InterconnectSpec,
                   axes: Dict[str, Sequence]
                   ) -> List[InterconnectSpec]:
    """The specs one axis step away from ``spec``: for each axis, the
    values adjacent to the current value in the axis's ordered value
    list (every axis value when the current value is off-axis).
    Deterministic order — axis declaration order, lower neighbor first —
    so seeded searches reproduce exactly."""
    out: List[InterconnectSpec] = []
    seen = {spec}
    for name, vals in axes.items():
        cur = getattr(spec, name)
        vals = tuple(vals)
        if cur in vals:
            i = vals.index(cur)
            adj = [vals[j] for j in (i - 1, i + 1) if 0 <= j < len(vals)]
        else:
            adj = list(vals)
        for v in adj:
            cand = replace(spec, **{name: v})
            if cand not in seen:
                seen.add(cand)
                out.append(cand)
    return out


def spec_grid(base: InterconnectSpec,
              axes: Dict[str, Sequence],
              label: Optional[Callable[[InterconnectSpec], Dict]] = None
              ) -> List[Tuple[InterconnectSpec, Dict]]:
    """Declarative sweep grid: the cartesian product of field overrides
    over ``base``. Returns ``(spec, extra)`` points for
    :meth:`repro.core.dse.SweepExecutor.run_points` — ``extra`` defaults
    to the JSON-safe values of the varied fields and can be customized
    with ``label`` (a ``spec -> dict`` function)."""
    names = list(axes)
    points: List[Tuple[InterconnectSpec, Dict]] = []
    for combo in itertools.product(*(axes[n] for n in names)):
        s = replace(base, **dict(zip(names, combo)))
        extra = (label(s) if label is not None
                 else {n: _json_safe(getattr(s, n)) for n in names})
        points.append((s, extra))
    return points
