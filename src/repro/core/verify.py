"""Structural verification and configuration sweep (§3.3, last paragraph).

The paper verifies generated RTL by (1) comparing hardware connectivity
against the IR and (2) an exhaustive configuration sweep exercising every
possible connection. We do the same against the lowered JAX fabric:

* ``verify_structural`` — the fabric's gather tables must reproduce the IR
  fan-in lists exactly (order included: select-bit semantics).
* ``config_sweep`` — for every multi-input mux node and every one of its
  inputs, drive a distinguishing value pattern through the fabric with only
  that select programmed and check the mux output follows the selected
  input after one sweep (the hardware "every possible connection" test,
  evaluated in batch).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .graph import Interconnect
from .lowering import FabricModule


def verify_structural(ic: Interconnect, fabric: FabricModule) -> None:
    """Raise AssertionError if the lowered fabric's connectivity deviates
    from the IR (the paper's RTL-vs-IR connectivity check)."""
    ir_conn = ic.connectivity()
    hw_conn = fabric.structural_connectivity()
    if set(ir_conn) != set(hw_conn):
        missing = set(ir_conn) ^ set(hw_conn)
        raise AssertionError(f"node set mismatch, e.g. {list(missing)[:4]}")
    for key, fan_in in ir_conn.items():
        if fan_in != hw_conn[key]:
            raise AssertionError(
                f"fan-in mismatch at {key}: IR={fan_in} HW={hw_conn[key]}")


def config_sweep(fabric: FabricModule, batch: int = 2048,
                 seed: int = 0) -> int:
    """Exhaustively exercise every (mux, input) connection.

    For each configurable node ``n`` and each input index ``s``, build a
    config selecting ``s`` at ``n`` (zeros elsewhere) and check after one
    sweep: value(n) == value(input_s). Values are randomized per node so a
    wrong connection is detected w.h.p. Evaluated in vmap batches.
    Returns the number of connections checked.
    """
    a = fabric.arrays
    rng = np.random.default_rng(seed)
    # deterministic distinct per-node values (mod 16-bit)
    node_vals = rng.integers(1, 1 << 15,
                             size=a.num_nodes + 1).astype(np.int32)
    node_vals[-1] = 0

    # enumerate (slot, select) pairs
    cases: List[Tuple[int, int]] = []
    for si, slot in enumerate(fabric.config_slots):
        for s in range(slot.fanin):
            cases.append((si, s))

    vals0 = jnp.asarray(node_vals)
    src = jnp.asarray(a.src)
    config_slot = jnp.asarray(a.config_slot)
    fanin_count = jnp.asarray(a.fanin_count)
    slot_node = jnp.asarray(
        np.array([s.node_id for s in fabric.config_slots], dtype=np.int32)
        if fabric.config_slots else np.zeros(0, np.int32))

    def check_case(slot_idx, sel_val):
        config = jnp.zeros(a.num_config, dtype=jnp.int32) \
            .at[slot_idx].set(sel_val)
        sel = jnp.where(config_slot >= 0,
                        config[jnp.clip(config_slot, 0,
                                        max(a.num_config - 1, 0))], 0)
        sel = jnp.clip(sel, 0, jnp.maximum(fanin_count - 1, 0))
        src_sel = jnp.take_along_axis(src, sel[:, None], axis=1)[:, 0]
        new_vals = vals0[src_sel]
        node = slot_node[slot_idx]
        expect = vals0[src[node, sel_val]]
        return new_vals[node] == expect

    if not cases:
        return 0
    slot_ids = jnp.asarray(np.array([c[0] for c in cases], np.int32))
    sels = jnp.asarray(np.array([c[1] for c in cases], np.int32))
    ok = np.asarray(jax.vmap(check_case)(slot_ids, sels))
    bad = np.nonzero(~ok)[0]
    if len(bad):
        si, s = cases[bad[0]]
        slot = fabric.config_slots[si]
        raise AssertionError(
            f"config sweep failed at node {fabric.nodes[slot.node_id]} "
            f"select {s} (+{len(bad) - 1} more)")
    return len(cases)


def verify(ic: Interconnect, fabric: FabricModule) -> Dict[str, int]:
    verify_structural(ic, fabric)
    checked = config_sweep(fabric)
    return {"nodes": fabric.arrays.num_nodes,
            "configs": fabric.num_config,
            "connections_checked": checked}
