"""Spec-addressed persistent DSE result store.

Every design point has a canonical content address — ``spec.digest()``
(see :mod:`repro.core.spec`) — and this module makes that address the
key of an on-disk store of PnR/emulation/area records, so results
survive the process that computed them: a repeated sweep, a benchmark
re-run, or a :class:`repro.serve.dse_service.DSEService` query hits the
store instead of re-routing the same hardware (the artifact-reuse
discipline of cached-partition FPGA flows, applied to Canal's DSE).

Layout on disk (one JSON file per digest, atomically replaced)::

    <root>/
      records/<spec_digest>.json        # versioned envelope + record
      by_hardware/<hardware_digest>/<spec_digest>   # secondary index

The ``by_hardware`` index groups execution-knob variants (router
strategy, α sweep, annealing budget, ...) of the same hardware, making
them enumerable via :meth:`ResultStore.for_hardware`.

Durability rules:

* writes are atomic (`os.replace` of a same-directory temp file), so a
  crashed writer can never leave a half-record under the digest path;
* loads are corruption-tolerant: truncated/garbled/wrong-schema files
  count as misses (and are tallied in ``stats()``), never raise;
* the envelope carries a schema version stamp; unknown versions are
  treated as misses so future schema changes stay forward-compatible.

Merge rules (the differing-app-set fix): :meth:`ResultStore.put`
*merges* a record into any existing record for the same digest — app
union, newest-wins per app — instead of whole-record last-writer-wins.
Two executors alternating different app sets against one store used to
overwrite each other's records forever (each saw only the other's apps,
missed, recomputed, and clobbered); now the stored record accumulates
every app ever computed for the digest and both converge on hits.
Writers sharing one ``ResultStore`` object serialize the
read-merge-write; independent processes race last-writer-wins on a
single put but still converge, because every writer merges the other's
apps in before replacing the file.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import threading
from typing import Dict, Iterator, List, Optional

from .spec import InterconnectSpec

#: bump when the envelope layout changes incompatibly; readers treat any
#: other version as a miss rather than guessing
SCHEMA_VERSION = 1

#: env var naming the default store root (CI points it at a cached dir)
STORE_ENV = "CANAL_RESULT_STORE"

#: default on-disk location when neither an explicit root nor the env
#: var is given (relative to the working directory, like a build cache)
DEFAULT_ROOT = ".canal_store"

_DIGEST_RE = re.compile(r"^[0-9a-f]{64}$")


def default_store_root() -> str:
    """The store root honoring the ``CANAL_RESULT_STORE`` override."""
    return os.environ.get(STORE_ENV) or DEFAULT_ROOT


def record_metrics(rec: Dict) -> Dict[str, float]:
    """The frontier-relevant summary of a DSE record: the
    (area, critical-path delay, routability) triple the search front end
    (:mod:`repro.core.search`) optimizes over.

    * ``area`` — SB + CB area of the design point;
    * ``critical_path_ns`` — the *worst* critical path over the routed
      apps (``inf`` when nothing routed: an unroutable point can never
      dominate on delay);
    * ``routability`` — routed apps / total apps in the record.

    Records whose app entries carry the routed-scope static metrics
    (``static_ii`` / ``min_slack_ns``, stamped per app by the executor)
    additionally summarize to:

    * ``throughput`` — the *worst* static throughput bound over the
      routed apps, in tokens/cycle (``1 / static_ii``; 0.0 when nothing
      routed or a loop deadlocks);
    * ``min_slack_ns`` — the worst per-net slack over the routed apps
      against the fixed reference clock
      (:data:`repro.core.analysis.DEFAULT_CLOCK_NS`).

    These two appear only when at least one app entry carries the static
    fields, so records written before the routed analyzer keep their
    exact three-key shape.

    Stamped onto records at compute time and re-derived when an app-set
    merge changes the app population, so store consumers (``recommend``,
    external tooling) can rank records without reconstructing the
    aggregation."""
    apps = rec.get("apps") or {}
    routed = [a for a in apps.values()
              if isinstance(a, dict) and a.get("success")]
    crit = float("inf")
    if routed:
        crit = max(float(a.get("critical_path_ns", float("inf")))
                   for a in routed)
    area = float(rec.get("sb_area") or 0.0) + \
        float(rec.get("cb_area") or 0.0)
    metrics = {"area": area, "critical_path_ns": crit,
               "routability": len(routed) / len(apps) if apps else 0.0}
    if any(isinstance(a, dict)
           and ("static_ii" in a or "min_slack_ns" in a)
           for a in apps.values()):
        if routed:
            # worst-case over apps; an app predating the static stamps
            # defaults to the unconstrained values (II=1, slack vs the
            # reference clock) rather than poisoning the aggregate
            from .analysis import DEFAULT_CLOCK_NS
            metrics["throughput"] = min(
                (1.0 / ii if (ii := float(a.get("static_ii", 1.0))) > 0
                 and ii != float("inf") else 0.0)
                for a in routed)
            metrics["min_slack_ns"] = min(
                float(a.get("min_slack_ns",
                            DEFAULT_CLOCK_NS - crit)) for a in routed)
        else:
            metrics["throughput"] = 0.0
            metrics["min_slack_ns"] = float("-inf")
    return metrics


def _stamped_apps(rec: Dict) -> Dict[str, Dict]:
    """Copy a record's app entries with the record-level
    ``emulate_cycles`` claim stamped per app. A merged record holds apps
    produced by writers with *different* emulation contexts, so the
    record-level field alone can no longer vouch for every app — the
    stamp preserves each app's own claim across merges (``None`` marks
    an unknown claim, which emulating readers treat as a miss)."""
    cycles = rec.get("emulate_cycles")
    out: Dict[str, Dict] = {}
    for name, entry in (rec.get("apps") or {}).items():
        if isinstance(entry, dict):
            entry = dict(entry)
            entry.setdefault("emulate_cycles", cycles)
        out[name] = entry
    return out


def merge_records(old: Dict, new: Dict) -> Dict:
    """Merge ``new`` into ``old`` for the same digest: union of apps with
    newest-wins per app; every other field newest-wins wholesale. Both
    sides' app entries get per-app ``emulate_cycles`` stamps (see
    :func:`_stamped_apps`) and the frontier metrics are recomputed over
    the merged app population. Records without a dict app map fall back
    to plain newest-wins."""
    if not isinstance(old.get("apps"), dict) \
            or not isinstance(new.get("apps"), dict):
        return new
    apps = _stamped_apps(old)
    apps.update(_stamped_apps(new))
    merged = dict(new, apps=apps)
    if "metrics" in old or "metrics" in new:
        merged["metrics"] = record_metrics(merged)
    return merged


def atomic_write_json(path: str, payload) -> None:
    """Same-directory temp file + ``os.replace``: readers only ever see
    absent or complete files, even across a writer crash. The shared
    durability idiom for store records and benchmark trajectories."""
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=".tmp-", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True, default=str)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ResultStore:
    """Content-addressed persistent map ``spec.digest() -> DSE record``.

    Thread-safe; cheap to construct (directories are created lazily on
    first write, so opening a store never litters the filesystem).
    """

    def __init__(self, root: Optional[str] = None):
        self.root = os.path.abspath(root or default_store_root())
        self._records = os.path.join(self.root, "records")
        self._by_hw = os.path.join(self.root, "by_hardware")
        # re-entrant: put() holds it across its read-merge-write while
        # the envelope load underneath counts corruption under it too
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.corrupt = 0
        self.writes = 0

    # --------------------------------------------------------------- paths
    @staticmethod
    def _check_digest(digest: str) -> str:
        if not isinstance(digest, str) or not _DIGEST_RE.match(digest):
            raise ValueError(f"not a sha256 hex digest: {digest!r}")
        return digest

    def _record_path(self, digest: str) -> str:
        return os.path.join(self._records, f"{digest}.json")

    # --------------------------------------------------------------- reads
    def get(self, key) -> Optional[Dict]:
        """The stored record for ``key`` (a digest string or an
        :class:`InterconnectSpec`), or None on miss. A file that fails to
        parse, carries an unknown schema version, or misrecords its own
        digest is a *miss*, not an error — a corrupted cache must never
        poison or abort a sweep."""
        digest = self._as_digest(key)
        env = self._load_envelope(self._record_path(digest))
        with self._lock:
            if env is None or env.get("spec_digest") != digest:
                if env is not None:
                    self.corrupt += 1
                self.misses += 1
                return None
            self.hits += 1
        return env["record"]

    def _load_envelope(self, path: str) -> Optional[Dict]:
        try:
            with open(path) as f:
                env = json.load(f)
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            if os.path.exists(path):
                with self._lock:
                    self.corrupt += 1
            return None
        if (not isinstance(env, dict)
                or env.get("schema") != SCHEMA_VERSION
                or not isinstance(env.get("record"), dict)):
            with self._lock:
                self.corrupt += 1
            return None
        return env

    def __contains__(self, key) -> bool:
        """True iff :meth:`get` would serve a record — a corrupt or
        foreign-schema file under the digest path does not count (mere
        file existence must not talk a caller out of recomputing)."""
        digest = self._as_digest(key)
        env = self._load_envelope(self._record_path(digest))
        return env is not None and env.get("spec_digest") == digest

    def __len__(self) -> int:
        try:
            return sum(1 for _ in self.digests())
        except OSError:
            return 0

    def digests(self) -> Iterator[str]:
        """Every digest with a committed record file (temp files and
        foreign droppings are skipped — only ``<sha256>.json`` counts)."""
        try:
            names = os.listdir(self._records)
        except OSError:
            return
        for name in sorted(names):
            stem, ext = os.path.splitext(name)
            if ext == ".json" and _DIGEST_RE.match(stem):
                yield stem

    def for_hardware(self, key) -> List[Dict]:
        """All stored records whose spec compiles to the given hardware
        (``key``: a ``hardware_digest()`` string or a spec) — the
        execution-knob variants of one design, enumerable e.g. for
        router-strategy or α-sweep comparisons. Corrupt/missing entries
        are skipped."""
        if isinstance(key, InterconnectSpec):
            hw = key.hardware_digest()
        else:
            hw = self._check_digest(key)
        try:
            names = sorted(os.listdir(os.path.join(self._by_hw, hw)))
        except OSError:
            return []
        out = []
        for name in names:
            if _DIGEST_RE.match(name):
                rec = self.get(name)
                if rec is not None:
                    out.append(rec)
        return out

    # -------------------------------------------------------------- writes
    def put(self, spec_or_digest, record: Dict,
            hardware_digest: Optional[str] = None,
            spec_dict: Optional[Dict] = None,
            merge: bool = True) -> str:
        """Persist ``record`` under the design point's content address.

        Pass the :class:`InterconnectSpec` when available — the envelope
        then embeds the spec JSON (the store is self-describing: a record
        can be re-queried or re-verified without the producing process)
        and the hardware index is maintained automatically. With a bare
        digest string, ``hardware_digest``/``spec_dict`` are optional
        extras. Returns the digest written.

        With ``merge`` (the default) an existing record for the same
        digest is *merged into*, not overwritten: app union, newest-wins
        per app (see :func:`merge_records`) — the fix for executors with
        differing app sets ping-ponging overwrites against one store.
        ``merge=False`` restores whole-record replacement (e.g. to purge
        a record known to be stale). The caller's ``record`` dict is
        never mutated — merged app entries are copies."""
        if isinstance(spec_or_digest, InterconnectSpec):
            spec = spec_or_digest
            digest = spec.digest()
            hardware_digest = spec.hardware_digest()
            spec_dict = spec.canonical_dict()
        else:
            digest = self._check_digest(spec_or_digest)
            if hardware_digest is not None:
                self._check_digest(hardware_digest)
        path = self._record_path(digest)
        # the read-merge-write is serialized per store object (cross-
        # process writers race last-writer-wins but still converge: each
        # merges the other's apps in before replacing the file)
        with self._lock:
            if merge:
                old = self._load_envelope(path)
                if old is not None and old.get("spec_digest") == digest:
                    record = merge_records(old["record"], record)
            env = {"schema": SCHEMA_VERSION, "spec_digest": digest,
                   "hardware_digest": hardware_digest, "spec": spec_dict,
                   "record": record}
            os.makedirs(self._records, exist_ok=True)
            # index marker first: a crash between the two steps then
            # leaves a dangling marker (for_hardware skips it — get()
            # misses), never a committed record the index can't
            # enumerate; unconditional create also avoids the
            # exists-then-open race between writers
            if hardware_digest is not None:
                hw_dir = os.path.join(self._by_hw, hardware_digest)
                os.makedirs(hw_dir, exist_ok=True)
                with open(os.path.join(hw_dir, digest), "w"):
                    pass
            atomic_write_json(path, env)
            self.writes += 1
        return digest

    # --------------------------------------------------------------- misc
    @staticmethod
    def _as_digest(key) -> str:
        if isinstance(key, InterconnectSpec):
            return key.digest()
        return ResultStore._check_digest(key)

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {"root": self.root, "records": len(self),
                    "hits": self.hits, "misses": self.misses,
                    "corrupt": self.corrupt, "writes": self.writes}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"ResultStore({self.root!r}, records={len(self)})"
