"""Static-interconnect hardware backend (Canal §3.3).

Lowers the graph IR into a *functional JAX model* of the fabric instead of
magma RTL. The paper's three lowering rules are applied mechanically:

1. nodes with hardware attributes (cores) generate the specified hardware —
   here, a vectorized functional model of the PE/MEM/IO cores;
2. directed edges become wires — here, entries in a gather table;
3. nodes with multiple incoming edges become multiplexers — here,
   config-indexed selects into the gather table.

Because the structural graph contains *potential* combinational cycles
(register-bypass muxes), the fabric evaluates each cycle by fixpoint
sweeps: one sweep propagates every node's value one combinational level.
A legal configuration's active network is acyclic, so ``depth`` sweeps
(≥ longest configured combinational path) reach the fixed point. The sweep
itself is the perf hot spot and has a Pallas kernel
(``repro.kernels.fabric_step``); the batched path runs the whole fixpoint
— PE cores included — as one fused kernel call per cycle, masks each
configuration to its own combinational depth, and shards the batch axis
across devices (``run_batch``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.kernels.fabric_step import PE_OPS, pe_alu_candidates

from .graph import IO, Interconnect, Node, NodeKind
from .tiles import IOCore, MemCore, PECore, WORD

assert PECore.OPS == PE_OPS, \
    "fabric_step.PE_OPS must mirror PECore.OPS (shared PE ALU datapath)"
PE_OP_IDS = {op: i for i, op in enumerate(PECore.OPS)}

DepthSpec = Union[int, np.ndarray, jnp.ndarray]


@dataclass
class ConfigSlot:
    node_id: int
    fanin: int
    num_bits: int
    # bitstream address (tile-feature-register, see repro.core.bitstream)
    x: int
    y: int
    feature: str
    reg_index: int


@dataclass
class FabricArrays:
    """Dense tables driving the sweep evaluation. All numpy on the host;
    converted to jnp at jit boundaries."""

    num_nodes: int
    max_fanin: int
    src: np.ndarray           # (N, F) int32, padded with N (zero sentinel)
    fanin_count: np.ndarray   # (N,) int32
    config_slot: np.ndarray   # (N,) int32, -1 when unconfigured
    is_reg: np.ndarray        # (N,) bool
    is_driven: np.ndarray     # (N,) bool: updated by sweeps
    reg_ids: np.ndarray       # (R,) node ids of registers
    reg_src: np.ndarray       # (R,) node id feeding each register
    num_config: int


class FabricModule:
    """Functional model of the generated interconnect + cores.

    ``step(state, ext_in, config, pe_cfg)`` advances one fabric clock cycle;
    everything is jit/vmap friendly. Node values are int32 words masked to
    the layer bit width.
    """

    def __init__(self, ic: Interconnect, use_pallas: bool = False):
        self.ic = ic
        self.use_pallas = use_pallas
        self.nodes: List[Node] = list(ic.nodes())
        self.node_id: Dict[Node, int] = {n: i for i, n in
                                         enumerate(self.nodes)}
        self.config_slots: List[ConfigSlot] = []
        self._build_tables()
        self._build_cores()

    # ------------------------------------------------------------------ build
    def _feature_of(self, node: Node) -> str:
        if node.kind == NodeKind.PORT:
            return f"CB_{node.port_name}"
        return "SB"

    def _build_tables(self) -> None:
        n = len(self.nodes)
        fanins = [len(node.fan_in) for node in self.nodes]
        max_f = max(1, max(fanins, default=1))
        src = np.full((n, max_f), n, dtype=np.int32)   # sentinel = n
        fanin_count = np.zeros(n, dtype=np.int32)
        config_slot = np.full(n, -1, dtype=np.int32)
        is_reg = np.zeros(n, dtype=bool)
        is_driven = np.zeros(n, dtype=bool)

        # per-(tile, feature) register index counter for bitstream addressing
        feat_counter: Dict[Tuple[int, int, str], int] = {}

        for i, node in enumerate(self.nodes):
            fi = len(node.fan_in)
            fanin_count[i] = fi
            for j, s in enumerate(node.fan_in):
                src[i, j] = self.node_id[s]
            if node.kind == NodeKind.REGISTER:
                is_reg[i] = True
                continue
            if fi >= 1:
                is_driven[i] = True
            if fi > 1:
                key = (node.x, node.y, self._feature_of(node))
                idx = feat_counter.get(key, 0)
                feat_counter[key] = idx + 1
                config_slot[i] = len(self.config_slots)
                self.config_slots.append(ConfigSlot(
                    node_id=i, fanin=fi,
                    num_bits=int(np.ceil(np.log2(fi))),
                    x=node.x, y=node.y, feature=key[2], reg_index=idx))

        reg_ids = np.array([i for i, node in enumerate(self.nodes)
                            if node.kind == NodeKind.REGISTER],
                           dtype=np.int32)
        reg_src = np.array([src[i, 0] for i in reg_ids], dtype=np.int32)

        self.arrays = FabricArrays(
            num_nodes=n, max_fanin=max_f, src=src, fanin_count=fanin_count,
            config_slot=config_slot, is_reg=is_reg, is_driven=is_driven,
            reg_ids=reg_ids, reg_src=reg_src,
            num_config=len(self.config_slots))
        self.width_mask = np.array(
            [(1 << node.width) - 1 for node in self.nodes] + [0],
            dtype=np.int32)

    def _build_cores(self) -> None:
        """Vectorized core models: PEs and IOs (MEM modeled as delay reg)."""
        pe_in: List[List[int]] = []     # (n_pe, 4) input port node ids
        pe_out: List[List[int]] = []    # (n_pe, 2) output port node ids
        self.pe_coords: List[Tuple[int, int]] = []
        io_in_nodes: List[int] = []     # io_out ports (externally driven)
        io_out_nodes: List[int] = []    # io_in ports (externally observed)
        self.io_coords: List[Tuple[int, int]] = []
        mem_in: List[int] = []
        mem_out: List[int] = []

        sentinel = self.arrays.num_nodes
        seen = set()
        for g in self.ic.graphs.values():
            for (x, y), tile in sorted(g.tiles.items()):
                if tile.core is None or (x, y) in seen:
                    continue
                seen.add((x, y))
                core = tile.core
                if isinstance(core, PECore):
                    ins = [self.node_id[tile.get_port(f"data{i}")]
                           for i in range(core.num_inputs)]
                    ins += [sentinel] * (4 - len(ins))
                    outs = [self.node_id[tile.get_port(f"res{i}")]
                            for i in range(core.num_outputs)]
                    pe_in.append(ins[:4])
                    pe_out.append(outs)
                    self.pe_coords.append((x, y))
                elif isinstance(core, IOCore):
                    io_in_nodes.append(self.node_id[tile.get_port("io_out")])
                    io_out_nodes.append(self.node_id[tile.get_port("io_in")])
                    self.io_coords.append((x, y))
                elif isinstance(core, MemCore):
                    mem_in.append(self.node_id[tile.get_port("wdata")])
                    mem_out.append(self.node_id[tile.get_port("rdata")])

        self.pe_in = np.array(pe_in, dtype=np.int32).reshape(-1, 4)
        self.pe_out = (np.array(pe_out, dtype=np.int32)
                       if pe_out else np.zeros((0, 2), np.int32))
        self.io_in_nodes = np.array(io_in_nodes, dtype=np.int32)
        self.io_out_nodes = np.array(io_out_nodes, dtype=np.int32)
        self.mem_in = np.array(mem_in, dtype=np.int32)
        self.mem_out = np.array(mem_out, dtype=np.int32)
        self.num_pe = len(pe_in)
        self.num_io = len(io_in_nodes)
        self.num_mem = len(mem_in)
        self._build_fused_tables()

    def _build_fused_tables(self) -> None:
        """Node/PE tables for the fused batched engine (one kernel call per
        fixpoint): hold-flags, pin mask, sentinel-padded PE inputs and the
        scatter-free node -> PE-result index map."""
        a = self.arrays
        n = a.num_nodes
        p = max(self.num_pe, 1)
        pe_in = np.full((p, 4), n, dtype=np.int32)
        if self.num_pe:
            pe_in[:self.num_pe] = self.pe_in
        pe_res_idx = np.full(n, 2 * p, dtype=np.int32)
        for k in range(self.num_pe):
            for col in range(self.pe_out.shape[1]):
                pe_res_idx[self.pe_out[k, col]] = 2 * k + col
        pin_mask = np.zeros(n, dtype=np.int32)
        if len(a.reg_ids):
            pin_mask[a.reg_ids] = 1
        if self.num_io:
            pin_mask[self.io_in_nodes] = 1
        if self.num_mem:
            pin_mask[self.mem_out] = 1
        self.fused_tables = {
            "keep": (~a.is_driven).astype(np.int32),
            "pin_mask": pin_mask,
            "pe_in": pe_in,
            "pe_res_idx": pe_res_idx,
            "num_pe_slots": p,
        }
        self._stream_tables: Optional[Dict[str, np.ndarray]] = None

    def stream_tables(self) -> Dict[str, np.ndarray]:
        """Node tables for the streamed fused engine: the node → state
        gather map for scatter-free per-cycle re-pinning. State layout is
        ``[regs | ext io | mem | zero]``; every non-pinned node points at
        the trailing zero slot."""
        if self._stream_tables is None:
            a = self.arrays
            n_reg = len(a.reg_ids)
            s_len = n_reg + self.num_io + self.num_mem + 1
            pin_src = np.full(a.num_nodes, s_len - 1, dtype=np.int32)
            if n_reg:
                pin_src[a.reg_ids] = np.arange(n_reg, dtype=np.int32)
            if self.num_io:
                pin_src[self.io_in_nodes] = n_reg + np.arange(
                    self.num_io, dtype=np.int32)
            if self.num_mem:
                pin_src[self.mem_out] = n_reg + self.num_io + np.arange(
                    self.num_mem, dtype=np.int32)
            self._stream_tables = {
                "pin_src": pin_src,
                "reg_src": a.reg_src.astype(np.int32),
                "mem_in": self.mem_in.astype(np.int32),
                "io_out": self.io_out_nodes.astype(np.int32),
                "n_reg": n_reg,
            }
        return self._stream_tables

    # -------------------------------------------------------------- interface
    @property
    def num_config(self) -> int:
        return self.arrays.num_config

    def init_state(self) -> Dict[str, jnp.ndarray]:
        return {
            "regs": jnp.zeros(len(self.arrays.reg_ids), dtype=jnp.int32),
            "mem": jnp.zeros(max(self.num_mem, 1), dtype=jnp.int32),
        }

    def init_state_batch(self, batch: int) -> Dict[str, jnp.ndarray]:
        """State for ``batch`` independent configurations (leading B dim)."""
        return {
            "regs": jnp.zeros((batch, len(self.arrays.reg_ids)),
                              dtype=jnp.int32),
            "mem": jnp.zeros((batch, max(self.num_mem, 1)),
                             dtype=jnp.int32),
        }

    def default_pe_cfg(self) -> Dict[str, jnp.ndarray]:
        n = max(self.num_pe, 1)
        return {
            "op": jnp.full((n,), PE_OP_IDS["add"], dtype=jnp.int32),
            "const": jnp.zeros((n,), dtype=jnp.int32),
            # per-port packed-constant immediates (packing stage, §3.4)
            "imm_mask": jnp.zeros((n, 4), dtype=jnp.int32),
            "imm_val": jnp.zeros((n, 4), dtype=jnp.int32),
        }

    def default_pe_cfg_batch(self, batch: int) -> Dict[str, jnp.ndarray]:
        one = self.default_pe_cfg()
        return {k: jnp.broadcast_to(v, (batch,) + v.shape)
                for k, v in one.items()}

    # ------------------------------------------------------------- evaluation
    def _selects(self, config: jnp.ndarray) -> jnp.ndarray:
        """Per-node mux select: config value clipped to fan-in, 0 default."""
        a = self.arrays
        slot = jnp.asarray(a.config_slot)
        if a.num_config == 0:
            return jnp.zeros(a.num_nodes, dtype=jnp.int32)
        sel = jnp.where(slot >= 0,
                        config[jnp.clip(slot, 0, a.num_config - 1)],
                        0)
        return jnp.clip(sel, 0, jnp.maximum(jnp.asarray(a.fanin_count) - 1,
                                            0))

    def _sweep(self, vals_ext: jnp.ndarray, sel: jnp.ndarray) -> jnp.ndarray:
        """One combinational propagation sweep: the fabric hot loop.
        vals_ext has the zero sentinel appended (length N+1); returns (N,).
        """
        a = self.arrays
        if self.use_pallas:
            from repro.kernels import ops as kops
            new = kops.fabric_sweep(vals_ext, jnp.asarray(a.src), sel)
        else:
            src_sel = jnp.take_along_axis(
                jnp.asarray(a.src), sel[:, None], axis=1)[:, 0]
            new = vals_ext[src_sel]
        keep = jnp.asarray(~a.is_driven)
        return jnp.where(keep, vals_ext[:-1], new) \
                  .astype(jnp.int32)

    def _sweep_batch(self, vals_ext: jnp.ndarray,
                     sel: jnp.ndarray) -> jnp.ndarray:
        """Batched sweep: vals_ext (B, N+1), sel (B, N) -> (B, N).

        With ``use_pallas`` the batched kernel vectorizes over the
        configuration axis (bitstream-major layout); otherwise the single
        sweep is vmapped."""
        a = self.arrays
        src = jnp.asarray(a.src)
        if self.use_pallas:
            from repro.kernels import ops as kops
            new = kops.fabric_sweep_batch(vals_ext, src, sel)
        else:
            def one(v_ext, s):
                src_sel = jnp.take_along_axis(src, s[:, None],
                                              axis=1)[:, 0]
                return v_ext[src_sel]

            new = jax.vmap(one)(vals_ext, sel)
        keep = jnp.asarray(~a.is_driven)
        return jnp.where(keep[None, :], vals_ext[:, :-1], new) \
                  .astype(jnp.int32)

    def _eval_pes(self, vals: jnp.ndarray,
                  pe_cfg: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """vals is the (N,) vector; sentinel-padded PE inputs read 0 via the
        extended gather below."""
        if self.num_pe == 0:
            return vals
        vals_ext = jnp.concatenate([vals, jnp.zeros(1, jnp.int32)])
        ins = vals_ext[jnp.asarray(self.pe_in)]      # (n_pe, 4)
        if "imm_mask" in pe_cfg:
            ins = jnp.where(pe_cfg["imm_mask"][:self.num_pe] > 0,
                            pe_cfg["imm_val"][:self.num_pe], ins)
        a, b, c = ins[:, 0], ins[:, 1], ins[:, 2]
        op = pe_cfg["op"][:self.num_pe]
        const = pe_cfg["const"][:self.num_pe]
        candidates = pe_alu_candidates(a, b, c, const)   # (n_ops, n_pe)
        res0 = jnp.take_along_axis(candidates, op[None, :], axis=0)[0]
        res0 = res0 & WORD
        res1 = a & WORD                        # second output: pass-through
        out_ids = jnp.asarray(self.pe_out)
        vals = vals.at[out_ids[:, 0]].set(res0)
        if self.pe_out.shape[1] > 1:
            vals = vals.at[out_ids[:, 1]].set(res1)
        return vals

    def step(self, state: Dict[str, jnp.ndarray], ext_in: jnp.ndarray,
             config: jnp.ndarray,
             pe_cfg: Optional[Dict[str, jnp.ndarray]] = None,
             depth: int = 16) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
        """One fabric clock cycle.

        state: registers/mem. ext_in: (num_io,) values driven onto io_out
        ports. config: (num_config,) mux selects. Returns (state', io_out
        observations). ``depth`` = fixpoint sweeps (≥ longest configured
        combinational chain).
        """
        if pe_cfg is None:
            pe_cfg = self.default_pe_cfg()
        a = self.arrays
        sel = self._selects(config)
        # value vector with zero sentinel at index N
        vals = jnp.zeros(a.num_nodes, dtype=jnp.int32)
        if len(a.reg_ids):
            vals = vals.at[jnp.asarray(a.reg_ids)].set(state["regs"])
        if self.num_io:
            vals = vals.at[jnp.asarray(self.io_in_nodes)].set(
                ext_in.astype(jnp.int32))
        if self.num_mem:
            vals = vals.at[jnp.asarray(self.mem_out)].set(
                state["mem"][:self.num_mem])

        def body(_, v):
            v_ext = jnp.concatenate([v, jnp.zeros(1, jnp.int32)])
            v = self._sweep(v_ext, sel)
            # re-pin sources each sweep
            if len(a.reg_ids):
                v = v.at[jnp.asarray(a.reg_ids)].set(state["regs"])
            if self.num_io:
                v = v.at[jnp.asarray(self.io_in_nodes)].set(
                    ext_in.astype(jnp.int32))
            if self.num_mem:
                v = v.at[jnp.asarray(self.mem_out)].set(
                    state["mem"][:self.num_mem])
            v = self._eval_pes(v, pe_cfg)
            return v

        vals = jax.lax.fori_loop(0, depth, body, vals)
        vals_ext = jnp.concatenate([vals, jnp.zeros(1, jnp.int32)])
        new_state = dict(state)
        if len(a.reg_ids):
            new_state["regs"] = vals_ext[jnp.asarray(a.reg_src)]
        if self.num_mem:
            new_state["mem"] = state["mem"].at[:self.num_mem].set(
                vals_ext[jnp.asarray(self.mem_in)])
        io_obs = (vals_ext[jnp.asarray(self.io_out_nodes)]
                  if self.num_io else jnp.zeros(0, jnp.int32))
        return new_state, io_obs

    def run(self, config: jnp.ndarray, ext_stream: jnp.ndarray,
            pe_cfg: Optional[Dict[str, jnp.ndarray]] = None,
            depth: Optional[int] = None) -> jnp.ndarray:
        """Run T cycles; ext_stream (T, num_io) -> observations (T, num_io).

        ``depth=None`` computes the per-config combinational depth from the
        configured network (host-side; requires a concrete config)."""
        if depth is None:
            depth = self.combinational_depth(np.asarray(config))
        state = self.init_state()

        def scan_fn(st, x):
            st, obs = self.step(st, x, config, pe_cfg, depth=depth)
            return st, obs

        _, out = jax.lax.scan(scan_fn, state, ext_stream)
        return out

    def _norm_depth(self, depth: DepthSpec, max_depth: Optional[int],
                    b: int) -> Tuple[jnp.ndarray, int]:
        """Normalize a depth spec into ((B,) per-lane sweep counts,
        static loop bound). A traced per-lane array needs an explicit
        ``max_depth`` (e.g. under shard_map, where the lane axis is a
        device-local slice of host-computed depths)."""
        if isinstance(depth, (int, np.integer)):
            md = int(depth) if max_depth is None else int(max_depth)
            return jnp.full((b,), int(depth), jnp.int32), md
        if max_depth is None:
            try:
                max_depth = int(np.max(np.asarray(depth))) if b else 1
            except jax.errors.TracerArrayConversionError as e:
                raise ValueError(
                    "step_batch with a traced per-lane depth array needs "
                    "an explicit static max_depth") from e
        return jnp.asarray(depth, jnp.int32), int(max_depth)

    def _norm_pe_cfg(self, pe_cfg: Dict[str, jnp.ndarray], b: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                jnp.ndarray]:
        """PE program tables shaped for the fused kernel: (B, P) op/const
        and (B, P, 4) immediates, P = max(num_pe, 1) slots."""
        p = self.fused_tables["num_pe_slots"]
        npe = self.num_pe

        def pad2(x):
            x = jnp.asarray(x, jnp.int32)[:, :npe]
            return jnp.pad(x, ((0, 0), (0, p - npe)))

        def pad3(key):
            if key not in pe_cfg:
                return jnp.zeros((b, p, 4), jnp.int32)
            x = jnp.asarray(pe_cfg[key], jnp.int32)[:, :npe]
            return jnp.pad(x, ((0, 0), (0, p - npe), (0, 0)))

        return (pad2(pe_cfg["op"]), pad2(pe_cfg["const"]),
                pad3("imm_mask"), pad3("imm_val"))

    def step_batch(self, state: Dict[str, jnp.ndarray], ext_in: jnp.ndarray,
                   config: jnp.ndarray,
                   pe_cfg: Optional[Dict[str, jnp.ndarray]] = None,
                   depth: DepthSpec = 16,
                   max_depth: Optional[int] = None,
                   fused: Optional[bool] = None
                   ) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
        """One fabric clock cycle for B configurations at once.

        Every argument carries a leading batch dim: state regs (B, R) /
        mem (B, M), ext_in (B, num_io), config (B, num_config), pe_cfg
        leaves (B, ...). Returns (state', (B, num_io) observations).

        ``depth`` is either a shared int or a (B,) per-configuration sweep
        count: every lane runs the static ``max_depth`` loop but freezes
        once its own count is reached, so each configuration performs
        exactly its own fixpoint. ``fused`` (default True) runs the whole
        fixpoint — PE evaluation included — as one fused kernel call
        (``fabric_fused_batch`` when ``use_pallas``, its vmapped pure-jnp
        oracle otherwise); ``fused=False`` keeps the sweep-at-a-time loop
        (per-sweep batched Pallas gather + Python-level PE evaluation),
        bit-identical, as the unfused baseline."""
        b = config.shape[0]
        if pe_cfg is None:
            pe_cfg = self.default_pe_cfg_batch(b)
        if fused is None:
            fused = True
        a = self.arrays
        depths, max_depth = self._norm_depth(depth, max_depth, b)
        sel = jax.vmap(self._selects)(config)          # (B, N)

        def pin(v):
            if len(a.reg_ids):
                v = v.at[:, jnp.asarray(a.reg_ids)].set(state["regs"])
            if self.num_io:
                v = v.at[:, jnp.asarray(self.io_in_nodes)].set(
                    ext_in.astype(jnp.int32))
            if self.num_mem:
                v = v.at[:, jnp.asarray(self.mem_out)].set(
                    state["mem"][:, :self.num_mem])
            return v

        # pinned sources on a zero background double as the initial values
        pin_vals = pin(jnp.zeros((b, a.num_nodes), dtype=jnp.int32))

        if fused:
            t = self.fused_tables
            op, const, imm_mask, imm_val = self._norm_pe_cfg(pe_cfg, b)
            if self.use_pallas:
                from repro.kernels import ops as kops
                vals = kops.fabric_fused_batch(
                    pin_vals, sel, pin_vals, depths, op, const, imm_mask,
                    imm_val, jnp.asarray(a.src),
                    jnp.asarray(t["keep"]), jnp.asarray(t["pin_mask"]),
                    jnp.asarray(t["pe_in"]), jnp.asarray(t["pe_res_idx"]),
                    max_depth=max_depth, word=WORD)
            else:
                from repro.kernels import ref as kref
                vals = kref.fabric_fused_batch_ref(
                    pin_vals, sel, pin_vals, depths, op, const, imm_mask,
                    imm_val, jnp.asarray(a.src),
                    jnp.asarray(t["keep"]), jnp.asarray(t["pin_mask"]),
                    jnp.asarray(t["pe_in"]), jnp.asarray(self.pe_out),
                    max_depth=max_depth, word=WORD)
        else:
            def body(i, v):
                v_ext = jnp.concatenate(
                    [v, jnp.zeros((b, 1), jnp.int32)], axis=1)
                nv = self._sweep_batch(v_ext, sel)
                nv = pin(nv)
                nv = jax.vmap(self._eval_pes)(nv, pe_cfg)
                return jnp.where((i < depths)[:, None], nv, v)

            vals = jax.lax.fori_loop(0, max_depth, body, pin_vals)

        vals_ext = jnp.concatenate(
            [vals, jnp.zeros((b, 1), jnp.int32)], axis=1)
        new_state = dict(state)
        if len(a.reg_ids):
            new_state["regs"] = vals_ext[:, jnp.asarray(a.reg_src)]
        if self.num_mem:
            new_state["mem"] = state["mem"].at[:, :self.num_mem].set(
                vals_ext[:, jnp.asarray(self.mem_in)])
        io_obs = (vals_ext[:, jnp.asarray(self.io_out_nodes)]
                  if self.num_io else jnp.zeros((b, 0), jnp.int32))
        return new_state, io_obs

    def _run_batch_stream(self, configs: jnp.ndarray, ext: jnp.ndarray,
                          pe_cfgs: Dict[str, jnp.ndarray],
                          depths: jnp.ndarray, max_depth: int,
                          io_chunk: int) -> jnp.ndarray:
        """Streamed fused engine: the whole T-cycle emulation in one
        kernel invocation, ext-IO gridded from HBM in ``io_chunk``-cycle
        blocks instead of materializing (B, T, io) beside the value
        matrices in VMEM. Bit-identical to the per-cycle scan."""
        from repro.kernels import ops as kops

        a = self.arrays
        b = configs.shape[0]
        sel = jax.vmap(self._selects)(configs)
        op, const, imm_mask, imm_val = self._norm_pe_cfg(pe_cfgs, b)
        t = self.fused_tables
        s = self.stream_tables()
        return kops.fabric_fused_run(
            sel, ext, depths, op, const, imm_mask, imm_val,
            jnp.asarray(a.src), jnp.asarray(t["keep"]),
            jnp.asarray(t["pin_mask"]), jnp.asarray(s["pin_src"]),
            jnp.asarray(t["pe_in"]), jnp.asarray(t["pe_res_idx"]),
            jnp.asarray(s["reg_src"]), jnp.asarray(s["mem_in"]),
            jnp.asarray(s["io_out"]), n_reg=s["n_reg"],
            n_io=self.num_io, n_mem=self.num_mem, max_depth=max_depth,
            chunk=io_chunk, word=WORD)

    def _run_batch_local(self, configs: jnp.ndarray, ext: jnp.ndarray,
                         pe_cfgs: Dict[str, jnp.ndarray],
                         depths: jnp.ndarray, max_depth: int,
                         fused: Optional[bool],
                         io_chunk: Optional[int] = None) -> jnp.ndarray:
        """One device's share of ``run_batch``: scan T cycles over a
        (local) batch of configurations — or, with ``io_chunk`` on the
        Pallas fused engine, one streamed multi-cycle kernel call."""
        if io_chunk and self.use_pallas and (fused is None or fused):
            return self._run_batch_stream(configs, ext, pe_cfgs, depths,
                                          max_depth, io_chunk)
        b = configs.shape[0]
        state = self.init_state_batch(b)
        xs = jnp.swapaxes(ext, 0, 1)                    # (T, B, io)

        def scan_fn(st, x):
            st, obs = self.step_batch(st, x, configs, pe_cfgs,
                                      depth=depths, max_depth=max_depth,
                                      fused=fused)
            return st, obs

        _, out = jax.lax.scan(scan_fn, state, xs)
        return jnp.swapaxes(out, 0, 1)                  # (B, T, io)

    def run_batch(self, configs: jnp.ndarray, ext_streams: jnp.ndarray,
                  pe_cfgs: Optional[Dict[str, jnp.ndarray]] = None,
                  depth: Optional[DepthSpec] = None,
                  fused: Optional[bool] = None,
                  shard: Optional[bool] = None,
                  io_chunk: Optional[int] = None) -> jnp.ndarray:
        """Evaluate B configurations in one ``lax.scan``.

        configs: (B, num_config); ext_streams: (B, T, num_io); pe_cfgs
        leaves (B, ...). Returns (B, T, num_io) observations — the batched
        equivalent of looping ``run`` over the B axis, bit-identical to it
        lane for lane. ``depth=None`` computes every configuration's own
        combinational depth on the host; a lane freezes once its own count
        is reached (masked early exit), so even an adversarial config with
        a combinational loop — whose values depend on the sweep count —
        sees exactly the sweeps its per-config ``run`` would.

        ``shard`` (default: auto, on when >1 device) splits the batch axis
        across ``jax.devices()`` via shard_map, padding B up to a multiple
        of the device count; on a single device the local path runs
        unsharded. ``fused`` selects the fused kernel engine (default) or
        the sweep-at-a-time baseline.

        ``io_chunk`` streams the external IO from HBM in chunks of that
        many cycles through the fused multi-cycle kernel
        (``fabric_fused_run``) instead of scanning one kernel call per
        cycle — for long stimulus traces only (B, io_chunk, io) of the
        stimulus is resident per grid step. Requires ``use_pallas`` and
        the fused engine; otherwise it is ignored (the reference scan
        already keeps the trace in host/HBM memory). Bit-identical to the
        unstreamed path either way."""
        configs = jnp.asarray(configs)
        ext = jnp.asarray(ext_streams)
        b = configs.shape[0]
        if depth is None:
            host_cfgs = np.asarray(configs)
            depths_np = np.array(
                [self.combinational_depth(c) for c in host_cfgs],
                dtype=np.int32) if b else np.zeros(0, np.int32)
        else:
            depths_np = np.broadcast_to(
                np.asarray(depth, np.int32), (b,))
        max_depth = int(depths_np.max()) if b else 1
        if pe_cfgs is None:
            pe_cfgs = self.default_pe_cfg_batch(b)
        devices = jax.devices()
        n_dev = len(devices)
        use_shard = (n_dev > 1) if shard is None else shard
        if not use_shard or n_dev <= 1 or b == 0:
            return self._run_batch_local(configs, ext, pe_cfgs,
                                         jnp.asarray(depths_np),
                                         max_depth, fused, io_chunk)

        bp = -(-b // n_dev) * n_dev                     # ceil to devices
        pad = bp - b

        def pad_b(x):
            x = jnp.asarray(x)
            return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))

        mesh = Mesh(np.array(devices), ("b",))
        spec = PartitionSpec("b")

        def local(c, e, p, d):
            return self._run_batch_local(c, e, p, d, max_depth, fused,
                                         io_chunk)

        # check_rep=False: shard_map has no replication rule for
        # pallas_call; every operand/output is explicitly batch-sharded
        sharded = shard_map(local, mesh=mesh,
                            in_specs=(spec, spec, spec, spec),
                            out_specs=spec, check_rep=False)
        out = sharded(pad_b(configs), pad_b(ext),
                      {k: pad_b(v) for k, v in pe_cfgs.items()},
                      jnp.asarray(np.pad(depths_np, (0, pad))))
        return out[:b]

    # ------------------------------------------------- combinational depth
    def _selected_src_host(self, config: np.ndarray) -> np.ndarray:
        """Host-side selected source per node under ``config`` (N,)."""
        a = self.arrays
        sel = np.zeros(a.num_nodes, np.int64)
        mask = a.config_slot >= 0
        if a.num_config:
            cfg = np.asarray(config, np.int64)
            sel[mask] = cfg[a.config_slot[mask]]
        sel = np.clip(sel, 0, np.maximum(a.fanin_count - 1, 0))
        return a.src[np.arange(a.num_nodes), sel]

    def combinational_depth(self, config: np.ndarray,
                            margin: int = 1) -> int:
        """Sweeps needed to reach the fixpoint under ``config``: longest
        register-free chain of the *configured* network (each mux follows
        only its selected input), instead of the conservative fixed bound.

        Chains are rooted at pinned nodes (registers, externally driven IO,
        memory outputs, undriven nodes); a PE output sits one level above
        its deepest input. A legal configuration's active network is
        acyclic; combinational cycles through unconfigured default-0 muxes
        are detected and excluded (their values never stabilize and no
        routed path goes through them)."""
        a = self.arrays
        n = a.num_nodes
        src_sel = self._selected_src_host(config)
        pinned = (~a.is_driven) | a.is_reg
        if len(self.io_in_nodes):
            pinned[self.io_in_nodes] = True
        if len(self.mem_out):
            pinned[self.mem_out] = True
        derive = ~pinned
        depth = np.zeros(n + 1, np.int64)       # sentinel at n stays 0
        prev_changed: Optional[np.ndarray] = None
        cap = min(n + 2, 4096)
        for _ in range(cap):
            new = depth.copy()
            new[:n][derive] = depth[src_sel[derive]] + 1
            if self.num_pe:
                pe_depth = depth[self.pe_in].max(axis=1) + 1   # (n_pe,)
                for col in range(self.pe_out.shape[1]):
                    new[self.pe_out[:, col]] = pe_depth
            new[n] = 0
            changed = np.nonzero(new != depth)[0]
            depth = new
            if changed.size == 0:
                return int(depth.max()) + margin
            if (prev_changed is not None
                    and np.array_equal(changed, prev_changed)):
                # a set equal to its own successor set contains a cycle:
                # report the depth of the stable (acyclic) portion only
                stable = np.ones(n + 1, bool)
                stable[changed] = False
                d = int(depth[stable].max()) if stable.any() else 0
                return max(d + margin, 1)
            prev_changed = changed
        return cap

    def depth_for_route(self, edges: Sequence[Tuple[Node, Node]],
                        margin: int = 2) -> int:
        """Sweeps needed to emulate a routed application: longest
        register-free chain along the routed tree (PE core hops included),
        replacing the conservative ``len(edges) + 4`` bound."""
        sentinel = self.arrays.num_nodes
        is_reg = self.arrays.is_reg
        children: Dict[int, List[Tuple[int, int]]] = {}
        indeg: Dict[int, int] = {}
        nodes = set()

        def add_edge(u: int, v: int, w: int) -> None:
            children.setdefault(u, []).append((v, w))
            indeg[v] = indeg.get(v, 0) + 1
            nodes.add(u)
            nodes.add(v)

        for s, d in edges:
            add_edge(self.node_id[s], self.node_id[d], 1)
        # PE core hops are weight 0: _eval_pes runs after the gather, so a
        # PE output settles in the same sweep as its inputs
        for k in range(self.num_pe):
            ins = [int(i) for i in self.pe_in[k] if i != sentinel]
            for col in range(self.pe_out.shape[1]):
                out = int(self.pe_out[k, col])
                for i in ins:
                    add_edge(i, out, 0)
        # longest path over the routed DAG; registers restart the chain
        depth = {i: 0 for i in nodes}
        ready = [i for i in nodes if indeg.get(i, 0) == 0]
        seen = 0
        while ready:
            u = ready.pop()
            seen += 1
            du = 0 if is_reg[u] else depth[u]
            for v, w in children.get(u, ()):
                if not is_reg[v]:
                    depth[v] = max(depth[v], du + w)
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        if seen != len(nodes):
            # combinational loop through a PE (route feeds the PE its own
            # output): fall back to the conservative bound
            return len(list(edges)) + 4
        return max(depth.values(), default=0) + margin

    # ------------------------------------------------------- route → config
    def route_to_config(self, edges: Sequence[Tuple[Node, Node]]
                        ) -> np.ndarray:
        """Translate routed IR edges into a config vector: for every edge
        (src → dst) where dst is a mux, set dst's select to src's input
        index. Conflicting assignments raise (illegal route)."""
        config = np.zeros(self.num_config, dtype=np.int32)
        assigned: Dict[int, int] = {}
        for src, dst in edges:
            i = self.node_id[dst]
            slot = self.arrays.config_slot[i]
            if slot < 0:
                continue                    # single-input: hardwired
            sel = dst.fan_in.index(src)
            if i in assigned and assigned[i] != sel:
                raise ValueError(
                    f"conflicting mux assignment at {dst}: "
                    f"{assigned[i]} vs {sel}")
            assigned[i] = sel
            config[slot] = sel
        return config

    def structural_connectivity(self) -> Dict[Tuple, List[Tuple]]:
        """Connectivity as realized by the lowered tables — compared against
        the IR by repro.core.verify (paper: parse generated RTL)."""
        out: Dict[Tuple, List[Tuple]] = {}
        a = self.arrays
        for i, node in enumerate(self.nodes):
            keys = []
            for j in range(a.fanin_count[i]):
                keys.append(self.nodes[a.src[i, j]].node_key())
            out[node.node_key()] = keys
        return out


def compile_interconnect(ic: Interconnect,
                         use_pallas: bool = False) -> FabricModule:
    """The static-backend entry point (IR → hardware, §3.3)."""
    return FabricModule(ic, use_pallas=use_pallas)
