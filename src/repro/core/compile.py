"""The compiled-design handle behind ``canal.compile``.

``compile_spec(InterconnectSpec(...))`` (re-exported as ``canal.compile``)
runs the pass pipeline and returns a :class:`CompiledFabric`: one object
that owns the IR plus lazily-built, memoized backends —
``place_and_route(app)``, ``emulate(...)``, ``area()``,
``bitstream(cfg)``. Spec route knobs (``route_strategy``,
``auto_min_tiles``) flow through automatically, and ``spec.digest()`` /
``ir_digest()`` give the content addresses used for spec-keyed caching.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .graph import Interconnect, Node
from .spec import InterconnectSpec

Coord = Tuple[int, int]


class CompiledFabric:
    """A compiled interconnect design point.

    Construction goes through :meth:`repro.core.passes.PassManager.compile`
    (or the ``canal.compile`` / :func:`compile_spec` front door) — the
    constructor only binds the already-compiled IR.
    """

    def __init__(self, spec: InterconnectSpec, ic: Interconnect,
                 pass_log: Optional[List[Dict]] = None,
                 use_pallas: bool = False, cacheable: bool = True,
                 diagnostics=None):
        self.spec = spec
        self._ic = ic
        self.pass_log = list(pass_log or [])
        self.use_pallas = use_pallas
        #: False when a custom (non-serializable) core_fn was injected:
        #: the spec digest then under-describes the design, so
        #: digest-keyed caches must not admit this fabric
        self.cacheable = cacheable
        #: the static-analysis AnalysisReport produced at compile time
        #: (None when compiled with analyze="off" or constructed raw)
        self.diagnostics = diagnostics
        self._fabrics: Dict[Tuple[bool, bool], object] = {}
        self._resources: Dict[float, object] = {}
        self._codec = None

    # ------------------------------------------------------------- identity
    @property
    def interconnect(self) -> Interconnect:
        return self._ic

    def digest(self) -> str:
        """The design point's content address (= ``spec.digest()``)."""
        return self.spec.digest()

    def ir_digest(self) -> str:
        """Content hash of the compiled IR (see ``passes.ir_digest``)."""
        from .passes import ir_digest
        return ir_digest(self._ic)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        s = self.spec
        return (f"CompiledFabric({s.width}x{s.height}, "
                f"{s.num_tracks}x{s.track_width}b {s.sb_type.value}, "
                f"digest={self.digest()[:12]})")

    # ------------------------------------------------------------- backends
    def fabric(self, use_pallas: Optional[bool] = None):
        """The lowered functional model: :class:`FabricModule` for the
        static interconnect, :class:`repro.fabric.RVFabric` when the spec
        requests the hybrid ready-valid interconnect. Memoized per
        (engine, rv) pair."""
        up = self.use_pallas if use_pallas is None else use_pallas
        key = (up, self.spec.ready_valid)
        fab = self._fabrics.get(key)
        if fab is None:
            if self.spec.ready_valid:
                from repro.fabric import RVFabric
                # the readyvalid_transform pass annotated the IR; the
                # lowering consumes that annotation, not the raw spec
                mode = self._ic.params["rv_fifo_mode"]
                fab = RVFabric(self._ic, fifo_mode=mode, use_pallas=up)
            else:
                from .lowering import FabricModule
                fab = FabricModule(self._ic, use_pallas=up)
            self._fabrics[key] = fab
        return fab

    def resources(self, reg_penalty: float = 4.0):
        """Shared :class:`RoutingResources` (adjacency, base costs,
        coarse graph), memoized per ``reg_penalty``."""
        from .pnr.route import RoutingResources
        key = float(reg_penalty)
        res = self._resources.get(key)
        if res is None:
            res = RoutingResources(self._ic, reg_penalty=reg_penalty)
            self._resources[key] = res
        return res

    # ------------------------------------------------------------- analysis
    def analyze(self, rules: Optional[Sequence[str]] = None,
                fail_on: Optional[str] = None,
                scope: str = "ir",
                pnr=None,
                clock_ns: Optional[float] = None,
                severities: Optional[Dict[str, object]] = None):
        """(Re-)run the static analyzer on this design point and return
        the :class:`AnalysisReport` — for subsets or severities beyond
        what the compile-time ``analyze=`` knob recorded in
        :attr:`diagnostics`, or for other scopes: pass
        ``scope="routed"`` with a ``pnr=`` :class:`PnRResult` to audit a
        configured design (deadlock / throughput / slack / congestion /
        X-propagation; add ``clock_ns=`` for a slack target)."""
        from .analysis import analyze as run_rules
        return run_rules(self._ic, spec=self.spec, rules=rules,
                         scope=scope, pnr=pnr, clock_ns=clock_ns,
                         severities=severities, fail_on=fail_on)

    def verify(self, rules: Optional[Sequence[str]] = None,
               fail_on: Optional[str] = "error",
               use_pallas: Optional[bool] = None):
        """Run the post-lowering verification analyses (the paper's §3.3
        checks, registered as ``scope="lowered"`` rules:
        ``structural-equivalence`` and the exhaustive ``config-sweep``)
        against this fabric's lowered module. Costs device time —
        deliberately not part of compile-time analysis. Raises
        :class:`AnalysisError` at ``fail_on`` severity (pass ``None`` to
        only report); returns the :class:`AnalysisReport`."""
        from .analysis import analyze as run_rules
        if self.spec.ready_valid:
            raise NotImplementedError(
                "lowered verification covers the static interconnect; "
                "the ready-valid fabric has its own emulation tests")
        return run_rules(self._ic, spec=self.spec, rules=rules,
                         scope="lowered", fabric=self.fabric(use_pallas),
                         fail_on=fail_on)

    # ------------------------------------------------------------------ PnR
    def place_and_route(self, app,
                        alphas: Optional[Sequence[float]] = None,
                        sa_steps: Optional[int] = None,
                        sa_batch: Optional[int] = None,
                        seed: Optional[int] = None,
                        reg_penalty: Optional[float] = None,
                        route_strategy: Optional[str] = None,
                        place_strategy: Optional[str] = None,
                        **kwargs):
        """Pack, place and route ``app`` on this fabric (paper §3.4).

        Every PnR knob resolves spec-first: a per-call argument wins,
        then the spec's folded knob (``spec.alphas``, ``spec.sa_steps``,
        ...), then the historical front-door default — so a fully-pinned
        spec (one whose ``digest()`` addresses the result store) routes
        identically here and in the DSE executor.

        On success the routed-scope analysis report is attached as
        ``result.analysis`` (``analyze(scope="routed", ...)`` re-runs it
        with a clock target or custom severities)."""
        from .pnr import place_and_route as pnr
        s = self.spec

        def pick(call_value, spec_value, default):
            if call_value is not None:
                return call_value
            return spec_value if spec_value is not None else default

        strategy = (route_strategy or s.route_strategy or "auto")
        p_strat = (place_strategy or s.place_strategy or "auto")
        if (kwargs.get("split_fifo_ctrl_delay") is None
                and s.split_fifo_ctrl_delay is not None):
            kwargs["split_fifo_ctrl_delay"] = s.split_fifo_ctrl_delay
        result = pnr(self._ic, app,
                     alphas=pick(alphas, s.alphas, (1.0, 2.0, 4.0)),
                     sa_steps=pick(sa_steps, s.sa_steps, 200),
                     sa_batch=pick(sa_batch, s.sa_batch, 32),
                     seed=pick(seed, s.seed, 0),
                     resources=self.resources(
                         pick(reg_penalty, s.reg_penalty, 4.0)),
                     route_strategy=strategy,
                     auto_min_tiles=s.auto_min_tiles,
                     place_strategy=p_strat, **kwargs)
        if result.success:
            result.analysis = self.analyze(scope="routed", pnr=result)
        return result

    # ------------------------------------------------------------ emulation
    def emulate(self, result, inputs: Dict[Union[str, Coord], np.ndarray],
                cycles: int,
                use_pallas: Optional[bool] = None) -> Dict[Coord,
                                                           np.ndarray]:
        """Emulate a routed application for ``cycles`` fabric clocks.

        ``result`` is the :class:`PnRResult` from
        :meth:`place_and_route`; ``inputs`` maps IO tiles — by ``(x, y)``
        coordinate or by app instance name — to driven value streams.
        Returns observed output streams keyed by IO tile coordinate."""
        from repro.fabric import AppEmulator

        if not result.success:
            raise ValueError(f"cannot emulate failed PnR: {result.error}")
        fab = self.fabric(use_pallas)
        emu = AppEmulator.from_pnr(fab, result.packed, result)
        ins: Dict[Coord, np.ndarray] = {}
        for k, v in inputs.items():
            coord = result.placement[k] if isinstance(k, str) else k
            ins[coord] = np.asarray(v, dtype=np.int32)
        return emu.run(ins, cycles)

    # ----------------------------------------------------------------- PPA
    def area(self) -> Dict[str, float]:
        """Analytical GF12-calibrated area of the design point, in µm²
        (ready-valid FIFO overhead included when the spec asks for it)."""
        from .area import connection_box_area, switch_box_area
        if self.spec.ready_valid:
            rv = "split" if self.spec.split_fifo else "full"
            sb = switch_box_area(self._ic, rv=rv)
        else:
            sb = switch_box_area(self._ic)
        return {"sb_area": sb, "cb_area": connection_box_area(self._ic)}

    # ------------------------------------------------------------ bitstream
    def bitstream(self, cfg):
        """Configuration words for ``cfg``: a :class:`PnRResult` (route
        edges -> mux selects), a list of routed IR edges, or a raw
        ``(num_config,)`` select vector."""
        from .bitstream import BitstreamCodec
        if self._codec is None:
            self._codec = BitstreamCodec(self.fabric())
        codec = self._codec
        if hasattr(cfg, "route_edges"):
            return codec.words_for_route(cfg.route_edges())
        if (isinstance(cfg, (list, tuple)) and cfg
                and isinstance(cfg[0], tuple)
                and isinstance(cfg[0][0], Node)):
            return codec.words_for_route(cfg)
        return codec.encode(np.asarray(cfg, dtype=np.int32))


def compile_spec(spec: InterconnectSpec, core_fn=None,
                 use_pallas: bool = False,
                 passes=None,
                 analyze: str = "warn",
                 analyze_per_pass: bool = False) -> CompiledFabric:
    """The single front door (``canal.compile``): compile a declarative
    :class:`InterconnectSpec` through the pass pipeline into a
    :class:`CompiledFabric`. ``passes`` overrides the default pipeline
    (a sequence of :class:`repro.core.passes.IRPass`); ``analyze``
    gates the static analyzer (``"error"`` raises on error-severity
    findings, ``"warn"`` — the default — records the report on
    ``CompiledFabric.diagnostics``, ``"off"`` skips it) and
    ``analyze_per_pass`` attributes each finding to the pipeline pass
    that introduced it."""
    from .passes import DEFAULT_PASSES, PassManager
    pm = PassManager(DEFAULT_PASSES if passes is None else passes)
    return pm.compile(spec, core_fn=core_fn, use_pallas=use_pallas,
                      analyze=analyze, analyze_per_pass=analyze_per_pass)
