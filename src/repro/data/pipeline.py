"""Deterministic synthetic data pipeline.

Produces packed LM batches with document structure (Zipf-distributed
tokens, EOS-separated documents), sharded across hosts: each process
materializes only its slice of the global batch (process_index-based),
so the pipeline scales to multi-pod topologies without a central reader.
A background prefetch thread keeps one batch in flight.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 2
    mean_doc_len: int = 512
    process_index: int = 0
    process_count: int = 1

    def __post_init__(self):
        if self.global_batch % self.process_count:
            raise ValueError("global_batch must divide across processes")
        self.local_batch = self.global_batch // self.process_count

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic per-(step, process) packed batch."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.process_index)
        b, s = self.local_batch, self.seq_len
        # Zipf-ish token distribution (truncated)
        ranks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        tokens = (ranks % (self.vocab_size - 3)) + 3
        # EOS-separated document packing
        doc_break = rng.random((b, s + 1)) < 1.0 / self.mean_doc_len
        tokens = np.where(doc_break, self.eos_id, tokens)
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }


def make_batch_iterator(ds: SyntheticTokens, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[Dict[str,
                                                            np.ndarray]]:
    """Background-prefetched iterator (restartable from any step)."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(ds.batch(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
