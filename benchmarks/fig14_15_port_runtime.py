"""Figs. 14/15 — application run time vs SB / CB core-port connections.

Paper: reducing SB-side connections has a small negative effect on run
time; reducing CB-side connections has a larger one.
"""
from __future__ import annotations

from repro.core.dse import sweep_port_connections
from repro.core.pnr.app import BENCH_APPS

from .common import emit, save_json, timed


def run(quick: bool = False):
    apps = {k: BENCH_APPS[k] for k in
            (("tree_reduce", "butterfly") if quick else
             ("pointwise", "tree_reduce", "fir", "butterfly"))}
    lines = []
    payload = {}
    for kind in ("sb", "cb"):
        recs, us = timed(lambda: sweep_port_connections(
            kind, sides=(4, 3, 2), apps=apps, sa_steps=40))
        for r in recs:
            oks = [a for a in r["apps"].values() if a["success"]]
            mean_crit = (sum(a["critical_path_ns"] for a in oks)
                         / len(oks) if oks else float("inf"))
            r["mean_critical_path_ns"] = mean_crit
            lines.append(emit(
                f"fig{'14' if kind == 'sb' else '15'}/"
                f"{kind}_sides={r['sides']}", us / len(recs),
                f"routed={len(oks)}/{len(r['apps'])} "
                f"mean_crit={mean_crit:.2f}ns"))
        payload[kind] = recs
    save_json("fig14_15_port_runtime", payload)
    return lines
