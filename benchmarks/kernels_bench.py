"""Kernel micro-benchmarks: interpret-mode correctness + host us/call.

On CPU the Pallas kernels run interpreted (correctness only — TPU is the
perf target); ``derived`` reports the max abs error vs the jnp oracle.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels import ops, ref

from .common import emit, timed


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    lines = []

    n, f = 2048, 6
    vals = jnp.asarray(rng.integers(0, 999, n + 1).astype(np.int32))
    src = jnp.asarray(rng.integers(0, n + 1, (n, f)).astype(np.int32))
    sel = jnp.asarray(rng.integers(0, f, n).astype(np.int32))
    out, us = timed(lambda: ops.fabric_sweep(vals, src, sel)
                    .block_until_ready())
    err = int(np.abs(np.asarray(out)
                     - np.asarray(ref.fabric_sweep_ref(vals, src,
                                                       sel))).max())
    lines.append(emit("kernel/fabric_sweep", us, f"maxerr={err}"))

    pins = jnp.asarray(rng.integers(0, 64, (1024, 8, 2)).astype(np.int32))
    mask = jnp.asarray((rng.random((1024, 8)) < 0.8).astype(np.int32))
    out, us = timed(lambda: ops.hpwl(pins, mask).block_until_ready())
    err = int(np.abs(np.asarray(out)
                     - np.asarray(ref.hpwl_ref(pins, mask))).max())
    lines.append(emit("kernel/hpwl", us, f"maxerr={err}"))

    d = jnp.asarray((rng.random((4, 256)) * 9).astype(np.float32))
    w = np.where(rng.random((256, 256)) < 0.05,
                 rng.random((256, 256)) * 3, 1e30)
    np.fill_diagonal(w, 0.0)
    w = jnp.asarray(w.astype(np.float32))
    out, us = timed(lambda: ops.minplus_step(d, w).block_until_ready())
    err = float(np.abs(np.asarray(out)
                       - np.asarray(ref.minplus_ref(d, w))).max())
    lines.append(emit("kernel/minplus", us, f"maxerr={err:.2e}"))

    sq = 256 if quick else 512
    q = jnp.asarray(rng.standard_normal((1, 4, sq, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, sq, 64)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, 2, sq, 64)).astype(np.float32))
    out, us = timed(lambda: ops.flash_attention(q, k, v)
                    .block_until_ready())
    kk, vv = jnp.repeat(k, 2, 1), jnp.repeat(v, 2, 1)
    want = ref.attention_ref(q.reshape(4, sq, 64), kk.reshape(4, sq, 64),
                             vv.reshape(4, sq, 64)).reshape(1, 4, sq, 64)
    err = float(np.abs(np.asarray(out) - np.asarray(want)).max())
    lines.append(emit("kernel/flash_attention", us, f"maxerr={err:.2e}"))

    bh, l, p, nst = 2, 256, 16, 8
    x = jnp.asarray(rng.standard_normal((bh, l, p)).astype(np.float32))
    dt = jnp.asarray((0.1 + rng.random((bh, l)) * 0.5).astype(np.float32))
    a = jnp.asarray((-0.5 - rng.random(bh)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((bh, l, nst)).astype(np.float32)
                    * 0.3)
    c = jnp.asarray(rng.standard_normal((bh, l, nst)).astype(np.float32)
                    * 0.3)
    out, us = timed(lambda: ops.ssd_scan(x, dt, a, b, c, chunk=128)
                    .block_until_ready())
    err = float(np.abs(np.asarray(out)
                       - np.asarray(ref.ssd_ref(x, dt, a, b, c))).max())
    lines.append(emit("kernel/ssd_scan", us, f"maxerr={err:.2e}"))
    return lines
