"""§Roofline — the full baseline table from the dry-run artifacts, plus
the Canal-ICI congestion-aware collective refinement (DESIGN.md §2)."""
from __future__ import annotations

import glob
import json
import os

from repro.core.ici import pod_collective_model

from .common import emit, save_json

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh: str = "single"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*",
                                              "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("tag"):
            continue                      # perf-iteration variants
        cells.append(rec)
    return cells


def run(quick: bool = False):
    lines = []
    table = []
    for rec in load_cells("single"):
        r = rec["roofline"]
        ici = pod_collective_model(
            rec["collectives"]["by_kind_traffic"], rec["mesh_axes"])
        row = {
            "arch": rec["arch"], "shape": rec["shape"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "roofline_fraction": r["roofline_fraction"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
            "ici_congestion_factor": ici["congestion_factor"],
            "ici_collective_s": ici["collective_time_s"],
        }
        table.append(row)
        lines.append(emit(
            f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
            f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"coll={r['collective_s']:.4f}s dom={r['dominant']} "
            f"frac={r['roofline_fraction']:.3f} "
            f"useful={rec['useful_flops_ratio']:.2f} "
            f"ici_cong={ici['congestion_factor']:.2f}"))
    if not table:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all` first")
        return lines
    save_json("roofline_table", table)

    # hillclimb candidate selection (assignment: worst fraction, most
    # collective-bound, most paper-representative)
    worst = min(table, key=lambda r: r["roofline_fraction"])
    coll = max(table, key=lambda r: r["collective_s"]
               / max(r["compute_s"] + r["memory_s"], 1e-12))
    lines.append(emit("roofline/worst_fraction", 0.0,
                      f"{worst['arch']}/{worst['shape']} "
                      f"frac={worst['roofline_fraction']:.3f}"))
    lines.append(emit("roofline/most_collective_bound", 0.0,
                      f"{coll['arch']}/{coll['shape']}"))
    return lines
