"""Abstract claim — "Canal enables fast design space exploration": IR
generation + hardware lowering speed vs array size, plus the batched DSE
engine: B fabric configurations emulated as one ``run_batch`` scan
(batched Pallas sweep kernel) vs the serial per-config baseline."""
from __future__ import annotations

from repro.core.dse import batched_vs_serial_emulation, generation_speed

from .common import emit, save_json, timed


def run(quick: bool = False):
    sizes = (4, 8, 16) if quick else (4, 8, 16, 32)
    recs, us = timed(lambda: generation_speed(sizes))
    lines = []
    for r in recs:
        lines.append(emit(
            f"dse_speed/array={r['size']}x{r['size']}", us / len(recs),
            f"nodes={r['nodes']} gen={r['gen_seconds'] * 1e3:.0f}ms "
            f"lower={r['lower_seconds'] * 1e3:.0f}ms"))

    # batched configuration emulation: the production run_batch path
    # (fabric_sweep_batch under use_pallas) vs looping run per config
    batch = 4 if quick else 8
    cycles = 8 if quick else 16
    emu = batched_vs_serial_emulation(width=4 if quick else 6,
                                      height=4 if quick else 6,
                                      num_tracks=2 if quick else 4,
                                      batch=batch, cycles=cycles,
                                      use_pallas=True)
    lines.append(emit(
        f"dse_speed/batched_emulation_b={emu['batch']}",
        emu["batched_seconds"] * 1e6,
        f"serial={emu['serial_seconds'] * 1e3:.0f}ms "
        f"batched={emu['batched_seconds'] * 1e3:.0f}ms "
        f"speedup={emu['speedup']:.2f}x depth={emu['depth']}"))
    # both paths are pre-warmed; the measured margin is ~2.5-4x, so a 1.5x
    # tolerance only absorbs shared-runner timing noise, not a regression
    assert emu["batched_seconds"] <= emu["serial_seconds"] * 1.5, \
        "batched DSE emulation must not be slower than the serial baseline"
    save_json("dse_speed", {"generation": recs, "batched_emulation": emu})
    return lines
