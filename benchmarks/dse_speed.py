"""Abstract claim — "Canal enables fast design space exploration": IR
generation + hardware lowering speed vs array size, plus the batched DSE
engine: B fabric configurations emulated as one ``run_batch`` scan vs the
serial per-config baseline, the fused engine (whole fixpoint + in-kernel
PE eval per cycle) vs the sweep-at-a-time PR-1 path, batch-axis sharding
across devices (in-process, plus a forced multi-device probe), and the
spec-addressed persistent result store: the same track sweep cold
(computing + persisting) vs warm (served from the store, zero PnR) —
appended to the repo-root ``BENCH_dse.json`` trajectory."""
from __future__ import annotations

import os
import time
from typing import Dict

import jax

from repro.core.dse import (batched_vs_serial_emulation,
                            fused_vs_unfused_emulation, generation_speed,
                            sharded_emulation_probe,
                            sharded_vs_single_emulation)

from .common import append_bench, emit, load_bench, save_json, timed


def store_warm_vs_cold(quick: bool = False,
                       store_root: str = None) -> Dict:
    """The persistent-store payoff: one ``sweep_num_tracks`` grid run
    against an empty (or pre-warmed) store, then re-run on a fresh
    executor over the same store. The second pass must do zero PnR —
    every record is served by digest. ``store_root`` defaults to
    ``$CANAL_RESULT_STORE`` when set (so incremental benchmark re-runs
    start warm), else a throwaway temp store — under ``run.py
    --no-store`` the first pass is therefore genuinely cold."""
    import tempfile

    from repro.core.dse import SweepExecutor, sweep_num_tracks
    from repro.core.pnr.app import BENCH_APPS
    from repro.core.store import STORE_ENV, ResultStore

    root = store_root or os.environ.get(STORE_ENV) or \
        tempfile.mkdtemp(prefix="canal-store-bench-")

    apps = {k: BENCH_APPS[k] for k in
            (("fir",) if quick else ("fir", "tree_reduce"))}
    tracks = (3, 4) if quick else (3, 4, 5)
    width = 6 if quick else 8

    def one_pass() -> Dict:
        ex = SweepExecutor(apps=apps, emulate_cycles=8, use_pallas=False,
                           max_workers=2,
                           store=ResultStore(root))
        t0 = time.perf_counter()
        sweep_num_tracks(tracks, width=width, height=width, executor=ex)
        return {"seconds": time.perf_counter() - t0,
                "store_hits": ex.store_hits,
                "store_misses": ex.store_misses,
                "pnr_computations": ex.pnr_computations}

    cold = one_pass()     # cold only on a truly fresh store; hit counts
    warm = one_pass()     # tell the two cases apart in the record
    assert warm["pnr_computations"] == 0, \
        "warm store must serve the whole sweep without recomputing PnR"
    assert warm["store_hits"] == len(tracks)
    return {"tracks": list(tracks), "width": width, "apps": list(apps),
            "first_pass": cold, "second_pass": warm,
            "cold_seconds": cold["seconds"],
            "warm_seconds": warm["seconds"],
            "speedup": cold["seconds"] / max(warm["seconds"], 1e-9),
            "first_pass_was_warm": cold["pnr_computations"] == 0}


def search_vs_grid(quick: bool = False) -> Dict:
    """The optimizer payoff: greedy ``canal.search`` vs the exhaustive
    grid on the ``sweep_num_tracks`` axis. Asserts the search lands on
    the grid's best fully-routed point while evaluating fewer
    candidates, and that an identical re-run against the warm store
    performs zero new PnR (pure store hits)."""
    import tempfile

    from repro.core.dse import SweepExecutor, sweep_num_tracks
    from repro.core.pnr.app import BENCH_APPS
    from repro.core.search import search
    from repro.core.spec import InterconnectSpec, SwitchBoxType
    from repro.core.store import ResultStore

    apps = {"fir": BENCH_APPS["fir"]}
    tracks = (2, 3, 4) if quick else (2, 3, 4, 5, 6)
    width = 6
    budget = 2 if quick else 4
    base = InterconnectSpec(width=width, height=width, num_tracks=3,
                            io_ring=True, sb_type=SwitchBoxType.WILTON,
                            reg_density=1.0, cb_track_fc=1.0,
                            sb_track_fc=1.0)
    grid_root = tempfile.mkdtemp(prefix="canal-grid-bench-")
    search_root = tempfile.mkdtemp(prefix="canal-search-bench-")

    grid_ex = SweepExecutor(apps=apps, use_pallas=False, max_workers=2,
                            store=ResultStore(grid_root))
    t0 = time.perf_counter()
    grid = sweep_num_tracks(tracks, width=width, height=width,
                            executor=grid_ex)
    grid_seconds = time.perf_counter() - t0
    routed = [r for r in grid
              if all(a["success"] for a in r["apps"].values())]
    best_grid = min(routed, key=lambda r: r["sb_area"] + r["cb_area"])

    t0 = time.perf_counter()
    res = search(base, {"num_tracks": tracks}, selector="greedy",
                 objective="area",
                 constraints={"min_routability": 1.0},
                 budget=budget, batch_size=2, seed=0, store=search_root,
                 apps=apps, use_pallas=False, max_workers=2)
    search_seconds = time.perf_counter() - t0
    best = res.best("area", {"min_routability": 1.0})
    assert best is not None, "search found no feasible point"
    assert best.digest == best_grid["spec_digest"], \
        "greedy search must land on the grid's best design point"
    assert len(res.evaluated) < len(tracks), \
        "search must evaluate fewer candidates than the full grid"

    rerun = search(base, {"num_tracks": tracks}, selector="greedy",
                   objective="area",
                   constraints={"min_routability": 1.0},
                   budget=budget, batch_size=2, seed=0,
                   store=search_root, apps=apps, use_pallas=False,
                   max_workers=2)
    assert rerun.stats["executor"]["pnr_computations"] == 0, \
        "repeated identical search must be pure store hits"

    return {"tracks": list(tracks), "width": width, "budget": budget,
            "grid_size": len(tracks),
            "grid_seconds": grid_seconds,
            "search_seconds": search_seconds,
            "search_evaluations": len(res.evaluated),
            "search_matched_best": True,
            "best_num_tracks": best.spec.num_tracks,
            "best_area": best.metrics["area"],
            "rerun_executor": rerun.stats["executor"]}


def run(quick: bool = False):
    sizes = (4, 8, 16) if quick else (4, 8, 16, 32)
    recs, us = timed(lambda: generation_speed(sizes))
    lines = []
    for r in recs:
        lines.append(emit(
            f"dse_speed/array={r['size']}x{r['size']}", us / len(recs),
            f"nodes={r['nodes']} gen={r['gen_seconds'] * 1e3:.0f}ms "
            f"lower={r['lower_seconds'] * 1e3:.0f}ms"))

    # batched configuration emulation: the production run_batch path
    # (fused batched kernel under use_pallas) vs looping run per config
    batch = 4 if quick else 8
    cycles = 8 if quick else 16
    width = 4 if quick else 6
    tracks = 2 if quick else 4
    emu = batched_vs_serial_emulation(width=width, height=width,
                                      num_tracks=tracks,
                                      batch=batch, cycles=cycles,
                                      use_pallas=True)
    lines.append(emit(
        f"dse_speed/batched_emulation_b={emu['batch']}",
        emu["batched_seconds"] * 1e6,
        f"serial={emu['serial_seconds'] * 1e3:.0f}ms "
        f"batched={emu['batched_seconds'] * 1e3:.0f}ms "
        f"speedup={emu['speedup']:.2f}x depth={emu['depth']}"))
    # both paths are pre-warmed; the measured margin is ~2.5-4x, so a 1.5x
    # tolerance only absorbs shared-runner timing noise, not a regression
    assert emu["batched_seconds"] <= emu["serial_seconds"] * 1.5, \
        "batched DSE emulation must not be slower than the serial baseline"

    # fused engine (one kernel call per cycle, PE cores in-kernel,
    # per-config depth masking) vs the sweep-at-a-time PR-1 baseline
    fus = fused_vs_unfused_emulation(width=width, height=width,
                                     num_tracks=tracks, batch=batch,
                                     cycles=cycles, use_pallas=True)
    lines.append(emit(
        f"dse_speed/fused_emulation_b={fus['batch']}",
        fus["fused_seconds"] * 1e6,
        f"unfused={fus['unfused_seconds'] * 1e3:.0f}ms "
        f"fused={fus['fused_seconds'] * 1e3:.0f}ms "
        f"speedup={fus['speedup']:.2f}x "
        f"depths={fus['min_depth']}..{fus['max_depth']}"))
    # measured margin ~1.3x in favour of the fused engine; the tolerance
    # absorbs runner noise while still catching a real regression
    assert fus["fused_seconds"] <= fus["unfused_seconds"] * 1.2, \
        "fused DSE engine must not regress the sweep-at-a-time baseline"

    # batch-axis sharding: in-process (1 device on CI -> fallback parity
    # check) plus a subprocess probe with forced host devices
    shd = sharded_vs_single_emulation(width=4, height=4, num_tracks=2,
                                      batch=batch, cycles=cycles,
                                      use_pallas=True)
    lines.append(emit(
        f"dse_speed/sharded_emulation_dev={shd['devices']}",
        shd["sharded_seconds"] * 1e6,
        f"single={shd['single_seconds'] * 1e3:.0f}ms "
        f"sharded={shd['sharded_seconds'] * 1e3:.0f}ms "
        f"speedup={shd['speedup']:.2f}x"))
    if len(jax.devices()) == 1:
        # same code path either way; anything beyond noise is a bug in
        # the single-device fallback
        assert shd["sharded_seconds"] <= shd["single_seconds"] * 1.5, \
            "single-device shard fallback must not add overhead"
    probe = sharded_emulation_probe(devices=2 if quick else 4,
                                    batch=batch, cycles=4)
    if "error" in probe:
        lines.append(emit("dse_speed/sharded_probe", 0.0,
                          f"skipped: {probe['error'][:120]}"))
    else:
        # forced host devices share the same cores, so this reports the
        # shard_map split working (bit-identical output is asserted in
        # the child), not a real speedup
        lines.append(emit(
            f"dse_speed/sharded_probe_dev={probe['devices']}",
            probe["sharded_seconds"] * 1e6,
            f"single={probe['single_seconds'] * 1e3:.0f}ms "
            f"sharded={probe['sharded_seconds'] * 1e3:.0f}ms "
            f"speedup={probe['speedup']:.2f}x"))
    # persistent result store: cold (compute + persist) vs warm (served
    # by digest, zero PnR asserted inside)
    wc = store_warm_vs_cold(quick=quick)
    lines.append(emit(
        f"dse_speed/store_warm_sweep_t{len(wc['tracks'])}",
        wc["warm_seconds"] * 1e6,
        f"cold={wc['cold_seconds']:.2f}s warm={wc['warm_seconds']:.2f}s "
        f"speedup={wc['speedup']:.1f}x "
        f"warm_hits={wc['second_pass']['store_hits']}"))

    # search-driven DSE vs the exhaustive grid (matched-best, fewer
    # evaluations, and zero-PnR re-run all asserted inside)
    sg = search_vs_grid(quick=quick)
    lines.append(emit(
        f"dse_speed/search_vs_grid_t{sg['grid_size']}",
        sg["search_seconds"] * 1e6,
        f"grid={sg['grid_seconds']:.2f}s "
        f"search={sg['search_seconds']:.2f}s "
        f"evals={sg['search_evaluations']}/{sg['grid_size']} "
        f"best_tracks={sg['best_num_tracks']}"))

    save_json("dse_speed", {"generation": recs, "batched_emulation": emu,
                            "fused_emulation": fus,
                            "sharded_emulation": shd,
                            "sharded_probe": probe,
                            "store_warm_vs_cold": wc,
                            "search_vs_grid": sg})
    # repo-root perf trajectory (append-style; one record per run).
    # A warm first pass makes the cold/warm speedup meaningless (~1x
    # noise next to real ~3000x measurements): record null so
    # trajectory consumers (load_bench skips nulls) never average it in.
    append_bench("BENCH_dse", {
        "quick": quick,
        "batched_speedup": emu["speedup"],
        "fused_speedup": fus["speedup"],
        "store_cold_seconds": wc["cold_seconds"],
        "store_warm_seconds": wc["warm_seconds"],
        "store_warm_speedup": (None if wc["first_pass_was_warm"]
                               else wc["speedup"]),
        "store_first_pass_was_warm": wc["first_pass_was_warm"],
        "search_evaluations": sg["search_evaluations"],
        "search_grid_size": sg["grid_size"],
        "search_matched_best": sg["search_matched_best"],
        "search_seconds": sg["search_seconds"],
        "grid_seconds": sg["grid_seconds"],
    })
    speedups = sorted(load_bench("BENCH_dse", "store_warm_speedup"))
    if speedups:
        lines.append(emit(
            "dse_speed/store_warm_trajectory",
            0.0,
            f"n={len(speedups)} "
            f"median={speedups[len(speedups) // 2]:.0f}x "
            "(warm-first-pass nulls skipped)"))
    return lines
