"""Abstract claim — "Canal enables fast design space exploration": IR
generation + hardware lowering speed vs array size, plus end-to-end
generate+PnR wall time for one DSE point."""
from __future__ import annotations

from repro.core.dse import generation_speed

from .common import emit, save_json, timed


def run(quick: bool = False):
    sizes = (4, 8, 16) if quick else (4, 8, 16, 32)
    recs, us = timed(lambda: generation_speed(sizes))
    lines = []
    for r in recs:
        lines.append(emit(
            f"dse_speed/array={r['size']}x{r['size']}", us / len(recs),
            f"nodes={r['nodes']} gen={r['gen_seconds'] * 1e3:.0f}ms "
            f"lower={r['lower_seconds'] * 1e3:.0f}ms"))
    save_json("dse_speed", recs)
    return lines
