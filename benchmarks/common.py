"""Shared benchmark utilities: timing, CSV emission, result persistence."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def timed(fn: Callable[[], Any]) -> tuple:
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def emit(name: str, us_per_call: float, derived: Any) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def save_json(name: str, payload: Any) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path
