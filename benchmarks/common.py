"""Shared benchmark utilities: timing, CSV emission, result persistence."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timed(fn: Callable[[], Any]) -> tuple:
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def emit(name: str, us_per_call: float, derived: Any) -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def save_json(name: str, payload: Any) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def append_bench(name: str, record: Dict) -> str:
    """Append one timestamped record to the repo-root ``<name>.json``
    trajectory file (a JSON list that grows run over run — the
    append-style perf history the roadmap tracks, as opposed to the
    overwritten snapshots under ``benchmarks/results/``). A corrupt or
    non-list file is restarted rather than crashing the benchmark.

    The write is atomic (unique same-directory temp file + ``os.replace``)
    so readers never see a torn file; the read-modify-write itself is not
    locked, so two benchmark runs racing on the same trajectory resolve
    last-writer-wins (one appended record may be dropped)."""
    from repro.core.store import atomic_write_json

    path = os.path.join(REPO_ROOT, f"{name}.json")
    try:
        with open(path) as f:
            history = json.load(f)
        if not isinstance(history, list):
            history = [history]
    except (OSError, json.JSONDecodeError):
        history = []
    history.append(dict(record, ts=time.time()))
    atomic_write_json(path, history)
    return path


def load_bench(name: str, metric: str = None) -> list:
    """Read a repo-root trajectory written by :func:`append_bench`.

    Without ``metric``: the full record list ([] when the file is
    missing or corrupt — consumers must tolerate a restarted
    trajectory). With ``metric``: that field's value per record, with
    ``None``/missing values *skipped* — a null metric marks a run where
    the measurement was meaningless (e.g. ``store_warm_speedup`` on a
    warm-first-pass run) and must not pollute medians or regression
    gates."""
    path = os.path.join(REPO_ROOT, f"{name}.json")
    try:
        with open(path) as f:
            history = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []
    if not isinstance(history, list):
        history = [history]
    if metric is None:
        return history
    return [r[metric] for r in history
            if isinstance(r, dict) and r.get(metric) is not None]
