"""Fig. 10 — SB and CB area vs number of routing tracks (area only)."""
from __future__ import annotations

import canal

from .common import emit, save_json, timed


def run(quick: bool = False):
    tracks = (2, 3, 4, 5, 6, 8, 10)
    recs = []

    def build():
        base = canal.InterconnectSpec(width=8, height=8, reg_density=1.0)
        for spec, extra in canal.spec_grid(base, {"num_tracks": tracks}):
            fab = canal.compile(spec)
            recs.append({**extra, **fab.area()})
        return recs

    _, us = timed(build)
    lines = []
    for r in recs:
        lines.append(emit(
            f"fig10/tracks={r['num_tracks']}", us / len(recs),
            f"sb={r['sb_area']:.0f}um2 cb={r['cb_area']:.0f}um2"))
    save_json("fig10_track_area", recs)
    sb = [r["sb_area"] for r in recs]
    cb = [r["cb_area"] for r in recs]
    assert all(b > a for a, b in zip(sb, sb[1:])), "SB area must grow"
    assert all(b > a for a, b in zip(cb, cb[1:])), "CB area must grow"
    return lines
