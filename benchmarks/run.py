"""Benchmark harness — one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig08]
        [--store PATH | --no-store]

Prints ``name,us_per_call,derived`` CSV per benchmark and saves JSON
records under benchmarks/results/ (consumed by EXPERIMENTS.md). Sweep
benchmarks run store-backed: design-point records persist in the
spec-addressed result store (``--store``, default ``.canal_store`` /
``$CANAL_RESULT_STORE``), so an incremental re-run only recomputes
design points whose spec digest is new — everything else is served from
disk. ``--no-store`` forces every point cold.

The digest addresses the *design point*, not the producing code: stored
records survive source edits, so after changing the router/emulator run
with ``--no-store`` (or delete the store root) to re-measure — CI gets
this for free by salting its store cache key with ``src/**``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced app/track sets")
    ap.add_argument("--only", type=str, default=None,
                    help="substring filter on benchmark module name")
    ap.add_argument("--store", type=str, default=None,
                    help="result-store root (default $CANAL_RESULT_STORE "
                         "or .canal_store)")
    ap.add_argument("--no-store", action="store_true",
                    help="run every design point cold (no persistence)")
    args = ap.parse_args()

    # the sweep executors attach the store via the env default; setting it
    # here makes every figure benchmark store-backed without threading a
    # store object through each module
    from repro.core.store import STORE_ENV, default_store_root
    if args.no_store:
        os.environ.pop(STORE_ENV, None)
    else:
        os.environ[STORE_ENV] = args.store or default_store_root()
        # per-record PnR timings (gen_pnr_seconds) always reflect the
        # original cold computation; only the module-level wall clocks
        # shrink on a warm store
        print(f"# result store: {os.environ[STORE_ENV]} (warm sweeps "
              "measure serve latency; records survive source edits — "
              "--no-store after changing the engines)", flush=True)

    from . import (dse_speed, fig08_fifo_area, fig09_topology_routability,
                   fig10_track_area, fig11_track_runtime, fig13_port_area,
                   fig14_15_port_runtime, pnr_speed)
    try:
        from . import kernels_bench
    except Exception:                                  # pragma: no cover
        kernels_bench = None
    try:
        from . import roofline_table
    except Exception:                                  # pragma: no cover
        roofline_table = None

    mods = [fig08_fifo_area, fig10_track_area, fig13_port_area, dse_speed,
            pnr_speed, fig09_topology_routability, fig11_track_runtime,
            fig14_15_port_runtime]
    if kernels_bench is not None:
        mods.append(kernels_bench)
    if roofline_table is not None:
        mods.append(roofline_table)

    print("name,us_per_call,derived")
    failures = []
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            mod.run(quick=args.quick)
            print(f"# {name}: ok in {time.perf_counter() - t0:.1f}s",
                  flush=True)
        except Exception as e:                        # pragma: no cover
            failures.append(name)
            traceback.print_exc()
            print(f"# {name}: FAILED ({e})", flush=True)
    if failures:
        sys.exit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
