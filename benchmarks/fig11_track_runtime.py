"""Fig. 11 — application run time vs number of routing tracks.

Paper: run time generally decreases with more tracks; benefit < 25 %.
Run time proxy = post-route critical path (cycle count is fixed per app).
"""
from __future__ import annotations

from repro.core.dse import sweep_num_tracks
from repro.core.pnr.app import BENCH_APPS

from .common import emit, save_json, timed


def run(quick: bool = False):
    from repro.core.pnr.app import app_butterfly
    tracks = (2, 4, 6) if quick else (2, 3, 4, 5, 6)
    apps = {"butterfly3": lambda: app_butterfly(3)}
    if not quick:
        apps.update({k: BENCH_APPS[k] for k in ("tree_reduce", "fir")})
    recs, us = timed(lambda: sweep_num_tracks(tracks, apps=apps,
                                              sa_steps=40, track_fc=0.5))
    lines = []
    for r in recs:
        oks = [a for a in r["apps"].values() if a["success"]]
        mean_crit = (sum(a["critical_path_ns"] for a in oks) / len(oks)
                     if oks else float("inf"))
        r["mean_critical_path_ns"] = mean_crit
        lines.append(emit(
            f"fig11/tracks={r['num_tracks']}", us / len(recs),
            f"routed={len(oks)}/{len(r['apps'])} "
            f"mean_crit={mean_crit:.2f}ns"))
    save_json("fig11_track_runtime", recs)
    done = [r for r in recs if all(a["success"] for a in r["apps"].values())]
    if len(done) >= 2:
        crits = [r["mean_critical_path_ns"] for r in done]
        # paper: runtime generally decreases, benefits < 25 % — i.e. track
        # count is a second-order effect once routable; assert the band.
        assert max(crits) / min(crits) < 1.25, \
            "track-count runtime spread should stay within the paper's band"
        assert crits[-1] <= crits[0] * 1.15, \
            "more tracks should not systematically slow applications"
        # fewer tracks must reduce routability or never improve it
        n_ok = [sum(a["success"] for a in r["apps"].values()) for r in recs]
        assert n_ok[0] <= max(n_ok), "routability should not shrink w/ tracks"
    return lines
