"""Fig. 13 — SB / CB area vs number of core-port connection sides
(Fig. 12 reduction: 4 -> 3 (drop east) -> 2 (drop south))."""
from __future__ import annotations

import canal

from .common import emit, save_json, timed


def run(quick: bool = False):
    recs = []

    def build():
        base = canal.InterconnectSpec(width=8, height=8, num_tracks=5,
                                      reg_density=1.0)
        for kind in ("sb", "cb"):
            axis = f"{kind}_sides"
            for spec, _ in canal.spec_grid(base, {axis: (4, 3, 2)}):
                fab = canal.compile(spec)
                recs.append({"kind": kind, "sides": getattr(spec, axis),
                             **fab.area()})
        return recs

    _, us = timed(build)
    lines = []
    for r in recs:
        lines.append(emit(
            f"fig13/{r['kind']}_sides={r['sides']}", us / len(recs),
            f"sb={r['sb_area']:.0f}um2 cb={r['cb_area']:.0f}um2"))
    save_json("fig13_port_area", recs)
    sb_rows = [r for r in recs if r["kind"] == "sb"]
    cb_rows = [r for r in recs if r["kind"] == "cb"]
    assert sb_rows[0]["sb_area"] > sb_rows[-1]["sb_area"], \
        "fewer SB core connections must shrink the SB"
    assert cb_rows[0]["cb_area"] > cb_rows[-1]["cb_area"], \
        "fewer CB connections must shrink the CB"
    # paper: CB shrinks relatively more than SB
    sb_drop = 1 - sb_rows[-1]["sb_area"] / sb_rows[0]["sb_area"]
    cb_drop = 1 - cb_rows[-1]["cb_area"] / cb_rows[0]["cb_area"]
    assert cb_drop > sb_drop, "CB depopulation should matter more (paper)"
    return lines
