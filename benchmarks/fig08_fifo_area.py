"""Fig. 8 — SB area: static baseline vs depth-2 FIFO vs split FIFO.

Paper: full FIFOs +54 % area over the static baseline; split FIFOs +32 %.
"""
from __future__ import annotations

from repro.core.dse import fifo_area_study

from .common import emit, save_json, timed


def run(quick: bool = False):
    recs, us = timed(lambda: fifo_area_study())
    lines = []
    for r in recs:
        lines.append(emit(f"fig08/{r['design']}", us / len(recs),
                          f"sb_area={r['sb_area']:.0f}um2 "
                          f"overhead={r['overhead'] * 100:+.1f}%"))
    save_json("fig08_fifo_area", recs)
    full = next(r for r in recs if r["design"] == "fifo_full")
    split = next(r for r in recs if r["design"] == "fifo_split")
    assert abs(full["overhead"] - 0.54) < 0.03, "Fig8 full-FIFO ratio drift"
    assert abs(split["overhead"] - 0.32) < 0.03, "Fig8 split-FIFO ratio drift"
    return lines
