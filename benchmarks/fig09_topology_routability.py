"""§4.2.1 (Fig. 9 topologies) — Wilton vs Disjoint routability.

Paper: "the Wilton topology performs much better than the Disjoint
topology, which failed to route in all of our test cases."
"""
from __future__ import annotations

from repro.core.dse import sweep_sb_topology
from repro.core.edsl import SwitchBoxType
from repro.core.pnr.app import BENCH_APPS

from .common import emit, save_json, timed


def run(quick: bool = False):
    from repro.core.pnr.app import app_butterfly
    apps = {"butterfly3": lambda: app_butterfly(3)}
    if not quick:
        apps.update({k: BENCH_APPS[k] for k in ("tree_reduce", "fir")})
    # depopulated track connections (Fc=0.5) stress the topology, as the
    # paper's larger application suite does
    recs, us = timed(lambda: sweep_sb_topology(
        (SwitchBoxType.WILTON, SwitchBoxType.DISJOINT), apps=apps,
        num_tracks=4, width=8, height=8, sa_steps=60, track_fc=0.5))
    lines = []
    for r in recs:
        lines.append(emit(
            f"fig09/{r['topology']}", us / len(recs),
            f"routed={r['n_routed']}/{r['n_apps']} "
            f"sb_area={r['sb_area']:.0f}um2"))
    save_json("fig09_topology", recs)
    wil = next(r for r in recs if r["topology"] == "wilton")
    dis = next(r for r in recs if r["topology"] == "disjoint")
    assert wil["n_routed"] > dis["n_routed"], \
        "Wilton should out-route Disjoint"
    assert abs(wil["sb_area"] - dis["sb_area"]) < 1e-6, \
        "paper: same area for both topologies"
    return lines
