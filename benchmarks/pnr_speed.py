"""PnR speed: the device-accelerated PathFinder vs the Python A* oracle.

Three measurements, persisted as ``BENCH_pnr.json``:

* ``routing`` — routed nets/sec on a shared placement of the benchmark
  apps over a >=8x8 mesh with >=5 tracks: ``strategy="python"``
  (Manhattan-bounded A*) vs ``strategy="minplus"`` (batched tropical
  Bellman-Ford coarse cost fields as A* lower bounds). Both run on the
  same cached ``RoutingResources``; the headline number is the speedup
  of the tile-coarsened batched path (acceptance: >=2x).
* ``placement`` — annealing steps/sec at an equal step budget:
  ``strategy="python"`` (host SA, one chain, one device round-trip per
  step) vs ``strategy="batched"`` (K parallel-tempering chains as one
  jitted ``lax.scan``). Same chain/batch population; also records the
  final Eq. 2 cost ratio (acceptance: >=3x faster, ratio <= 1).
* ``sweep`` — end-to-end ``SweepExecutor`` wall time for a small track
  sweep (PnR + batched emulation) per strategy, with the async
  PnR/emulation pipeline on, so router gains survive to the sweep level.
"""
from __future__ import annotations

import time
from typing import Dict, List

from .common import append_bench, emit, save_json


def _route_workload(width: int, height: int, num_tracks: int,
                    app_names: List[str]):
    """Shared fixture: interconnect, resources, and packed+placed apps
    (placement runs once — the benchmark times *routing* only)."""
    from repro.core.passes import PassManager
    from repro.core.pnr.app import BENCH_APPS
    from repro.core.pnr.detailed_place import detailed_place
    from repro.core.pnr.global_place import assign_ios, global_place, legalize
    from repro.core.pnr.packing import pack
    from repro.core.pnr.route import RoutingResources
    from repro.core.spec import InterconnectSpec, SwitchBoxType

    ic = PassManager().run(InterconnectSpec(
        width=width, height=height, num_tracks=num_tracks, io_ring=True,
        sb_type=SwitchBoxType.WILTON, reg_density=1.0))
    res = RoutingResources(ic)
    placed = []
    for name in app_names:
        packed = pack(BENCH_APPS[name]())
        fixed = assign_ios(packed, width, height)
        cont = global_place(packed, width, height, fixed=fixed, seed=0)
        base = legalize(packed, cont, width, height, io_ring=True,
                        fixed=fixed)
        pl = detailed_place(packed, base, width, height, io_ring=True,
                            gamma=0.3, alpha=2.0, n_steps=40, batch=8,
                            seed=0)
        placed.append((name, packed, pl))
    return ic, res, placed


def _route_all(ic, res, placed, strategy: str) -> int:
    from repro.core.pnr.route import route_app

    nets = 0
    for _, packed, pl in placed:
        result = route_app(ic, packed, pl, res=res, strategy=strategy)
        nets += len(result.nets)
    return nets


def routing_speed(width: int = 8, height: int = 8, num_tracks: int = 5,
                  repeats: int = 3) -> Dict:
    """python-A* vs minplus-batched routed nets/sec (shared placement,
    shared resources, best-of-N wall clocks)."""
    apps = ["pointwise", "tree_reduce", "fir", "butterfly"]
    ic, res, placed = _route_workload(width, height, num_tracks, apps)
    rec: Dict = {"width": width, "height": height,
                 "num_tracks": num_tracks, "apps": apps,
                 "nodes": len(res.nodes)}
    for strategy in ("python", "minplus"):
        nets = _route_all(ic, res, placed, strategy)   # warm (jit, fields)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            nets = _route_all(ic, res, placed, strategy)
            best = min(best, time.perf_counter() - t0)
        rec[strategy] = {"nets": nets, "seconds": best,
                         "nets_per_sec": nets / max(best, 1e-9)}
    rec["speedup"] = (rec["minplus"]["nets_per_sec"]
                      / max(rec["python"]["nets_per_sec"], 1e-9))
    return rec


def place_speed(width: int = 8, height: int = 8,
                quick: bool = False) -> Dict:
    """Host SA vs device-resident parallel-tempering chains: annealing
    steps/sec at an equal step budget and chain population, plus the
    final Eq. 2 cost ratio (batched / host, lower is better)."""
    from repro.core.pnr.app import BENCH_APPS
    from repro.core.pnr.batched_anneal import batched_place, eq2_cost
    from repro.core.pnr.detailed_place import detailed_place
    from repro.core.pnr.global_place import assign_ios, global_place, legalize
    from repro.core.pnr.packing import pack

    app_name = "butterfly"
    steps = 60 if quick else 120
    chains = 16
    packed = pack(BENCH_APPS[app_name]())
    fixed = assign_ios(packed, width, height)
    cont = global_place(packed, width, height, fixed=fixed, seed=0)
    base = legalize(packed, cont, width, height, io_ring=True, fixed=fixed)

    # warm both engines so neither pays jit compilation in the timed run
    batched_place(packed, base, width, height, io_ring=True,
                  n_steps=steps, n_chains=chains, seed=0)
    detailed_place(packed, base, width, height, io_ring=True, n_steps=2,
                   batch=chains, seed=0)

    t0 = time.perf_counter()
    pl_b, cost_b = batched_place(packed, base, width, height,
                                 io_ring=True, n_steps=steps,
                                 n_chains=chains, seed=0,
                                 return_cost=True)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    pl_h = detailed_place(packed, base, width, height, io_ring=True,
                          n_steps=steps, batch=chains, seed=0)
    t_host = time.perf_counter() - t0
    cost_h = float(eq2_cost(packed, pl_h, width, height))

    return {"width": width, "height": height, "app": app_name,
            "steps": steps, "chains": chains,
            "python": {"seconds": t_host,
                       "steps_per_sec": steps / max(t_host, 1e-9),
                       "final_cost": cost_h},
            "batched": {"seconds": t_batched,
                        "steps_per_sec": steps / max(t_batched, 1e-9),
                        "final_cost": float(cost_b)},
            "speedup": t_host / max(t_batched, 1e-9),
            "cost_ratio": float(cost_b) / max(cost_h, 1e-9)}


def sweep_speed(quick: bool = False) -> Dict:
    """End-to-end SweepExecutor wall time per router strategy (async
    emulation pipeline on): the router win at the DSE-sweep level."""
    from repro.core.dse import SweepExecutor
    from repro.core.pnr.app import BENCH_APPS
    from repro.core.spec import InterconnectSpec, spec_grid

    apps = {k: BENCH_APPS[k] for k in
            (("fir",) if quick else ("fir", "tree_reduce"))}
    tracks = (5,) if quick else (4, 5)
    # the annealing budget lives on the spec now (folded PnR knobs): the
    # design point fully describes how it is placed and routed
    base = InterconnectSpec(width=8, height=8, io_ring=True,
                            reg_density=1.0, sa_steps=30, sa_batch=8)
    points = spec_grid(base, {"num_tracks": tracks})
    rec: Dict = {"tracks": list(tracks), "apps": list(apps)}
    for strategy in ("python", "minplus"):
        # store=False: this benchmark times the router — serving records
        # from a warm store would measure the cache, not the engine
        ex = SweepExecutor(apps=apps,
                           emulate_cycles=8, use_pallas=False,
                           route_strategy=strategy, max_workers=2,
                           store=False)
        t0 = time.perf_counter()
        recs = ex.run_points(points)
        rec[strategy] = {"seconds": time.perf_counter() - t0,
                         "n_routed": sum(
                             1 for r in recs for a in r["apps"].values()
                             if a["success"])}
    rec["speedup"] = (rec["python"]["seconds"]
                      / max(rec["minplus"]["seconds"], 1e-9))
    return rec


def run(quick: bool = False):
    lines = []
    route_rec = routing_speed(repeats=2 if quick else 3)
    lines.append(emit(
        f"pnr_speed/route_{route_rec['width']}x{route_rec['height']}"
        f"_t{route_rec['num_tracks']}",
        route_rec["minplus"]["seconds"] * 1e6,
        f"python={route_rec['python']['nets_per_sec']:.1f}n/s "
        f"minplus={route_rec['minplus']['nets_per_sec']:.1f}n/s "
        f"speedup={route_rec['speedup']:.2f}x"))
    # the acceptance margin (>=2x) holds with ~2x headroom on a warm run;
    # assert a floor low enough to only flag real regressions on noisy
    # shared runners
    assert route_rec["speedup"] >= 1.2, \
        "batched min-plus router must beat the Python A* baseline"

    place_rec = place_speed(quick=quick)
    lines.append(emit(
        f"pnr_speed/place_{place_rec['width']}x{place_rec['height']}"
        f"_k{place_rec['chains']}",
        place_rec["batched"]["seconds"] * 1e6,
        f"python={place_rec['python']['steps_per_sec']:.0f}st/s "
        f"batched={place_rec['batched']['steps_per_sec']:.0f}st/s "
        f"speedup={place_rec['speedup']:.1f}x "
        f"cost_ratio={place_rec['cost_ratio']:.3f}"))
    # acceptance is >=3x with equal-or-better final cost; the asserted
    # floors leave noise headroom on shared runners
    assert place_rec["speedup"] >= 1.5, \
        "batched annealing chains must beat the host SA loop"
    assert place_rec["cost_ratio"] <= 1.05, \
        "batched annealing must not regress final Eq. 2 cost"

    sweep_rec = sweep_speed(quick=quick)
    lines.append(emit(
        "pnr_speed/sweep_8x8",
        sweep_rec["minplus"]["seconds"] * 1e6,
        f"python={sweep_rec['python']['seconds']:.2f}s "
        f"minplus={sweep_rec['minplus']['seconds']:.2f}s "
        f"speedup={sweep_rec['speedup']:.2f}x"))
    save_json("BENCH_pnr", {"routing": route_rec, "placement": place_rec,
                            "sweep": sweep_rec})
    # repo-root perf trajectory (append-style; one record per run)
    append_bench("BENCH_pnr", {
        "route_speedup": route_rec["speedup"],
        "minplus_nets_per_sec": route_rec["minplus"]["nets_per_sec"],
        "python_nets_per_sec": route_rec["python"]["nets_per_sec"],
        "place_speedup": place_rec["speedup"],
        "place_cost_ratio": place_rec["cost_ratio"],
        "batched_steps_per_sec": place_rec["batched"]["steps_per_sec"],
        "sweep_speedup": sweep_rec["speedup"],
        "sweep_minplus_seconds": sweep_rec["minplus"]["seconds"],
    })
    return lines
